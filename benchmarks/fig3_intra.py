"""Fig 3: work saved by the intra-iteration optimization vs sample size."""
import jax

from benchmarks.common import emit, timeit
from repro.core import Mean, bootstrap, optimal_y, shared_base_bootstrap, \
    work_saved
from repro.data import synthetic_numeric
import jax.numpy as jnp


def run() -> None:
    key = jax.random.PRNGKey(1)
    # analytic curve (Eq. 4): work saved at optimal y per n
    for n in (10, 29, 50, 100, 500, 1000, 5000):
        y, w = optimal_y(n)
        emit(f"fig3_worksaved_n{n}", 0.0,
             f"y*={y:.3f};saved={w:.4f};p_shared={work_saved(n, y) / max(y, 1e-9):.4f}")

    # measured: shared-base bootstrap vs standard (same B, n)
    x = jnp.asarray(synthetic_numeric(4000, 10, 2, seed=1))
    us_std = timeit(lambda: jax.block_until_ready(
        bootstrap(x, Mean(), B=64, key=key, engine="multinomial").thetas))
    us_int = timeit(lambda: jax.block_until_ready(
        shared_base_bootstrap(x, Mean(), B=64, key=key).thetas))
    emit("fig3_standard_bootstrap", us_std, "")
    emit("fig3_shared_base_bootstrap", us_int,
         f"speedup={us_std / max(us_int, 1e-9):.2f}x")
