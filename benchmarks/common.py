"""Benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")
