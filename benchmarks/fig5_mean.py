"""Fig 5: mean via EARL vs full computation ('stock Hadoop') vs data size.

Two cost metrics per N:
  * wall time (warm JIT: the session runs once cold to populate caches,
    then the timed run starts from a fresh sampler)
  * rows processed — the hardware-independent cost EARL actually saves
    (the paper's regime is I/O-dominated; row savings is the transferable
    number, wall-clock speedup on this CPU container is the lower bound).

The paper's small-data fallback (<1 GB ⇒ run exact) is exercised last."""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import EarlSession, Mean
from repro.data import PreMapSampler, ShardedStore, synthetic_numeric


def _run_session(data, key, sigma=0.05):
    store = ShardedStore.from_array(data, 65_536)
    sess = EarlSession(PreMapSampler(store, seed=3), Mean(), sigma=sigma)
    out = sess.run(key)
    return out, store


def run() -> None:
    key = jax.random.PRNGKey(2)
    for N in (50_000, 500_000, 5_000_000):
        data = synthetic_numeric(N, 10.0, 2.0, seed=2)

        t0 = time.perf_counter()
        full = float(np.mean(np.concatenate(
            ShardedStore.from_array(data, 65_536).splits)))
        t_full = time.perf_counter() - t0

        _run_session(data, key)                  # warm JIT caches
        t0 = time.perf_counter()
        out, store = _run_session(data, key)     # timed, fresh sampler
        t_earl = time.perf_counter() - t0

        est = float(np.ravel(out.result)[0])
        emit(f"fig5_mean_N{N}", t_earl * 1e6,
             f"wall_speedup={t_full / max(t_earl, 1e-9):.2f}x;"
             f"row_speedup={store.stats.rows_read and N / store.stats.rows_read:.1f}x;"
             f"rel_err={abs(est - full) / abs(full):.4f};"
             f"fraction={out.fraction:.4f};fellback={out.fell_back}")

    # small-data fallback (paper Fig 5 left edge)
    data = synthetic_numeric(2_000, 10.0, 2.0, seed=2)
    store = ShardedStore.from_array(data, 512)
    sess = EarlSession(PreMapSampler(store, seed=3), Mean(), sigma=0.001)
    out = sess.run(key)
    emit("fig5_mean_smalldata", out.wall_time_s * 1e6,
         f"fellback={out.fell_back}")
