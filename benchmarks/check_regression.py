"""Per-kernel perf regression gate for the nightly kernelbench run.

Compares freshly produced BENCH_*.json files against the checked-in
baselines: each file's headline speedup must stay within ``--min-ratio``
of its baseline (wall-clock microseconds are NOT compared — CI hardware
differs run to run; speedup ratios are self-normalizing), must stay above
its absolute floor (a structural win that stops being a win is a
regression even if the baseline already drifted), and the structural
invariants (zero weight-matrix bytes, shared-weight bitwise equality)
must hold exactly.

Usage:
    python benchmarks/check_regression.py \
        --baseline /tmp/bench-baseline --current . [--min-ratio 0.5]

Exit code 1 (with a per-metric table) on any violation; missing current
files fail, missing baseline files are skipped with a note (a new
benchmark has no history yet).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

#: file -> headline speedup keys gated against min_ratio × baseline
METRICS = {
    "BENCH_bootstrap.json": ("speedup_fused_vs_materialized",
                             "speedup_fused_vs_naive"),
    "BENCH_kmeans.json": ("speedup_fused_vs_materialized",),
    "BENCH_quantile.json": ("speedup_fused_vs_materialized",),
    "BENCH_multi.json": ("speedup_group_vs_sequential",),
    "BENCH_grouped.json": ("speedup_grouped_vs_sequential",),
    "BENCH_stream.json": ("speedup_stream_vs_serial",),
}

#: absolute floors: the fused paths must stay faster than their baselines
#: at all (>= 1.0), and the k=3 group must keep its ISSUE-5 acceptance
#: margin over sequential runs.
FLOORS = {
    "speedup_fused_vs_materialized": 1.0,
    "speedup_fused_vs_naive": 1.0,
    "speedup_group_vs_sequential": 1.5,
    # ISSUE-7: G=8 grouped means share ONE weight stream and one data
    # pass vs 8 sequential per-key fused runs
    "speedup_grouped_vs_sequential": 2.0,
    # ISSUE-6: streaming must beat the non-overlapped serial
    # transfer+compute pipeline by 30% even on a 1-core host
    "speedup_stream_vs_serial": 1.3,
}

#: file -> (key, min) pairs gated against an ABSOLUTE floor only (no
#: baseline ratio): throughputs that depend on the host and would be
#: noise under a cross-hardware baseline comparison, but whose collapse
#: (an accidentally quadratic drain, a fold that stopped being O(Δn))
#: should still fail loudly.  Floors are deliberately conservative for
#: the 1-core CI container.
ABS_FLOORS = {
    # ISSUE-9: a standing LiveSession must sustain a usable fold rate
    "BENCH_live.json": (("batches_per_sec", 20.0),),
}

#: file -> (key, max) pairs for lower-is-better metrics: absolute caps,
#: not baseline-relative (an overhead that doubles but stays under the
#: cap is fine; one that creeps past it is a regression even if the
#: baseline had already drifted there).
CEILINGS = {
    # ISSUE-8: snapshotting the donated carry every k chunks must cost
    # <= 10% over the uncheckpointed streamed run
    "BENCH_ft.json": (("checkpoint_overhead_ratio", 1.10),),
    # ISSUE-10: the durable ingest pipeline under the default group-commit
    # policy (fsync=batch) must cost <= 1.5x the in-memory pipeline — the
    # write-behind writer thread earns this by overlapping segment writes
    # with the producer's next batch
    "BENCH_durable.json": (("fsync_tax_batch", 1.5),),
}

#: (file, dotted path) -> exact required value
INVARIANTS = {
    ("BENCH_bootstrap.json", "peak_weight_bytes.fused_rng"): 0,
    ("BENCH_multi.json", "member_thetas_bitwise_equal_to_sequential"): True,
    ("BENCH_multi.json", "weight_streams.group"): 1,
    ("BENCH_grouped.json",
     "per_key_thetas_bitwise_equal_to_sequential"): True,
    ("BENCH_grouped.json", "weight_streams.grouped"): 1,
    ("BENCH_stream.json", "thetas_bitwise_equal_to_chunked"): True,
    # ISSUE-8: kill/resume and checkpointed runs reproduce the
    # uninterrupted run bit for bit, and an injected-fault run finishes
    # without manual intervention
    ("BENCH_ft.json", "resumed_bitwise_equal"): True,
    ("BENCH_ft.json", "checkpointed_bitwise_equal"): True,
    ("BENCH_ft.json", "degraded_run_completed"): True,
    # ISSUE-9: the live-ingest robustness contract — kill/resume bitwise,
    # shed fold bitwise equal to the dedicated valid_mask oracle, pane
    # ring within its memory bound, every batch folded exactly once
    ("BENCH_live.json", "resumed_bitwise_equal"): True,
    ("BENCH_live.json", "shed_bitwise_equal_to_oracle"): True,
    ("BENCH_live.json", "pane_ring_bounded"): True,
    ("BENCH_live.json", "dedup_exactly_once"): True,
    # ISSUE-10: recovery from disk is not just fast but RIGHT — the
    # recovered store is bitwise equal to the in-memory log fed the same
    # batches, and a torn tail write truncates to the surviving prefix
    ("BENCH_durable.json", "recovery_bitwise_equal"): True,
    ("BENCH_durable.json", "torn_recovery_ok"): True,
}


def _get(doc: dict, dotted: str):
    cur = doc
    for part in dotted.split("."):
        cur = cur[part]
    return cur


def check(baseline_dir: pathlib.Path, current_dir: pathlib.Path,
          min_ratio: float) -> list:
    failures = []
    for fname, keys in METRICS.items():
        cur_path = current_dir / fname
        if not cur_path.exists():
            failures.append(f"{fname}: missing from current run")
            continue
        cur = json.loads(cur_path.read_text())
        base_path = baseline_dir / fname
        base = (json.loads(base_path.read_text())
                if base_path.exists() else None)
        if base is None:
            print(f"NOTE  {fname}: no baseline (new benchmark) — "
                  f"floor checks only")
        for key in keys:
            val = float(cur[key])
            floor = FLOORS.get(key, 1.0)
            status = "ok"
            if val < floor:
                status = f"BELOW FLOOR {floor}"
                failures.append(f"{fname}:{key} = {val:.2f} < floor {floor}")
            elif base is not None:
                ref = float(base[key])
                if val < min_ratio * ref:
                    status = f"REGRESSED vs {ref:.2f}"
                    failures.append(
                        f"{fname}:{key} = {val:.2f} < "
                        f"{min_ratio} x baseline {ref:.2f}")
            ref_s = f"{float(base[key]):8.2f}" if base is not None else \
                "     new"
            print(f"{'FAIL' if status != 'ok' else ' ok '} {fname}:{key}"
                  f"  current={val:8.2f}  baseline={ref_s}  [{status}]")

    for fname, mins in ABS_FLOORS.items():
        cur_path = current_dir / fname
        if not cur_path.exists():
            failures.append(f"{fname}: missing from current run")
            continue
        cur = json.loads(cur_path.read_text())
        for key, floor in mins:
            val = float(cur[key])
            if val < floor:
                failures.append(
                    f"{fname}:{key} = {val:.2f} < abs floor {floor}")
                print(f"FAIL {fname}:{key}  current={val:8.2f}  "
                      f"[BELOW ABS FLOOR {floor}]")
            else:
                print(f" ok  {fname}:{key}  current={val:8.2f}  "
                      f"abs_floor={floor}")

    for fname, caps in CEILINGS.items():
        cur_path = current_dir / fname
        if not cur_path.exists():
            failures.append(f"{fname}: missing from current run")
            continue
        cur = json.loads(cur_path.read_text())
        for key, cap in caps:
            val = float(cur[key])
            if val > cap:
                failures.append(
                    f"{fname}:{key} = {val:.3f} > ceiling {cap}")
                print(f"FAIL {fname}:{key}  current={val:8.3f}  "
                      f"[ABOVE CEILING {cap}]")
            else:
                print(f" ok  {fname}:{key}  current={val:8.3f}  "
                      f"ceiling={cap}")

    for (fname, dotted), want in INVARIANTS.items():
        cur_path = current_dir / fname
        if not cur_path.exists():
            continue                      # already failed above
        got = _get(json.loads(cur_path.read_text()), dotted)
        if got != want:
            failures.append(f"{fname}:{dotted} = {got!r}, expected {want!r}")
            print(f"FAIL {fname}:{dotted} = {got!r} != {want!r}")
        else:
            print(f" ok  {fname}:{dotted} = {got!r}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=pathlib.Path, required=True,
                    help="directory holding the checked-in BENCH_*.json")
    ap.add_argument("--current", type=pathlib.Path, default=pathlib.Path("."),
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--min-ratio", type=float, default=0.5,
                    help="current speedup must be >= this fraction of the "
                         "baseline speedup (default 0.5 — timing on shared "
                         "CI is noisy; floors catch structural losses)")
    args = ap.parse_args(argv)
    failures = check(args.baseline, args.current, args.min_ratio)
    if failures:
        print("\nREGRESSIONS:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nall kernel benchmarks within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
