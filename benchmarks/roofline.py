"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e-like, per chip):
    peak  = 197 TFLOP/s bf16
    hbm   = 819 GB/s
    ici   = ~50 GB/s per chip of interconnect bandwidth

Terms per (arch × shape), single-pod mesh (per the assignment the roofline
table is single-pod; the pod2 artifacts prove multi-pod sharding):

    compute_term    = HLO_FLOPs / (chips · peak)
    memory_term     = HLO_bytes / (chips · hbm)
    collective_term = collective_bytes / (chips · ici)

HLO_FLOPs/bytes use the trip-multiplied dot accounting
(launch/hlo_flops.py) because XLA's cost_analysis does not multiply scan
bodies — both numbers are recorded.  collective_bytes is per-chip wire
bytes (launch/hlo_analysis.py) × chips, matching the prescribed form.

MFU bound = MODEL_FLOPS / (chips · peak · max(terms)) — the achievable
model-flops utilization of the compiled program assuming perfect
compute/comm overlap; serial MFU uses Σ terms (no overlap).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.model_flops import model_flops_for

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def parse_artifact_name(filename: str):
    """{arch}.{shape}.{podN}[.{tag}].json — arch may itself contain dots
    (llama-3.2-vision-90b), so parse from the END."""
    base = os.path.basename(filename)
    if base.endswith(".json"):
        base = base[:-5]
    parts = base.split(".")
    if parts[-1] in ("pod1", "pod2"):
        tag, pod = "", parts[-1]
        shape = parts[-2]
        arch = ".".join(parts[:-2])
    else:
        tag, pod = parts[-1], parts[-2]
        shape = parts[-3]
        arch = ".".join(parts[:-3])
    return arch, shape, pod, tag


def load_records(mesh: str = "16x16", tag: str = "",
                 directory: Optional[str] = None) -> List[dict]:
    directory = directory or ARTIFACT_DIR
    out = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        _, _, _, rec_tag = parse_artifact_name(f)
        if rec_tag != tag:
            continue
        r = json.load(open(f))
        if r.get("mesh") == mesh or r.get("status") == "skipped":
            out.append(r)
    return out


def roofline_row(rec: dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    compute = rec["dot_flops_per_chip"] / PEAK
    memory = rec["dot_bytes_per_chip"] / HBM
    coll = rec["collective_bytes_per_chip"]["total"] / ICI
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_for(rec["arch"], rec["shape"])
    hlo_flops_global = rec["dot_flops_per_chip"] * chips
    ratio = mf / hlo_flops_global if hlo_flops_global else float("nan")
    t_overlap = max(terms.values())
    t_serial = sum(terms.values())
    mfu = mf / (chips * PEAK * t_overlap) if t_overlap else 0.0
    mfu_serial = mf / (chips * PEAK * t_serial) if t_serial else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=chips,
        compute_s=compute, memory_s=memory, collective_s=coll,
        dominant=dominant,
        model_flops=mf, hlo_flops=hlo_flops_global,
        useful_ratio=ratio,
        mfu_overlap=mfu, mfu_serial=mfu_serial,
        state_gib_per_chip=rec["state_bytes_per_chip"] / 2**30,
    )


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO flops | MFU bound |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_overlap']:.1%} |")
    return hdr + "\n".join(lines)


def run() -> None:
    from benchmarks.common import emit
    rows = [r for r in (roofline_row(rec) for rec in load_records())
            if r is not None]
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}",
             max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6,
             f"dominant={r['dominant']};mfu_bound={r['mfu_overlap']:.4f};"
             f"useful_ratio={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    rows = [r for r in (roofline_row(rec) for rec in load_records())
            if r is not None]
    print(markdown_table(rows))
