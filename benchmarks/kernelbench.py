"""Kernel-level microbench: fused weighted-moments path vs naive jnp.

On this CPU container the Pallas kernels run in interpret mode (a
correctness tool, not a perf tool), so the timing comparison here is the
fused *algorithm* (one pass, three moments) against the naive version — the
structural win the TPU kernel encodes.  The VMEM/MXU design constants are
reported as derived metadata for the roofline discussion.

``run_bootstrap`` benchmarks the matrix-free resample loop (in-kernel
counter-based RNG fused into the contraction, via the scan lowering on CPU)
against the materialized-(B, n) weight-matrix path and the naive 3-pass
formulation — plus the bf16-input variant (ROADMAP study: x and w enter the
dots in bf16 with f32 accumulators), quantifying its cv error against the
f32 kernel — and writes the trajectory to BENCH_bootstrap.json so perf is
tracked PR-over-PR.  ``run_kmeans`` does the same for bootstrap-over-
k-means (BENCH_kmeans.json); ``run_quantile`` for the fused Quantile sketch
(kernels/weighted_hist.fused_poisson_hist vs materializing the implicit
weights and scatter-adding per resample), writing BENCH_quantile.json;
``run_stream`` for the double-buffered streaming driver vs the
non-overlapped materialize-then-compute pipeline (BENCH_stream.json).

``--smoke`` (or ``run(smoke=True)``) drives every kernel dispatch path at
tiny shapes with NO timing and NO BENCH_*.json writes — a tier-1 pytest
runs it (tests/test_kernelbench_smoke.py) so dispatch regressions fail in
CI instead of only surfacing in benchmark runs.
"""
import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.bootstrap import fused_resample_states
from repro.core.reduce_api import (KMeansStep, Mean, Quantile,
                                   StatisticGroup, Var)
from repro.kernels.fused_multi import ops as fm_ops
from repro.kernels.kmeans_assign import ops as ka_ops
from repro.kernels.weighted_hist import ops as wh_ops
from repro.kernels.weighted_stats import ops as ws_ops

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_BENCH_JSON = _ROOT / "BENCH_bootstrap.json"
_BENCH_KMEANS_JSON = _ROOT / "BENCH_kmeans.json"
_BENCH_QUANTILE_JSON = _ROOT / "BENCH_quantile.json"
_BENCH_MULTI_JSON = _ROOT / "BENCH_multi.json"
_BENCH_STREAM_JSON = _ROOT / "BENCH_stream.json"
_BENCH_GROUPED_JSON = _ROOT / "BENCH_grouped.json"
_BENCH_FT_JSON = _ROOT / "BENCH_ft.json"
_BENCH_LIVE_JSON = _ROOT / "BENCH_live.json"
_BENCH_DURABLE_JSON = _ROOT / "BENCH_durable.json"


def _timer(smoke: bool):
    """smoke: execute once (so every dispatch path actually runs), report
    0 — the smoke run is a correctness/dispatch gate, not a perf tool."""
    if smoke:
        def _once(fn):
            jax.block_until_ready(fn())
            return 0.0
        return _once
    return lambda fn: timeit(lambda: jax.block_until_ready(fn()))


def _naive(w, x):
    w_tot = jnp.sum(w, axis=1)
    s1 = w @ x
    s2 = w @ (x * x)
    return w_tot, s1, s2


def run(smoke: bool = False) -> None:
    time = _timer(smoke)
    key = jax.random.PRNGKey(7)
    B, n, d = (8, 512, 3) if smoke else (64, 65_536, 8)
    w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))

    fused = jax.jit(lambda w, x: ws_ops.weighted_moments(w, x,
                                                         backend="jnp"))
    # "naive" = three separate jitted passes over W (models 3 HBM reads of
    # the (B, n) weight matrix; the TPU kernel reads each W tile once)
    n1 = jax.jit(lambda w: jnp.sum(w, axis=1))
    n2 = jax.jit(lambda w, x: w @ x)
    n3 = jax.jit(lambda w, x: w @ (x * x))
    us_f = time(lambda: fused(w, x))
    us_n = time(lambda: (n1(w), n2(w, x), n3(w, x)))
    emit("kernel_weighted_moments_fused", us_f, "")
    emit("kernel_weighted_moments_3pass", us_n,
         f"fused_speedup={us_n / max(us_f, 1e-9):.2f}x;"
         f"w_bytes_read_ratio=3.0")

    # kernel design constants (per EXAMPLE tile): VMEM working set
    bb, bn, bd = 128, 512, 128
    vmem = (bb * bn + bn * bd + 2 * bb * bd + bb) * 4
    intensity = (2 * 2 * bb * bn * bd) / ((bb * bn + bn * bd) * 4)
    emit("kernel_weighted_moments_design", 0.0,
         f"tile_vmem_bytes={vmem};arith_intensity={intensity:.1f}"
         f";mxu_aligned={bb % 128 == 0 and bd % 128 == 0}")

    run_bootstrap(smoke=smoke)
    run_histogram(smoke=smoke)
    run_quantile(smoke=smoke)
    run_kmeans(smoke=smoke)
    run_multi(smoke=smoke)
    run_grouped(smoke=smoke)
    run_stream(smoke=smoke)
    run_ft(smoke=smoke)
    run_live(smoke=smoke)
    run_durable(smoke=smoke)


def _cv(thetas):
    m = jnp.mean(thetas, axis=0)
    return float(jnp.mean(jnp.std(thetas, axis=0) / (jnp.abs(m) + 1e-12)))


def run_bootstrap(smoke: bool = False) -> None:
    """Matrix-free bootstrap: fused-RNG (f32 and bf16-input) vs
    materialized-W vs naive 3-pass.

    The fused-RNG path never builds the (B, n) weight matrix (peak live
    memory O(B·block_n + B·d) on CPU, O(B·d) HBM on TPU); the other two pay
    for both the jax.random.poisson draw of (B, n) and its memory traffic.
    The bf16 variant feeds x/w to the dots in bf16 with f32 accumulators
    (halves X-side HBM/VMEM traffic on TPU) — the emitted cv_rel_err
    quantifies what that costs in bootstrap-accuracy terms.
    """
    time = _timer(smoke)
    B, n, d = (8, 512, 2) if smoke else (256, 1 << 16, 8)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))

    @jax.jit
    def materialized(key, x):
        w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
        return jnp.sum(w, axis=1), w @ x, w @ (x * x)

    wgen = jax.jit(
        lambda key: jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32))
    p1 = jax.jit(lambda w: jnp.sum(w, axis=1))
    p2 = jax.jit(lambda w, x: w @ x)
    p3 = jax.jit(lambda w, x: w @ (x * x))

    def naive():
        w = wgen(key)
        return p1(w), p2(w, x), p3(w, x)

    us_fused = time(lambda: ws_ops.fused_poisson_moments(7, x, B))
    us_bf16 = time(lambda: ws_ops.fused_poisson_moments(
        7, x, B, dtype=jnp.bfloat16))
    us_mat = time(lambda: materialized(key, x))
    us_naive = time(naive)

    # bf16 accuracy study: same implicit weights, different input precision
    # — compare the bootstrap cv of the Mean (the quantity EARL's AES
    # gates on) and the raw moment error.
    wt32, s1_32, s2_32 = ws_ops.fused_poisson_moments(7, x, B)
    wtbf, s1_bf, s2_bf = ws_ops.fused_poisson_moments(7, x, B,
                                                      dtype=jnp.bfloat16)
    cv32 = _cv(s1_32 / wt32[:, None])
    cvbf = _cv(s1_bf / wtbf[:, None])
    cv_rel_err = abs(cvbf - cv32) / max(cv32, 1e-12)
    # scale-normalized moment error (element-wise relative error is
    # meaningless for s1 of zero-mean data, where the true sums sit near 0)
    s1_rel = float(jnp.max(jnp.abs(s1_bf - s1_32))
                   / (jnp.max(jnp.abs(s1_32)) + 1e-9))
    s2_rel = float(jnp.max(jnp.abs(s2_bf - s2_32))
                   / (jnp.max(jnp.abs(s2_32)) + 1e-9))

    speedup_mat = us_mat / max(us_fused, 1e-9)
    speedup_naive = us_naive / max(us_fused, 1e-9)
    emit("bootstrap_fused_rng", us_fused,
         f"B={B};n={n};d={d};weight_matrix_bytes=0")
    emit("bootstrap_fused_rng_bf16", us_bf16,
         f"cv_rel_err={cv_rel_err:.2e};s1_rel_err={s1_rel:.2e};"
         f"s2_rel_err={s2_rel:.2e}")
    emit("bootstrap_materialized_w", us_mat,
         f"fused_speedup={speedup_mat:.2f}x;weight_matrix_bytes={4 * B * n}")
    emit("bootstrap_naive_3pass", us_naive,
         f"fused_speedup={speedup_naive:.2f}x;w_bytes_read_ratio=3.0")

    if smoke:
        return
    _BENCH_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "d": d,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"fused_rng": us_fused,
                        "fused_rng_bf16": us_bf16,
                        "materialized_w": us_mat,
                        "naive_3pass": us_naive},
        "speedup_fused_vs_materialized": speedup_mat,
        "speedup_fused_vs_naive": speedup_naive,
        "bf16_study": {"cv_f32": cv32, "cv_bf16": cvbf,
                       "cv_rel_err": cv_rel_err,
                       "s1_max_rel_err": s1_rel,
                       "s2_max_rel_err": s2_rel,
                       "x_bytes_ratio_vs_f32": 0.5},
        "peak_weight_bytes": {"fused_rng": 0,
                              "materialized_w": 4 * B * n,
                              "naive_3pass": 4 * B * n},
    }, indent=2) + "\n")


def run_quantile(smoke: bool = False) -> None:
    """Matrix-free bootstrap-over-Quantile: fused histogram sketch vs
    materializing the SAME implicit weights and scatter-adding per resample.

    The fused path (kernels/weighted_hist.fused_poisson_hist, scan lowering
    on CPU) generates the Poisson(1) weights in-pass and bins tile-locally
    — neither the (B, n) weight matrix nor any (n, d, nbins) one-hot
    exists; peak live state is the (B, d, nbins) sketch accumulator.
    """
    time = _timer(smoke)
    B, n, nbins = (8, 512, 64) if smoke else (256, 1 << 16, 2048)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (n,)) * 2.0 + 8.0
    q = Quantile(0.5, nbins=nbins, lo=0.0, hi=16.0)

    @jax.jit
    def fused(x):
        return wh_ops.fused_poisson_hist(7, x[:, None], q.lo, q.hi,
                                         nbins, B)

    @jax.jit
    def materialized(x):
        w = ws_ops.implicit_weights(7, B, n)
        st0 = q.init_state(1)
        return jax.vmap(lambda wr: q.update(st0, x, wr).counts)(w)

    us_fused = time(lambda: fused(x))
    us_mat = time(lambda: materialized(x))
    speedup = us_mat / max(us_fused, 1e-9)
    emit("quantile_bootstrap_fused", us_fused,
         f"B={B};n={n};nbins={nbins};weight_matrix_bytes=0")
    emit("quantile_bootstrap_materialized", us_mat,
         f"fused_speedup={speedup:.2f}x;weight_matrix_bytes={4 * B * n}")

    if smoke:
        return
    _BENCH_QUANTILE_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "d": 1, "nbins": nbins,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"fused": us_fused, "materialized": us_mat},
        "speedup_fused_vs_materialized": speedup,
        "peak_intermediate_bytes": {
            "fused": 4 * (B * 512 + B * nbins),   # weight tile + sketch
            "materialized": 4 * B * n,            # implicit weights
        },
    }, indent=2) + "\n")


def run_kmeans(smoke: bool = False) -> None:
    """Bootstrap-over-k-means: fused assignment+accumulate vs materialized.

    The materialized path draws the (B, n) Poisson weight matrix AND builds
    the (B, n, k) weighted one-hot inside the vmapped KMeansStep.update;
    the fused path (kernels/kmeans_assign, scan lowering on CPU) generates
    the weights in-pass and keeps assignment tile-local — peak live state
    O(B·k·d).  A single-state assignment pass is timed too (tiled vs the
    materialized (n, k) distance/one-hot).
    """
    time = _timer(smoke)
    B, n, k, d = (8, 512, 3, 2) if smoke else (64, 1 << 16, 8, 2)
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (n, d))
    cent = jax.random.normal(jax.random.fold_in(key, 1), (k, d)) * 2

    @jax.jit
    def materialized(key, x, cent):
        stat = KMeansStep(cent)
        w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
        st = jax.vmap(lambda wr: stat.update(stat.init_state(d), x, wr))(w)
        return st.sums, st.counts, st.inertia

    us_mat = time(lambda: materialized(key, x, cent))
    us_fused = time(lambda: ka_ops.fused_poisson_kmeans(7, x, cent, B))
    speedup = us_mat / max(us_fused, 1e-9)
    emit("kmeans_bootstrap_fused", us_fused,
         f"B={B};n={n};k={k};d={d};weight_matrix_bytes=0;onehot_bytes=0")
    emit("kmeans_bootstrap_materialized", us_mat,
         f"fused_speedup={speedup:.2f}x;"
         f"weight_matrix_bytes={4 * B * n};onehot_bytes={4 * B * n * k}")

    # single-state assignment pass: tiled scan vs materialized (n, k)
    assign_jnp = jax.jit(
        lambda x, cent: ka_ops.kmeans_assign(x, None, cent, backend="jnp"))
    us_a_jnp = time(lambda: assign_jnp(x, cent))
    us_a_scan = time(lambda: ka_ops.kmeans_assign(x, None, cent,
                                                  backend="scan"))
    emit("kmeans_assign_scan", us_a_scan, f"n={n};k={k};d={d}")
    emit("kmeans_assign_materialized", us_a_jnp,
         f"scan_speedup={us_a_jnp / max(us_a_scan, 1e-9):.2f}x;"
         f"nk_bytes={4 * n * k}")

    if smoke:
        return
    _BENCH_KMEANS_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "k": k, "d": d,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"fused": us_fused,
                        "materialized": us_mat,
                        "assign_scan": us_a_scan,
                        "assign_materialized": us_a_jnp},
        "speedup_fused_vs_materialized": speedup,
        "peak_intermediate_bytes": {
            "fused": 4 * (B * 512 + B * k * d),       # weight tile + states
            "materialized": 4 * B * n * (1 + k),      # weights + one-hot
        },
    }, indent=2) + "\n")


def run_multi(smoke: bool = False) -> None:
    """Single-pass multi-statistic bootstrap (StatisticGroup) vs k
    sequential fused runs of the same statistics.

    The k=3 group (mean + variance + median) pays ONE implicit Poisson(1)
    weight stream and one pass over x — mean and variance additionally
    share one moment accumulator slot — where the sequential baseline
    regenerates an identical-cost threefry stream and re-reads x per
    statistic.  Each sequential statistic is its own jitted dispatch
    (three ``bootstrap`` calls, the pre-group workflow); fusing them into
    one jit would let XLA CSE the duplicate moment pass and misreport the
    baseline.
    """
    time = _timer(smoke)
    B, n, nbins = (8, 512, 64) if smoke else (256, 1 << 16, 2048)
    key = jax.random.PRNGKey(13)
    x2 = (jax.random.normal(key, (n,)) * 2.0 + 8.0)[:, None]
    members = (Mean(), Var(), Quantile(0.5, nbins=nbins, lo=0.0, hi=16.0))
    group = StatisticGroup(members)

    @jax.jit
    def grp(x2):
        return jax.vmap(group.finalize)(
            fused_resample_states(group, 7, x2, B))

    seqs = [jax.jit(lambda x2, m=m: jax.vmap(m.finalize)(
        fused_resample_states(m, 7, x2, B))) for m in members]

    if smoke:
        us_grp = time(lambda: grp(x2))
        us_seq = time(lambda: [f(x2) for f in seqs])
        speedup = us_seq / max(us_grp, 1e-9)
    else:
        # this ratio is an acceptance gate and the container's background
        # load drifts on the timescale of a single run — interleave the
        # two measurements and gate on the median of PER-PAIR ratios, so
        # a load spike hits both sides of each pair instead of one.
        import time as _time
        jax.block_until_ready(grp(x2))
        [jax.block_until_ready(f(x2)) for f in seqs]
        tg, ts = [], []
        for _ in range(7):
            t0 = _time.perf_counter()
            jax.block_until_ready(grp(x2))
            tg.append(_time.perf_counter() - t0)
            t0 = _time.perf_counter()
            [jax.block_until_ready(f(x2)) for f in seqs]
            ts.append(_time.perf_counter() - t0)
        ratios = sorted(b / a for a, b in zip(tg, ts))
        speedup = ratios[len(ratios) // 2]
        us_grp = sorted(tg)[len(tg) // 2] * 1e6
        us_seq = sorted(ts)[len(ts) // 2] * 1e6
    emit("multi_bootstrap_group", us_grp,
         f"B={B};n={n};k={len(members)};slots={len(group.slots)};"
         f"nbins={nbins};weight_streams=1")
    emit("multi_bootstrap_sequential", us_seq,
         f"group_speedup={speedup:.2f}x;weight_streams={len(members)}")

    # shared weights => member thetas identical to their dedicated fused
    # runs (joint CIs); record the invariant alongside the timing.
    tg = grp(x2)
    same = all(bool(jnp.array_equal(tg[i], f(x2)))
               for i, f in enumerate(seqs))
    emit("multi_bootstrap_shared_weights", 0.0, f"member_bitwise={same}")

    if smoke:
        # exercise the Pallas multi-kernel dispatch (interpret mode on CPU)
        jax.block_until_ready(jax.tree_util.tree_leaves(
            fm_ops.fused_poisson_multi(group, 7, x2, B,
                                       backend="pallas_interpret"))[0])
        return
    _BENCH_MULTI_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "k": len(members),
                   "slots": len(group.slots), "nbins": nbins,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"group": us_grp, "sequential": us_seq},
        "speedup_group_vs_sequential": speedup,
        "member_thetas_bitwise_equal_to_sequential": same,
        "weight_streams": {"group": 1, "sequential": len(members)},
    }, indent=2) + "\n")


def run_grouped(smoke: bool = False) -> None:
    """GROUP BY bootstrap (GroupedStatistic) vs G sequential per-key runs.

    The grouped path pays ONE implicit Poisson(1) weight stream and one
    pass over x, routing each weight tile into G per-key accumulator
    slots by exact 0/1 key masks; the sequential baseline reruns the
    fused kernel per key with ``valid_mask = (key == g)`` — G threefry
    streams of identical cost and G passes over x.  Each per-key run is
    its own jitted dispatch (the pre-GROUP-BY workflow); the PRNG + data
    pass dominate on CPU, so grouped should approach G×/(1 + small
    per-key dot overhead) — the regression gate floors the ratio at 2×
    for G=8 means.
    """
    time = _timer(smoke)
    from repro.core.reduce_api import GroupedStatistic
    B, n, d, G = (8, 512, 2, 4) if smoke else (256, 1 << 16, 4, 8)
    key = jax.random.PRNGKey(19)
    x = jax.random.normal(key, (n, d))
    gid = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, G)
    vals = jnp.concatenate([x, gid[:, None].astype(jnp.float32)], axis=1)
    stat = GroupedStatistic(Mean(), G)
    inner = Mean()

    @jax.jit
    def grouped(vals):
        return jax.vmap(stat.finalize)(
            fused_resample_states(stat, 7, vals, B))

    seqs = [jax.jit(lambda x, g=g: jax.vmap(inner.finalize)(
        fused_resample_states(inner, 7, x, B,
                              valid_mask=(gid == g).astype(jnp.float32))))
        for g in range(G)]

    if smoke:
        us_grp = time(lambda: grouped(vals))
        us_seq = time(lambda: [f(x) for f in seqs])
        speedup = us_seq / max(us_grp, 1e-9)
    else:
        # interleaved paired-ratio discipline (see run_multi): the ratio
        # is an acceptance gate, so each rep times both sides back to
        # back and the gate takes the median of per-pair ratios.
        import time as _time
        jax.block_until_ready(grouped(vals))
        [jax.block_until_ready(f(x)) for f in seqs]
        tg, ts = [], []
        for _ in range(7):
            t0 = _time.perf_counter()
            jax.block_until_ready(grouped(vals))
            tg.append(_time.perf_counter() - t0)
            t0 = _time.perf_counter()
            [jax.block_until_ready(f(x)) for f in seqs]
            ts.append(_time.perf_counter() - t0)
        ratios = sorted(b / a for a, b in zip(tg, ts))
        speedup = ratios[len(ratios) // 2]
        us_grp = sorted(tg)[len(tg) // 2] * 1e6
        us_seq = sorted(ts)[len(ts) // 2] * 1e6
    emit("grouped_bootstrap", us_grp,
         f"B={B};n={n};d={d};G={G};weight_streams=1")
    emit("grouped_bootstrap_sequential", us_seq,
         f"grouped_speedup={speedup:.2f}x;weight_streams={G}")

    # common random numbers => key g's thetas identical to the dedicated
    # per-key fused run under valid_mask=(key==g); record the invariant.
    tgv = grouped(vals)
    same = all(bool(jnp.array_equal(tgv[:, g], f(x)))
               for g, f in enumerate(seqs))
    emit("grouped_bootstrap_per_key", 0.0, f"per_key_bitwise={same}")

    if smoke:
        # exercise the grouped Pallas moments kernel (interpret on CPU)
        jax.block_until_ready(ws_ops.fused_poisson_moments(
            7, x, B, backend="pallas_interpret", group_ids=gid,
            num_groups=G)[0])
        return
    _BENCH_GROUPED_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "d": d, "G": G,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"grouped": us_grp, "sequential": us_seq},
        "speedup_grouped_vs_sequential": speedup,
        "per_key_thetas_bitwise_equal_to_sequential": same,
        "weight_streams": {"grouped": 1, "sequential": G},
    }, indent=2) + "\n")


def run_stream(smoke: bool = False) -> None:
    """Double-buffered streaming bootstrap over a ShardedStore vs the
    non-overlapped serial transfer+compute pipeline.

    The serial baseline is what the pre-streaming API required: transfer
    EVERYTHING (``read_all`` concat → full f32 decode → one big
    ``device_put``), *then* compute (warm jitted fused chunk scan — jitted
    so the baseline pays transfer+compute, not Python retracing).  The
    streamed path interleaves chunk-sized staging with compute through the
    prefetch queue, so staging stays cache-resident and nothing of size n
    is ever materialized on host or device.

    On this 1-CPU container stage and compute timeshare one core, so the
    win measured here is the avoided full-size materialization passes
    (concat + whole-array decode + whole-array device_put + on-device
    pad/reshape), not thread-level overlap; ``overlap_efficiency``
    (stream wall / max(serial transfer, serial compute)) still reports
    how close the pipeline runs to the ideal-overlap bound — on TPU the
    same driver overlaps host decode with device compute for real.

    The store holds float64 rows so staging pays a per-chunk decode (the
    record-decode cost a real on-disk store has).  Streamed thetas must be
    BITWISE equal to ``bootstrap_chunked`` over ``read_all()`` under the
    same (key, chunk) — recorded as an invariant next to the timing.
    """
    import time as _time

    import numpy as np

    from repro.core.bootstrap import (bootstrap_chunked, offset_seed,
                                      seed_from_key)
    from repro.core.streaming import bootstrap_streaming
    from repro.data.store import ShardedStore

    B, chunk, nchunks, d = (4, 256, 3, 8) if smoke else (8, 8192, 48, 64)
    n = nchunks * chunk - chunk // 2            # ragged tail
    rng = np.random.default_rng(11)
    store = ShardedStore.from_array(rng.normal(size=(n, d)),
                                    split_size=chunk, interleave=False)
    key = jax.random.PRNGKey(17)
    stat = Mean()
    base_seed = seed_from_key(key)

    @jax.jit
    def _chunked_states(xd):
        nn, dim = xd.shape
        xp = jnp.pad(xd, ((0, (-nn) % chunk), (0, 0)))
        xc = xp.reshape(-1, chunk, dim)
        init = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))

        def body(carry, inp):
            states, est = carry
            i, xi = inp
            n_valid = jnp.minimum(chunk, nn - i * chunk)
            vi = (jnp.arange(chunk) < n_valid).astype(jnp.float32)
            est = stat.update(est, xi, vi)
            delta = fused_resample_states(stat, offset_seed(base_seed, i),
                                          xi, B, n_valid=n_valid)
            return (jax.vmap(stat.merge)(states, delta), est), None

        return jax.lax.scan(body, (init, stat.init_state(dim)),
                            (jnp.arange(xc.shape[0]), xc))[0]

    def serial():
        t0 = _time.perf_counter()
        xh = np.ascontiguousarray(store.read_all(), np.float32)
        xd = jax.block_until_ready(jax.device_put(xh))
        t1 = _time.perf_counter()
        out = jax.block_until_ready(_chunked_states(xd))
        t2 = _time.perf_counter()
        return out, t1 - t0, t2 - t1

    # warm both sides (compile; first store pass)
    rs = bootstrap_streaming(store, stat, B, key, chunk=chunk)
    serial()

    # streamed thetas == bootstrap_chunked(read_all()) bit for bit: the
    # streaming driver is a transport change, not an estimator change.
    rc = bootstrap_chunked(jnp.asarray(store.read_all(), jnp.float32),
                           stat, B=B, key=key, chunk=chunk,
                           backend="fused_rng")
    bits = bool(np.array_equal(np.asarray(rs.thetas), np.asarray(rc.thetas))
                and np.array_equal(np.asarray(rs.estimate),
                                   np.asarray(rc.estimate)))

    if smoke:
        emit("stream_bootstrap", 0.0,
             f"B={B};chunk={chunk};nchunks={nchunks};d={d}")
        emit("stream_bitwise", 0.0,
             f"thetas_bitwise_equal_to_chunked={bits}")
        return

    # same interleaved paired-ratio discipline as run_multi: the speedup
    # is an acceptance gate, so each rep times both pipelines back to
    # back and the gate takes the median of per-pair ratios.
    t_stream, t_serial, t_xfer, t_comp = [], [], [], []
    for _ in range(7):
        t0 = _time.perf_counter()
        rs = bootstrap_streaming(store, stat, B, key, chunk=chunk)
        t_stream.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        _, xfer, comp = serial()
        t_serial.append(_time.perf_counter() - t0)
        t_xfer.append(xfer)
        t_comp.append(comp)

    ratios = sorted(b / a for a, b in zip(t_stream, t_serial))
    speedup = ratios[len(ratios) // 2]
    med = lambda ts: sorted(ts)[len(ts) // 2]  # noqa: E731
    us_stream = med(t_stream) * 1e6
    us_serial = med(t_serial) * 1e6
    us_xfer = med(t_xfer) * 1e6
    us_comp = med(t_comp) * 1e6
    overlap_eff = us_stream / max(us_xfer, us_comp, 1e-9)

    emit("stream_bootstrap", us_stream,
         f"B={B};chunk={chunk};nchunks={nchunks};d={d};queue_depth=2;"
         f"stage_us={rs.stream.stage_s * 1e6:.0f};"
         f"wait_us={rs.stream.wait_s * 1e6:.0f};"
         f"dispatch_us={rs.stream.dispatch_s * 1e6:.0f}")
    emit("stream_serial_baseline", us_serial,
         f"stream_speedup={speedup:.2f}x;transfer_us={us_xfer:.0f};"
         f"compute_us={us_comp:.0f};overlap_eff={overlap_eff:.2f}")
    emit("stream_bitwise", 0.0,
         f"thetas_bitwise_equal_to_chunked={bits}")

    _BENCH_STREAM_JSON.write_text(json.dumps({
        "config": {"B": B, "chunk": chunk, "nchunks": nchunks, "d": d,
                   "rows": n, "store_dtype": "float64",
                   "queue_depth": 2,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"stream": us_stream, "serial": us_serial,
                        "serial_transfer": us_xfer,
                        "serial_compute": us_comp},
        "speedup_stream_vs_serial": speedup,
        "overlap_efficiency": overlap_eff,
        "thetas_bitwise_equal_to_chunked": bits,
        "stream_report": {"stage_s": rs.stream.stage_s,
                          "wait_s": rs.stream.wait_s,
                          "dispatch_s": rs.stream.dispatch_s,
                          "n_chunks": rs.stream.n_chunks,
                          "rows": rs.stream.rows},
    }, indent=2) + "\n")


def run_ft(smoke: bool = False) -> None:
    """Crash-safety tax and recovery speed for the streaming bootstrap.

    Three questions, each gated or recorded in BENCH_ft.json:

    * What does checkpointing COST?  A streamed run snapshotting its
      donated carry every 8 chunks vs the plain run — same interleaved
      paired-ratio discipline as run_stream (the ratio is an acceptance
      gate: ``checkpoint_overhead_ratio`` must stay <= 1.10).  The carry
      is O(B·d) states, so the tax is device_get + an async npz write
      every 8 chunks, amortized over 8 chunks of compute.
    * How fast is RECOVERY?  Kill the run at the midpoint checkpoint,
      resume, and time the resumed half-run; the resumed result must be
      BITWISE equal to the uninterrupted run (the ``resumed_bitwise_equal``
      invariant), and the resumed pass re-reads only the unconsumed rows.
    * Does a FAULTY run finish hands-off?  Injected transient IOError +
      one permanently dead split under a degrade policy: the run must
      complete with the loss surfaced in its StreamReport.
    """
    import shutil
    import tempfile
    import time as _time

    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.core.streaming import bootstrap_streaming
    from repro.data.store import ShardedStore
    from repro.ft import (FailurePolicy, Fault, FaultyStore, RetryPolicy)

    B, chunk, nchunks, d = (4, 256, 3, 8) if smoke else (8, 8192, 48, 64)
    every = 1 if smoke else 8
    n = nchunks * chunk - chunk // 2            # ragged tail
    rng = np.random.default_rng(23)
    store = ShardedStore.from_array(rng.normal(size=(n, d)),
                                    split_size=chunk, interleave=False)
    key = jax.random.PRNGKey(29)
    stat = Mean()
    root = tempfile.mkdtemp(prefix="earl_bench_ft_")

    class _Die(Exception):
        pass

    class _DyingManager(CheckpointManager):
        def __init__(self, r, die_after, **kw):
            super().__init__(r, **kw)
            self.die_after, self.saves = die_after, 0

        def save(self, *a, **kw):
            super().save(*a, **kw)
            self.saves += 1
            if self.saves >= self.die_after:
                raise _Die()

    def plain():
        return bootstrap_streaming(store, stat, B, key, chunk=chunk)

    def checkpointed(tag):
        # fresh root per rep: every rep pays real (not overwritten-warm)
        # directory creation and npz writes
        return bootstrap_streaming(store, stat, B, key, chunk=chunk,
                                   checkpoint=f"{root}/rep_{tag}",
                                   checkpoint_every=every)

    base = plain()                               # warm both pipelines
    rc = checkpointed("warm")
    bits_ckpt = bool(
        np.array_equal(np.asarray(base.thetas), np.asarray(rc.thetas))
        and np.array_equal(np.asarray(base.estimate),
                           np.asarray(rc.estimate)))

    # -- kill at the midpoint checkpoint, resume, time the recovery ------
    kill_at = max(1, nchunks // 2)
    rroot = f"{root}/resume"
    try:
        bootstrap_streaming(store, stat, B, key, chunk=chunk,
                            checkpoint=_DyingManager(rroot, kill_at,
                                                     async_save=False),
                            checkpoint_every=1)
        raise RuntimeError("dying manager did not die")
    except _Die:
        pass
    store.stats.reset()
    t0 = _time.perf_counter()
    rres = bootstrap_streaming(
        store, stat, B, key, chunk=chunk, resume=True,
        checkpoint=CheckpointManager(rroot, async_save=False))
    resume_s = _time.perf_counter() - t0
    rows_reread = int(store.stats.rows_read)
    bits_resume = bool(
        np.array_equal(np.asarray(base.thetas), np.asarray(rres.thetas))
        and np.array_equal(np.asarray(base.estimate),
                           np.asarray(rres.estimate)))

    # -- injected faults: the run must finish without manual intervention
    fstore = FaultyStore(store, [Fault(split=1, kind="io", attempts=1),
                                 Fault(split=2, kind="io", permanent=True)])
    rdeg = bootstrap_streaming(
        fstore, stat, B, key, chunk=chunk,
        policy=FailurePolicy(retry=RetryPolicy(max_attempts=2,
                                               base_delay=0.0),
                             on_exhausted="degrade"))
    degraded_ok = (rdeg.stream.lost_splits == (2,)
                   and rdeg.stream.faults.io_errors == 3
                   and rdeg.stream.faults.splits_lost == 1)

    if smoke:
        emit("ft_checkpoint_stream", 0.0,
             f"B={B};chunk={chunk};nchunks={nchunks};every={every}")
        emit("ft_resume_bitwise", 0.0,
             f"resumed_bitwise_equal={bits_resume};"
             f"checkpointed_bitwise_equal={bits_ckpt};"
             f"degraded_run_completed={degraded_ok}")
        shutil.rmtree(root, ignore_errors=True)
        return

    # interleaved paired-ratio discipline (see run_multi): the overhead
    # ratio is an acceptance gate (<= 1.10), so each rep times plain and
    # checkpointed back to back and the gate takes the median per-pair.
    t_plain, t_ckpt = [], []
    for i in range(7):
        t0 = _time.perf_counter()
        plain()
        t_plain.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        checkpointed(i)
        t_ckpt.append(_time.perf_counter() - t0)
    ratios = sorted(c / p for p, c in zip(t_plain, t_ckpt))
    overhead = ratios[len(ratios) // 2]
    med = lambda ts: sorted(ts)[len(ts) // 2]  # noqa: E731
    us_plain = med(t_plain) * 1e6
    us_ckpt = med(t_ckpt) * 1e6

    emit("ft_stream_plain", us_plain,
         f"B={B};chunk={chunk};nchunks={nchunks};d={d}")
    emit("ft_stream_checkpointed", us_ckpt,
         f"checkpoint_overhead={overhead:.3f}x;every={every};"
         f"n_checkpoints={rc.stream.n_checkpoints};"
         f"checkpoint_us={rc.stream.checkpoint_s * 1e6:.0f}")
    emit("ft_resume", resume_s * 1e6,
         f"killed_at_chunk={kill_at};rows_reread={rows_reread};"
         f"recovery_vs_full={resume_s / max(med(t_plain), 1e-9):.2f}x;"
         f"resumed_bitwise_equal={bits_resume}")
    emit("ft_degraded", 0.0,
         f"lost_splits={rdeg.stream.lost_splits};"
         f"io_errors={rdeg.stream.faults.io_errors};"
         f"completed={degraded_ok}")

    _BENCH_FT_JSON.write_text(json.dumps({
        "config": {"B": B, "chunk": chunk, "nchunks": nchunks, "d": d,
                   "rows": n, "checkpoint_every": every,
                   "backend": jax.default_backend()},
        "us_per_call": {"stream_plain": us_plain,
                        "stream_checkpointed": us_ckpt,
                        "resume_half_run": resume_s * 1e6},
        "checkpoint_overhead_ratio": overhead,
        "n_checkpoints": rc.stream.n_checkpoints,
        "checkpoint_s": rc.stream.checkpoint_s,
        "checkpointed_bitwise_equal": bits_ckpt,
        "resume_recovery": {"killed_at_chunk": kill_at,
                            "total_chunks": nchunks,
                            "rows_reread": rows_reread,
                            "rows_total": n,
                            "recovery_vs_full_ratio":
                                resume_s / max(med(t_plain), 1e-9)},
        "resumed_bitwise_equal": bits_resume,
        "degraded_run_completed": degraded_ok,
        "degraded_faults": {"io_errors": rdeg.stream.faults.io_errors,
                            "retries": rdeg.stream.faults.retries,
                            "splits_lost":
                                rdeg.stream.faults.splits_lost,
                            "lost_splits": list(rdeg.stream.lost_splits)},
    }, indent=2) + "\n")
    shutil.rmtree(root, ignore_errors=True)


def run_live(smoke: bool = False) -> None:
    """Live ingest: sustained fold throughput, lag recovery, shedding.

    Three questions, each recorded or gated in BENCH_live.json:

    * How fast does a standing ``LiveSession`` DRAIN?  Appends land in an
      ``IngestLog`` and a sliding-window session folds + re-emits a
      report per batch — sustained batches/sec (and rows/sec) over a
      pre-filled backlog is the headline, gated by an absolute floor.
    * How fast does it RECOVER from lag?  Stall the consumer while a
      burst accumulates, then measure the time to drain the burst back
      to a clean watermark — reported relative to the steady-state
      per-batch cost.
    * What does SHEDDING cost/buy?  The same burst drained under a
      ``LagPolicy.shed_backlog`` policy: shed fraction, p_eff, and the
      two bitwise invariants (kill/resume mid-stream equals the
      uninterrupted run; the shed fold equals a dedicated valid_mask
      oracle fold) that make degradation trustworthy.
    """
    import shutil
    import tempfile
    import time as _time

    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.core.bootstrap import seed_from_key, offset_seed
    from repro.core.reduce_api import SlidingWindow
    from repro.ft.policy import LagPolicy
    from repro.live import IngestLog, LiveSession

    B, rows, nbatch, d = (4, 64, 6, 4) if smoke else (16, 2048, 64, 8)
    win = SlidingWindow(Var(), 4 * rows, rows)   # 4-pane ring, 1 batch/pane
    key = jax.random.PRNGKey(31)
    rng = np.random.default_rng(41)
    batches = [rng.normal(size=(rows, d)).astype(np.float32)
               for _ in range(nbatch)]
    root = tempfile.mkdtemp(prefix="earl_bench_live_")

    def fill_log():
        log = IngestLog()
        for b in batches:
            log.append(b)
        return log

    # -- sustained drain throughput --------------------------------------
    log = fill_log()
    sess = LiveSession(log, win, B=B, key=key)    # warm the fold jit
    sess.poll()
    reps = 1 if smoke else 5
    times = []
    for _ in range(reps):
        log = fill_log()
        s = LiveSession(log, win, B=B, key=key)
        t0 = _time.perf_counter()
        out = s.poll()
        times.append(_time.perf_counter() - t0)
        assert len(out) == nbatch
    drain_s = sorted(times)[len(times) // 2]
    batches_per_sec = nbatch / drain_s
    us_batch = drain_s / nbatch * 1e6
    emit("live_drain", us_batch,
         f"batches_per_sec={batches_per_sec:.1f};"
         f"rows_per_sec={batches_per_sec * rows:.0f};"
         f"B={B};rows={rows};nbatch={nbatch};panes={win.panes}")

    # -- lag recovery: drain a standing burst back to a clean watermark --
    log = IngestLog()
    s = LiveSession(log, win, B=B, key=key)
    for b in batches[:2]:
        log.append(b)
    s.poll()                                      # steady state...
    burst = 4 if smoke else 16
    for b in batches[2:2 + burst]:
        log.append(b)                             # ...consumer stalled
    t0 = _time.perf_counter()
    out = s.poll()
    recovery_s = _time.perf_counter() - t0
    assert len(out) == burst and s.watermark_seq == 1 + burst
    emit("live_lag_recovery", recovery_s * 1e6,
         f"burst={burst};"
         f"recovery_vs_steady={recovery_s / max(us_batch * 1e-6, 1e-12) / burst:.2f}x")

    # -- shedding under backlog + the two bitwise invariants -------------
    policy = LagPolicy(max_lag_batches=4 * burst, shed_backlog=2,
                       p_shed=0.5, shed_seed=77)
    log = fill_log()
    shed_sess = LiveSession(log, win, B=B, key=key, policy=policy)
    shed_sess.poll()
    shed_rep = shed_sess.report()
    shed_fraction = (shed_sess.counters.shed_rows
                     / max(shed_sess.counters.folded * rows, 1))

    # oracle: re-fold the final window's batches by hand with the same
    # seeded masks handed to the kernels as a dedicated valid_mask
    stat = win.stat
    base_seed = seed_from_key(key)
    states = jax.vmap(lambda _: stat.init_state(d))(jnp.arange(B))
    est = stat.init_state(d)
    o_rows = o_valid = 0
    shed_upto = nbatch - 1 - policy.shed_backlog  # lag at fold of seq q
    for sq in range(nbatch - win.panes, nbatch):
        xb = batches[sq]
        if sq < shed_upto:
            r2 = np.random.default_rng((77, sq))
            m = (r2.random(rows) < policy.p_shed).astype(np.float32)
        else:
            m = np.ones(rows, np.float32)
        est = stat.update(est, xb, m)
        delta = fused_resample_states(
            stat, offset_seed(base_seed, jnp.asarray(sq, jnp.int32)),
            xb, B, valid_mask=m)
        states = jax.vmap(stat.merge)(states, delta)
        o_rows += rows
        o_valid += int(m.sum())
    p_eff = o_valid / o_rows
    o_thetas = stat.correct(jax.vmap(stat.finalize)(states), p_eff)
    o_est = stat.correct(stat.finalize(est), p_eff)
    shed_bitwise = bool(
        np.array_equal(np.asarray(shed_rep.thetas), np.asarray(o_thetas))
        and np.array_equal(np.asarray(shed_rep.estimate),
                           np.asarray(o_est))
        and shed_rep.p_eff == p_eff)

    # kill mid-stream (after the nbatch//2-th fold), resume, compare bits
    clean_log = fill_log()
    clean = LiveSession(clean_log, win, B=B, key=key)
    clean.poll()
    clean_rep = clean.report()

    class _Die(Exception):
        pass

    class _DyingManager(CheckpointManager):
        def __init__(self, r, die_after, **kw):
            kw.setdefault("async_save", False)
            super().__init__(r, **kw)
            self.die_after, self.saves = die_after, 0

        def save(self, *a, **kw):
            super().save(*a, **kw)
            self.saves += 1
            if self.saves >= self.die_after:
                raise _Die()

    log = fill_log()
    rroot = f"{root}/resume"
    try:
        LiveSession(log, win, B=B, key=key,
                    checkpoint=_DyingManager(rroot, max(1, nbatch // 2)),
                    checkpoint_every=1).poll()
        raise RuntimeError("dying manager did not die")
    except _Die:
        pass
    rs = LiveSession(log, win, B=B, key=key, resume=True,
                     checkpoint=CheckpointManager(rroot, async_save=False))
    rs.poll()
    rres = rs.report()
    resumed_bitwise = bool(
        np.array_equal(np.asarray(clean_rep.thetas),
                       np.asarray(rres.thetas))
        and np.array_equal(np.asarray(clean_rep.estimate),
                           np.asarray(rres.estimate)))
    ring_bounded = (rs.panes_live <= rs.memory_bound
                    and shed_sess.panes_live <= shed_sess.memory_bound)
    dedup_exact = (rs.counters.folded == nbatch
                   and clean.counters.folded == nbatch)

    emit("live_shed", 0.0,
         f"shed_fraction={shed_fraction:.3f};p_eff={shed_rep.p_eff:.3f};"
         f"shed_bitwise_equal_to_oracle={shed_bitwise};"
         f"resumed_bitwise_equal={resumed_bitwise}")

    if smoke:
        shutil.rmtree(root, ignore_errors=True)
        return

    _BENCH_LIVE_JSON.write_text(json.dumps({
        "config": {"B": B, "rows_per_batch": rows, "nbatch": nbatch,
                   "d": d, "window_size": win.size, "window_slide":
                   win.slide, "panes": win.panes,
                   "backend": jax.default_backend()},
        "us_per_batch": us_batch,
        "batches_per_sec": batches_per_sec,
        "rows_per_sec": batches_per_sec * rows,
        "lag_recovery": {"burst_batches": burst,
                         "recovery_s": recovery_s,
                         "per_batch_vs_steady_ratio":
                             recovery_s / burst / max(drain_s / nbatch,
                                                      1e-12)},
        "shedding": {"shed_fraction": shed_fraction,
                     "p_eff": shed_rep.p_eff,
                     "shed_batches": shed_sess.counters.shed_batches,
                     "shed_rows": shed_sess.counters.shed_rows},
        "shed_bitwise_equal_to_oracle": shed_bitwise,
        "resumed_bitwise_equal": resumed_bitwise,
        "pane_ring_bounded": ring_bounded,
        "dedup_exactly_once": dedup_exact,
    }, indent=2) + "\n")
    shutil.rmtree(root, ignore_errors=True)


def run_durable(smoke: bool = False) -> None:
    """Durable segment log: append tax per fsync policy, recovery scan
    speed, and the two recovery invariants (BENCH_durable.json).

    The pipeline under test is a realistic ingest producer: per batch it
    GENERATES the rows (the upstream cost every real producer pays),
    assembling each batch from smaller arrival chunks the way a real
    receiver drains a socket, and appends it to the log; the rep ends at
    the durability barrier (``flush``).  The in-memory ``IngestLog``
    runs the identical loop — generation included — so the ratio is the
    durability tax of the whole pipeline, not of a bare ``write()``
    against a bare memcpy.  ``fsync=batch`` (the default) is the
    acceptance gate: <= 1.5x the in-memory pipeline, which the
    write-behind writer earns by interleaving segment writes with
    generation while the sync thread's group ``fdatasync``s — device
    I/O, no GIL — overlap both.

    Recovery is timed as a cold scan of the sealed log (CRC-validating
    every record) and extrapolated to seconds per GB; the invariants
    assert the scan is not just fast but RIGHT: the recovered store is
    bitwise equal to the in-memory log fed the same batches, and a torn
    tail write is truncated to the surviving prefix.
    """
    import os
    import shutil
    import tempfile
    import time as _time

    import numpy as np

    from repro.ft import torn_write
    from repro.live import DurableIngestLog, IngestLog
    from repro.live import segment as _segment

    rows, d, nbatch = (64, 4, 4) if smoke else (131072, 4, 32)
    reps = 1 if smoke else 7
    root = tempfile.mkdtemp(prefix="earl_bench_durable_")

    def gen_batches(seed):
        rng = np.random.default_rng(seed)
        chunk = min(rows, 8192)       # arrival granularity (see docstring)
        return lambda: np.concatenate(
            [rng.standard_normal((chunk, d)).astype(np.float32)
             for _ in range(rows // chunk)])

    def mem_pipeline(seed):
        nxt = gen_batches(seed)
        log = IngestLog()
        for _ in range(nbatch):
            log.append(nxt())
        log.flush()
        return log

    def durable_pipeline(seed, tag, fsync):
        nxt = gen_batches(seed)
        with DurableIngestLog(f"{root}/{tag}", fsync=fsync) as log:
            for _ in range(nbatch):
                log.append(nxt())
            log.flush()
        return log

    # warm both pipelines (allocator, fs metadata, writer-thread startup)
    mem_pipeline(0)
    durable_pipeline(0, "warm", "batch")
    shutil.rmtree(f"{root}/warm", ignore_errors=True)

    # interleaved paired-ratio discipline (see run_multi): the batch-mode
    # tax is an acceptance gate, so each rep times the in-memory and the
    # durable pipeline back to back and the gate takes the median of
    # per-pair ratios.  Fresh directory per rep: every rep pays real
    # segment creation, not overwrite-warm inode reuse.
    taxes, t_mems = {f: [] for f in ("never", "batch", "always")}, []
    for i in range(reps):
        t0 = _time.perf_counter()
        mem_pipeline(i)
        t_mem = _time.perf_counter() - t0
        t_mems.append(t_mem)
        for fsync in taxes:
            tag = f"rep{i}_{fsync}"
            t0 = _time.perf_counter()
            durable_pipeline(i, tag, fsync)
            taxes[fsync].append((_time.perf_counter() - t0) / t_mem)
            # drop this rep's segments before the next timing: letting
            # runs accumulate dirty pages makes later reps pay earlier
            # reps' writeback
            shutil.rmtree(f"{root}/{tag}", ignore_errors=True)
    med = lambda ts: sorted(ts)[len(ts) // 2]  # noqa: E731
    tax = {f: med(taxes[f]) for f in taxes}
    us_mem = med(t_mems) * 1e6
    batch_bytes = rows * d * 4
    mb_s = nbatch * batch_bytes / (med(t_mems) * tax["batch"]) / 1e6

    emit("durable_append_mem_baseline", us_mem,
         f"rows={rows};d={d};nbatch={nbatch};batch_bytes={batch_bytes}")
    for fsync in ("never", "batch", "always"):
        emit(f"durable_append_fsync_{fsync}", us_mem * tax[fsync],
             f"tax={tax[fsync]:.3f}x;mb_per_sec="
             f"{nbatch * batch_bytes / (med(t_mems) * tax[fsync]) / 1e6:.0f}")

    # -- recovery: cold CRC-validating scan, and the invariants ----------
    seed = 101
    oracle = mem_pipeline(seed)
    rroot = f"{root}/recovery"
    durable_pipeline(seed, "recovery", "batch")
    log_bytes = sum(
        os.path.getsize(os.path.join(rroot, _segment.segment_name(i)))
        for i in range(nbatch))
    t_scan = []
    for _ in range(reps):
        t0 = _time.perf_counter()
        rec = DurableIngestLog(rroot)
        t_scan.append(_time.perf_counter() - t0)
        rec.close()
    scan_s = med(t_scan)
    scan_s_per_gb = scan_s / log_bytes * 1e9

    rec = DurableIngestLog(rroot)
    recovery_bitwise = (
        rec.recovery.batches == nbatch
        and all(np.array_equal(np.asarray(rec.store.splits[i]),
                               np.asarray(oracle.store.splits[i]))
                and (rec.store.split_checksum(i)
                     == oracle.store.split_checksum(i))
                for i in range(nbatch)))
    rec.close()

    torn_write(os.path.join(rroot, _segment.segment_name(nbatch - 1)),
               keep_bytes=_segment.HEADER_SIZE + 10)
    rec = DurableIngestLog(rroot)
    torn_ok = (
        rec.recovery.batches == nbatch - 1
        and rec.counters.short_reads == 1
        and all(np.array_equal(np.asarray(rec.store.splits[i]),
                               np.asarray(oracle.store.splits[i]))
                for i in range(nbatch - 1)))
    rec.close()

    emit("durable_recovery_scan", scan_s * 1e6,
         f"log_bytes={log_bytes};s_per_gb={scan_s_per_gb:.2f};"
         f"recovery_bitwise_equal={recovery_bitwise};"
         f"torn_recovery_ok={torn_ok}")

    if smoke:
        shutil.rmtree(root, ignore_errors=True)
        return
    _BENCH_DURABLE_JSON.write_text(json.dumps({
        "config": {"rows_per_batch": rows, "d": d, "nbatch": nbatch,
                   "batch_bytes": batch_bytes, "reps": reps,
                   "backend": jax.default_backend()},
        "us_per_pipeline": {
            "mem": us_mem,
            "fsync_never": us_mem * tax["never"],
            "fsync_batch": us_mem * tax["batch"],
            "fsync_always": us_mem * tax["always"]},
        "fsync_tax_never": tax["never"],
        "fsync_tax_batch": tax["batch"],
        "fsync_tax_always": tax["always"],
        "append_mb_per_sec_batch": mb_s,
        "recovery": {"log_bytes": log_bytes, "scan_s": scan_s,
                     "scan_s_per_gb": scan_s_per_gb},
        "recovery_bitwise_equal": recovery_bitwise,
        "torn_recovery_ok": torn_ok,
    }, indent=2) + "\n")
    shutil.rmtree(root, ignore_errors=True)


def run_histogram(smoke: bool = False) -> None:
    """Quantile sketch update: flattened scatter-add vs one_hot+einsum
    (the old (n, d, nbins) memory blowup)."""
    time = _timer(smoke)
    n, d, nbins = (512, 2, 64) if smoke else (1 << 16, 4, 2048)
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (n, d))
    w = jnp.ones((n,))
    lo, hi = jnp.zeros((d,)), jnp.ones((d,))

    scatter = jax.jit(lambda x, w: wh_ops.weighted_histogram(
        x, w, lo, hi, nbins, backend="jnp"))

    @jax.jit
    def onehot(x, w):
        idx = jnp.clip((x * nbins).astype(jnp.int32), 0, nbins - 1)
        oh = jax.nn.one_hot(idx, nbins, dtype=jnp.float32)
        return jnp.einsum("n,ndb->db", w, oh)

    us_s = time(lambda: scatter(x, w))
    us_o = time(lambda: onehot(x, w))
    emit("hist_scatter_add", us_s,
         f"n={n};d={d};nbins={nbins};peak_bytes={4 * n * d}")
    emit("hist_onehot_einsum", us_o,
         f"scatter_speedup={us_o / max(us_s, 1e-9):.2f}x"
         f";peak_bytes={4 * n * d * nbins}")
    if smoke:
        # smoke also exercises the Pallas interpret dispatch of the sketch
        jax.block_until_ready(wh_ops.weighted_histogram(
            x, w, lo, hi, nbins, backend="pallas_interpret"))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no timing, no BENCH_*.json writes — "
                         "kernel dispatch gate for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
