"""Kernel-level microbench: fused weighted-moments path vs naive jnp.

On this CPU container the Pallas kernels run in interpret mode (a
correctness tool, not a perf tool), so the timing comparison here is the
fused *algorithm* (one pass, three moments) against the naive version — the
structural win the TPU kernel encodes.  The VMEM/MXU design constants are
reported as derived metadata for the roofline discussion.

``run_bootstrap`` benchmarks the matrix-free resample loop (in-kernel
counter-based RNG fused into the contraction, via the scan lowering on CPU)
against the materialized-(B, n) weight-matrix path and the naive 3-pass
formulation, and writes the trajectory to BENCH_bootstrap.json so perf is
tracked PR-over-PR.  ``run_kmeans`` does the same for bootstrap-over-
k-means (fused assignment+accumulate, kernels/kmeans_assign) against the
materialized path that builds the (B, n) weights AND the (B, n, k)
weighted one-hot, writing BENCH_kmeans.json.
"""
import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.reduce_api import KMeansStep
from repro.kernels.kmeans_assign import ops as ka_ops
from repro.kernels.weighted_hist import ops as wh_ops
from repro.kernels.weighted_stats import ops as ws_ops

_BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_bootstrap.json"
_BENCH_KMEANS_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_kmeans.json"


def _naive(w, x):
    w_tot = jnp.sum(w, axis=1)
    s1 = w @ x
    s2 = w @ (x * x)
    return w_tot, s1, s2


def run() -> None:
    key = jax.random.PRNGKey(7)
    B, n, d = 64, 65_536, 8
    w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))

    fused = jax.jit(lambda w, x: ws_ops.weighted_moments(w, x,
                                                         backend="jnp"))
    # "naive" = three separate jitted passes over W (models 3 HBM reads of
    # the (B, n) weight matrix; the TPU kernel reads each W tile once)
    n1 = jax.jit(lambda w: jnp.sum(w, axis=1))
    n2 = jax.jit(lambda w, x: w @ x)
    n3 = jax.jit(lambda w, x: w @ (x * x))
    us_f = timeit(lambda: jax.block_until_ready(fused(w, x)))
    us_n = timeit(lambda: (jax.block_until_ready(n1(w)),
                           jax.block_until_ready(n2(w, x)),
                           jax.block_until_ready(n3(w, x))))
    emit("kernel_weighted_moments_fused", us_f, "")
    emit("kernel_weighted_moments_3pass", us_n,
         f"fused_speedup={us_n / max(us_f, 1e-9):.2f}x;"
         f"w_bytes_read_ratio=3.0")

    # kernel design constants (per EXAMPLE tile): VMEM working set
    bb, bn, bd = 128, 512, 128
    vmem = (bb * bn + bn * bd + 2 * bb * bd + bb) * 4
    intensity = (2 * 2 * bb * bn * bd) / ((bb * bn + bn * bd) * 4)
    emit("kernel_weighted_moments_design", 0.0,
         f"tile_vmem_bytes={vmem};arith_intensity={intensity:.1f}"
         f";mxu_aligned={bb % 128 == 0 and bd % 128 == 0}")

    run_bootstrap()
    run_histogram()
    run_kmeans()


def run_bootstrap() -> None:
    """Matrix-free bootstrap: fused-RNG vs materialized-W vs naive 3-pass.

    The fused-RNG path never builds the (B, n) weight matrix (peak live
    memory O(B·block_n + B·d) on CPU, O(B·d) HBM on TPU); the other two pay
    for both the jax.random.poisson draw of (B, n) and its memory traffic.
    """
    B, n, d = 256, 1 << 16, 8
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))

    @jax.jit
    def materialized(key, x):
        w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
        return jnp.sum(w, axis=1), w @ x, w @ (x * x)

    wgen = jax.jit(
        lambda key: jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32))
    p1 = jax.jit(lambda w: jnp.sum(w, axis=1))
    p2 = jax.jit(lambda w, x: w @ x)
    p3 = jax.jit(lambda w, x: w @ (x * x))

    def naive():
        w = wgen(key)
        jax.block_until_ready((p1(w), p2(w, x), p3(w, x)))

    us_fused = timeit(lambda: jax.block_until_ready(
        ws_ops.fused_poisson_moments(7, x, B)))
    us_mat = timeit(lambda: jax.block_until_ready(materialized(key, x)))
    us_naive = timeit(naive)

    speedup_mat = us_mat / max(us_fused, 1e-9)
    speedup_naive = us_naive / max(us_fused, 1e-9)
    emit("bootstrap_fused_rng", us_fused,
         f"B={B};n={n};d={d};weight_matrix_bytes=0")
    emit("bootstrap_materialized_w", us_mat,
         f"fused_speedup={speedup_mat:.2f}x;weight_matrix_bytes={4 * B * n}")
    emit("bootstrap_naive_3pass", us_naive,
         f"fused_speedup={speedup_naive:.2f}x;w_bytes_read_ratio=3.0")

    _BENCH_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "d": d,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"fused_rng": us_fused,
                        "materialized_w": us_mat,
                        "naive_3pass": us_naive},
        "speedup_fused_vs_materialized": speedup_mat,
        "speedup_fused_vs_naive": speedup_naive,
        "peak_weight_bytes": {"fused_rng": 0,
                              "materialized_w": 4 * B * n,
                              "naive_3pass": 4 * B * n},
    }, indent=2) + "\n")


def run_kmeans() -> None:
    """Bootstrap-over-k-means: fused assignment+accumulate vs materialized.

    The materialized path draws the (B, n) Poisson weight matrix AND builds
    the (B, n, k) weighted one-hot inside the vmapped KMeansStep.update;
    the fused path (kernels/kmeans_assign, scan lowering on CPU) generates
    the weights in-pass and keeps assignment tile-local — peak live state
    O(B·k·d).  A single-state assignment pass is timed too (tiled vs the
    materialized (n, k) distance/one-hot).
    """
    B, n, k, d = 64, 1 << 16, 8, 2
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (n, d))
    cent = jax.random.normal(jax.random.fold_in(key, 1), (k, d)) * 2

    @jax.jit
    def materialized(key, x, cent):
        stat = KMeansStep(cent)
        w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
        st = jax.vmap(lambda wr: stat.update(stat.init_state(d), x, wr))(w)
        return st.sums, st.counts, st.inertia

    us_mat = timeit(lambda: jax.block_until_ready(
        materialized(key, x, cent)))
    us_fused = timeit(lambda: jax.block_until_ready(
        ka_ops.fused_poisson_kmeans(7, x, cent, B)))
    speedup = us_mat / max(us_fused, 1e-9)
    emit("kmeans_bootstrap_fused", us_fused,
         f"B={B};n={n};k={k};d={d};weight_matrix_bytes=0;onehot_bytes=0")
    emit("kmeans_bootstrap_materialized", us_mat,
         f"fused_speedup={speedup:.2f}x;"
         f"weight_matrix_bytes={4 * B * n};onehot_bytes={4 * B * n * k}")

    # single-state assignment pass: tiled scan vs materialized (n, k)
    assign_jnp = jax.jit(
        lambda x, cent: ka_ops.kmeans_assign(x, None, cent, backend="jnp"))
    us_a_jnp = timeit(lambda: jax.block_until_ready(assign_jnp(x, cent)))
    us_a_scan = timeit(lambda: jax.block_until_ready(
        ka_ops.kmeans_assign(x, None, cent, backend="scan")))
    emit("kmeans_assign_scan", us_a_scan, f"n={n};k={k};d={d}")
    emit("kmeans_assign_materialized", us_a_jnp,
         f"scan_speedup={us_a_jnp / max(us_a_scan, 1e-9):.2f}x;"
         f"nk_bytes={4 * n * k}")

    _BENCH_KMEANS_JSON.write_text(json.dumps({
        "config": {"B": B, "n": n, "k": k, "d": d,
                   "backend": jax.default_backend(),
                   "fused_lowering": ("pallas"
                                      if jax.default_backend() == "tpu"
                                      else "scan")},
        "us_per_call": {"fused": us_fused,
                        "materialized": us_mat,
                        "assign_scan": us_a_scan,
                        "assign_materialized": us_a_jnp},
        "speedup_fused_vs_materialized": speedup,
        "peak_intermediate_bytes": {
            "fused": 4 * (B * 512 + B * k * d),       # weight tile + states
            "materialized": 4 * B * n * (1 + k),      # weights + one-hot
        },
    }, indent=2) + "\n")


def run_histogram() -> None:
    """Quantile sketch update: flattened scatter-add vs one_hot+einsum
    (the old (n, d, nbins) memory blowup)."""
    n, d, nbins = 1 << 16, 4, 2048
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (n, d))
    w = jnp.ones((n,))
    lo, hi = jnp.zeros((d,)), jnp.ones((d,))

    scatter = jax.jit(lambda x, w: wh_ops.weighted_histogram(
        x, w, lo, hi, nbins, backend="jnp"))

    @jax.jit
    def onehot(x, w):
        idx = jnp.clip((x * nbins).astype(jnp.int32), 0, nbins - 1)
        oh = jax.nn.one_hot(idx, nbins, dtype=jnp.float32)
        return jnp.einsum("n,ndb->db", w, oh)

    us_s = timeit(lambda: jax.block_until_ready(scatter(x, w)))
    us_o = timeit(lambda: jax.block_until_ready(onehot(x, w)))
    emit("hist_scatter_add", us_s,
         f"n={n};d={d};nbins={nbins};peak_bytes={4 * n * d}")
    emit("hist_onehot_einsum", us_o,
         f"scatter_speedup={us_o / max(us_s, 1e-9):.2f}x"
         f";peak_bytes={4 * n * d * nbins}")
