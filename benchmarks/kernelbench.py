"""Kernel-level microbench: fused weighted-moments path vs naive jnp.

On this CPU container the Pallas kernels run in interpret mode (a
correctness tool, not a perf tool), so the timing comparison here is the
fused *algorithm* (one pass, three moments) against the naive version — the
structural win the TPU kernel encodes.  The VMEM/MXU design constants are
reported as derived metadata for the roofline discussion.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.weighted_stats import ops as ws_ops


def _naive(w, x):
    w_tot = jnp.sum(w, axis=1)
    s1 = w @ x
    s2 = w @ (x * x)
    return w_tot, s1, s2


def run() -> None:
    key = jax.random.PRNGKey(7)
    B, n, d = 64, 65_536, 8
    w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))

    fused = jax.jit(lambda w, x: ws_ops.weighted_moments(w, x,
                                                         backend="jnp"))
    # "naive" = three separate jitted passes over W (models 3 HBM reads of
    # the (B, n) weight matrix; the TPU kernel reads each W tile once)
    n1 = jax.jit(lambda w: jnp.sum(w, axis=1))
    n2 = jax.jit(lambda w, x: w @ x)
    n3 = jax.jit(lambda w, x: w @ (x * x))
    us_f = timeit(lambda: jax.block_until_ready(fused(w, x)))
    us_n = timeit(lambda: (jax.block_until_ready(n1(w)),
                           jax.block_until_ready(n2(w, x)),
                           jax.block_until_ready(n3(w, x))))
    emit("kernel_weighted_moments_fused", us_f, "")
    emit("kernel_weighted_moments_3pass", us_n,
         f"fused_speedup={us_n / max(us_f, 1e-9):.2f}x;"
         f"w_bytes_read_ratio=3.0")

    # kernel design constants (per EXAMPLE tile): VMEM working set
    bb, bn, bd = 128, 512, 128
    vmem = (bb * bn + bn * bd + 2 * bb * bd + bb) * 4
    intensity = (2 * 2 * bb * bn * bd) / ((bb * bn + bn * bd) * 4)
    emit("kernel_weighted_moments_design", 0.0,
         f"tile_vmem_bytes={vmem};arith_intensity={intensity:.1f}"
         f";mxu_aligned={bb % 128 == 0 and bd % 128 == 0}")
