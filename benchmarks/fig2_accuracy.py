"""Fig 2: effect of B (left) and n (right) on c_v."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import Mean, bootstrap, bootstrap_thetas, weights_for
from repro.core.accuracy import coefficient_of_variation
from repro.data import synthetic_numeric


def run() -> None:
    key = jax.random.PRNGKey(0)
    x = jnp.asarray(synthetic_numeric(20_000, 10.0, 2.0, seed=0))

    # (a) B vs c_v at fixed n = 2000 (nested prefixes of one weight draw)
    n = 2000
    w = weights_for("poisson", key, 256, n)
    thetas = bootstrap_thetas(x[:n], Mean(), w)
    for B in (2, 4, 8, 16, 32, 64, 128, 256):
        cv = float(coefficient_of_variation(thetas[:B]))
        emit(f"fig2a_cv_at_B{B}", 0.0, f"cv={cv:.5f}")

    # (b) n vs c_v at fixed B = 32
    for n_i in (125, 250, 500, 1000, 2000, 4000, 8000, 16000):
        r = bootstrap(x[:n_i], Mean(), B=32, key=key)
        us = timeit(lambda: bootstrap(x[:n_i], Mean(), B=32, key=key))
        emit(f"fig2b_cv_at_n{n_i}", us, f"cv={r.cv:.5f}")
