"""Fig 10: processing time with/without the update (delta-maintenance)
procedure, plus paper-faithful multinomial delta vs the Poisson-exact path
(the beyond-paper optimization, DESIGN.md §7.1).  Warm-JIT timing."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (Mean, MultinomialDeltaBootstrap, bootstrap,
                        poisson_delta_extend, poisson_delta_init,
                        poisson_delta_result)
from repro.data import synthetic_numeric


def _recompute(data, key, B):
    r = bootstrap(data, Mean(), B=B, key=key)
    jax.block_until_ready(r.thetas)
    return r


def _delta_update(pd, delta):
    pd = poisson_delta_extend(pd, delta)
    res = poisson_delta_result(pd)
    jax.block_until_ready(res.thetas)
    return pd, res


def run() -> None:
    key = jax.random.PRNGKey(6)
    B = 32
    for total in (100_000, 400_000, 1_600_000):
        data = jnp.asarray(synthetic_numeric(total, 10.0, 2.0, seed=9))
        half = total // 2

        # WITHOUT optimization: recompute the whole bootstrap over s'
        _recompute(data, key, B)                         # warm
        t0 = time.perf_counter()
        _recompute(data, key, B)
        t_without = time.perf_counter() - t0

        # WITH: states already hold s; timed section = add Δs only
        pd = poisson_delta_init(Mean(), B, 1, key)
        pd = poisson_delta_extend(pd, data[:half])
        _delta_update(pd, data[half:])                   # warm (same shapes)
        pd = poisson_delta_init(Mean(), B, 1, key)
        pd = poisson_delta_extend(pd, data[:half])
        jax.block_until_ready(pd.states.s1)
        t0 = time.perf_counter()
        _delta_update(pd, data[half:])
        t_with = time.perf_counter() - t0

        emit(f"fig10_without_opt_N{total}", t_without * 1e6, "")
        emit(f"fig10_with_opt_N{total}", t_with * 1e6,
             f"speedup={t_without / max(t_with, 1e-9):.2f}x")

    # faithful §4.1 multinomial delta (sketch) vs Poisson-exact delta:
    # timed section = ONE extension of an existing sample by Δs
    data_np = synthetic_numeric(60_000, 10.0, 2.0, seed=10)
    mdb = MultinomialDeltaBootstrap(Mean(), B=16, seed=11)
    mdb.extend(data_np[:30_000])
    t0 = time.perf_counter()
    mdb.extend(data_np[30_000:])
    _ = mdb.result()
    t_multi = time.perf_counter() - t0

    pd = poisson_delta_init(Mean(), 16, 1, key)
    pd = poisson_delta_extend(pd, jnp.asarray(data_np[:30_000]))
    _delta_update(pd, jnp.asarray(data_np[30_000:]))     # warm
    pd = poisson_delta_init(Mean(), 16, 1, key)
    pd = poisson_delta_extend(pd, jnp.asarray(data_np[:30_000]))
    jax.block_until_ready(pd.states.s1)
    t0 = time.perf_counter()
    _delta_update(pd, jnp.asarray(data_np[30_000:]))
    t_pois = time.perf_counter() - t0
    emit("fig10_multinomial_sketch_delta", t_multi * 1e6,
         f"disk_accesses={mdb.disk_accesses}")
    emit("fig10_poisson_exact_delta", t_pois * 1e6,
         f"speedup_vs_faithful={t_multi / max(t_pois, 1e-9):.2f}x")
