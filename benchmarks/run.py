"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (fig2_accuracy, fig3_intra, fig5_mean,
                            fig6_median, fig7_kmeans, fig8_ssabe,
                            fig9_sampling, fig10_delta, kernelbench,
                            roofline)
    print("name,us_per_call,derived")
    modules = [fig2_accuracy, fig3_intra, fig5_mean, fig6_median,
               fig7_kmeans, fig8_ssabe, fig9_sampling, fig10_delta,
               kernelbench, roofline]
    failed = []
    for mod in modules:
        try:
            mod.run()
        except Exception as e:
            failed.append((mod.__name__, e))
            print(f"{mod.__name__},0.0,ERROR={e!r}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
