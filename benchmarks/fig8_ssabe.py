"""Fig 8: SSABE empirical n̂/B̂ vs theoretical predictions."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import Mean, ssabe
from repro.data import synthetic_numeric


def run() -> None:
    key = jax.random.PRNGKey(5)
    x = jnp.asarray(synthetic_numeric(20_000, 10.0, 2.0, seed=6))
    for sigma in (0.10, 0.05, 0.02, 0.01):
        res = ssabe(x[:2000], Mean(), sigma=sigma, tau=0.01, key=key,
                    N=100_000_000)
        us = timeit(lambda: ssabe(x[:2000], Mean(), sigma=sigma, tau=0.01,
                                  key=key, N=100_000_000), repeats=1)
        emit(f"fig8_ssabe_sigma{sigma}", us,
             f"B_hat={res.B};B_theory={res.B_theory};"
             f"n_hat={res.n};n_theory={res.n_theory};"
             f"fit_a={res.fit_a:.4f};fit_c={res.fit_c:.5f}")
