"""Analytic MODEL_FLOPS per (arch × shape) — the 'useful work' yardstick.

train:   6 · N_active · tokens      (fwd 2x + bwd 4x; assignment formula)
prefill: 2 · N_active · tokens
decode:  2 · N_active · batch        (one new token per sequence)

Attention score/value FLOPs and the MoE router/dispatch are excluded on
purpose — the MODEL_FLOPS / HLO_FLOPs ratio then exposes attention cost,
remat recompute and routing overhead (EXPERIMENTS.md §Roofline discusses
the decomposition per cell).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.models.config import SHAPES, ModelConfig, ShapeConfig


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.num_active_params()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def model_flops_for(arch: str, shape_name: str) -> float:
    return model_flops(get_config(arch), SHAPES[shape_name])
