"""Fig 6: median — stock (full scan) vs naive re-drawn bootstrap vs
optimized (delta-maintained) resampling.  Warm-JIT timing + row accounting
(see fig5 header for methodology)."""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import (Quantile, bootstrap, poisson_delta_extend,
                        poisson_delta_init, poisson_delta_result)
from repro.data import PreMapSampler, ShardedStore, synthetic_numeric
import jax.numpy as jnp


def _naive(data, key, q, sigma):
    sampler = PreMapSampler(ShardedStore.from_array(data, 65_536), seed=5)
    n, rows = 2048, 0
    while True:
        x = sampler.take(0, n)                  # re-read + redraw (naive)
        rows += n
        res = bootstrap(x, q, B=32, key=key)
        if res.cv <= sigma or n * 2 > sampler.N:
            return res, rows
        n *= 2


def _optimized(data, key, q, sigma):
    sampler = PreMapSampler(ShardedStore.from_array(data, 65_536), seed=5)
    pd = poisson_delta_init(q, 32, 1, key)
    n_have, n, rows = 0, 2048, 0
    while True:
        pd = poisson_delta_extend(pd, sampler.take(n_have, n))
        rows += n - n_have
        n_have = n
        res = poisson_delta_result(pd)
        if res.cv <= sigma or n_have * 2 > sampler.N:
            return res, rows
        n = min(sampler.N, n_have * 2)


def run() -> None:
    key = jax.random.PRNGKey(3)
    N, sigma = 2_000_000, 0.003
    data = synthetic_numeric(N, 10.0, 2.0, seed=4)
    q = Quantile(0.5, lo=0.0, hi=20.0)

    t0 = time.perf_counter()
    true = float(np.median(ShardedStore.from_array(data, 65_536).read_all()))
    t_full = time.perf_counter() - t0
    emit("fig6_median_stock", t_full * 1e6, f"value={true:.4f};rows={N}")

    _naive(data, key, q, sigma)                       # warm
    t0 = time.perf_counter()
    res, rows_naive = _naive(data, key, q, sigma)
    t_naive = time.perf_counter() - t0
    emit("fig6_median_naive_bootstrap", t_naive * 1e6,
         f"rows={rows_naive};row_speedup={N / rows_naive:.1f}x;"
         f"rel_err={abs(float(np.ravel(res.estimate)[0]) - true) / true:.4f}")

    _optimized(data, key, q, sigma)                   # warm
    t0 = time.perf_counter()
    res, rows_opt = _optimized(data, key, q, sigma)
    t_opt = time.perf_counter() - t0
    emit("fig6_median_optimized", t_opt * 1e6,
         f"rows={rows_opt};row_speedup={N / rows_opt:.1f}x;"
         f"wall_speedup_vs_naive={t_naive / max(t_opt, 1e-9):.2f}x;"
         f"rel_err={abs(float(np.ravel(res.estimate)[0]) - true) / true:.4f}")
