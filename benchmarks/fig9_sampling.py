"""Fig 9: pre-map vs post-map sampling processing time (+ rows read)."""
import time

import numpy as np

from benchmarks.common import emit
from repro.data import (PostMapSampler, PreMapSampler, ShardedStore,
                        synthetic_numeric)


def run() -> None:
    N = 2_000_000
    data = synthetic_numeric(N, 10.0, 2.0, seed=7)
    for frac in (0.001, 0.01, 0.05):
        n = int(N * frac)

        store = ShardedStore.from_array(data, 65_536)
        t0 = time.perf_counter()
        pre = PreMapSampler(store, seed=8)
        _ = pre.take(0, n)
        t_pre = time.perf_counter() - t0
        rows_pre = store.stats.rows_read

        store = ShardedStore.from_array(data, 65_536)
        t0 = time.perf_counter()
        post = PostMapSampler(store, seed=8)
        _ = post.take(0, n)
        t_post = time.perf_counter() - t0
        rows_post = store.stats.rows_read

        emit(f"fig9_premap_frac{frac}", t_pre * 1e6, f"rows_read={rows_pre}")
        emit(f"fig9_postmap_frac{frac}", t_post * 1e6,
             f"rows_read={rows_post};"
             f"premap_speedup={t_post / max(t_pre, 1e-9):.2f}x;"
             f"kv_exact={post.kv_count == N}")
