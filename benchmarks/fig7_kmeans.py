"""Fig 7: K-Means via EARL (sample + bootstrap bound) vs full-data Lloyd.

Both fits start from the SAME initial centroids (k rows of the permuted
sample) so the comparison isolates sample-vs-full data cost, not local
optima.  The paper validates 'centroids within 5% of the optimal'; we
check inertia of the sample-fit centroids, evaluated on the FULL data,
against the full fit.

The Lloyd loops run through ``kmeans_fit`` (one jitted scan, centroids as
carried state — no per-iteration recompile) and the bootstrap certifies
the centroids on the matrix-free path (``backend="fused_rng"`` →
kernels/kmeans_assign: no (B, n) weight matrix, no (n, k) one-hot)."""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import KMeansStep, bootstrap, kmeans_fit
from repro.data import PreMapSampler, ShardedStore, synthetic_clusters


def _inertia(x, cents):
    d2 = ((x[:, None, :] - cents[None]) ** 2).sum(-1)
    return float(d2.min(axis=1).mean())


def run() -> None:
    key = jax.random.PRNGKey(4)
    N, k, iters = 400_000, 5, 8
    x_np, _ = synthetic_clusters(N, k=k, dim=2, seed=5)
    sampler = PreMapSampler(ShardedStore.from_array(x_np, 65_536), seed=6)

    x_full = jax.numpy.asarray(x_np)
    n = max(2000, N // 50)
    xs = sampler.take(0, n)
    cents0 = xs[:k]                                   # shared init

    # warm: compiles the iters-length jitted scan once
    jax.block_until_ready(kmeans_fit(x_full, k, iters, key, init=cents0))
    t0 = time.perf_counter()
    cents_full, _ = kmeans_fit(x_full, k, iters, key, init=cents0)
    jax.block_until_ready(cents_full)
    t_full = time.perf_counter() - t0
    inertia_full = _inertia(x_np, np.asarray(cents_full))
    emit("fig7_kmeans_full", t_full * 1e6,
         f"inertia={inertia_full:.4f};rows={N * iters}")

    jax.block_until_ready(kmeans_fit(xs, k, iters, key, init=cents0))  # warm
    jax.block_until_ready(bootstrap(xs, KMeansStep(cents0), B=24, key=key,
                                    backend="fused_rng").thetas)       # warm
    t0 = time.perf_counter()
    cents_s, _ = kmeans_fit(xs, k, iters, key, init=cents0)
    jax.block_until_ready(cents_s)
    res = bootstrap(xs, KMeansStep(cents_s), B=24, key=key,
                    backend="fused_rng")
    jax.block_until_ready(res.thetas)
    t_earl = time.perf_counter() - t0
    inertia_s = _inertia(x_np, np.asarray(cents_s))
    gap = (inertia_s - inertia_full) / inertia_full
    emit("fig7_kmeans_earl", t_earl * 1e6,
         f"wall_speedup={t_full / max(t_earl, 1e-9):.2f}x;"
         f"row_speedup={N / n:.1f}x;centroid_cv={res.cv:.4f};"
         f"inertia_gap={gap:.4f};bootstrap=fused_rng")
    assert gap < 0.05, f"paper claims <5% of optimal; got {gap:.3f}"
