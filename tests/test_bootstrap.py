"""Bootstrap engines: multinomial vs poisson, chunked, kernel path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Mean, Std, Sum, Var, bootstrap, bootstrap_chunked,
                        multinomial_counts, poisson_weights)


class TestWeights:
    def test_multinomial_rows_sum_to_n(self, key):
        c = multinomial_counts(key, B=16, n=257)
        assert c.shape == (16, 257)
        np.testing.assert_array_equal(np.asarray(c.sum(axis=1)),
                                      np.full(16, 257))

    def test_multinomial_resample_size(self, key):
        c = multinomial_counts(key, B=4, n=100, resample_size=50)
        np.testing.assert_array_equal(np.asarray(c.sum(axis=1)),
                                      np.full(4, 50))

    def test_poisson_moments(self, key):
        w = poisson_weights(key, B=64, n=4096)
        assert abs(float(w.mean()) - 1.0) < 0.01
        assert abs(float(w.var()) - 1.0) < 0.02


class TestEngines:
    @pytest.mark.parametrize("engine", ["multinomial", "poisson"])
    def test_se_matches_clt(self, key, engine):
        """Bootstrap SE of the mean ~ s/sqrt(n)."""
        n = 4000
        x = jax.random.normal(key, (n,)) * 3.0 + 50.0
        res = bootstrap(x, Mean(), B=256, key=key, engine=engine)
        clt = float(jnp.std(x) / jnp.sqrt(n))
        assert abs(res.report.se - clt) / clt < 0.25, engine

    def test_engines_agree(self, key):
        x = jax.random.normal(key, (2000,)) * 2 + 10
        r1 = bootstrap(x, Mean(), B=200, key=key, engine="multinomial")
        r2 = bootstrap(x, Mean(), B=200, key=key, engine="poisson")
        assert abs(r1.cv - r2.cv) / r1.cv < 0.5

    def test_vector_statistic(self, key):
        x = jax.random.normal(key, (1000, 5)) + jnp.arange(5.0)
        res = bootstrap(x, Mean(), B=64, key=key)
        assert res.thetas.shape == (64, 5)
        assert np.isfinite(res.cv)

    def test_ci_covers_truth(self, key):
        hits = 0
        for i in range(20):
            k = jax.random.fold_in(key, i)
            x = jax.random.normal(k, (500,)) + 7.0
            res = bootstrap(x, Mean(), B=200, key=k, alpha=0.05)
            lo, hi = float(res.report.ci_lo[0]), float(res.report.ci_hi[0])
            hits += (lo <= 7.0 <= hi)
        assert hits >= 15, f"95% CI covered truth only {hits}/20 times"


class TestChunked:
    def test_matches_unchunked_distribution(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 5
        r_plain = bootstrap(x, Mean(), B=128, key=key, engine="poisson")
        r_chunk = bootstrap_chunked(x, Mean(), B=128, key=key, chunk=512)
        assert abs(r_plain.cv - r_chunk.cv) / r_plain.cv < 0.5
        np.testing.assert_allclose(np.ravel(r_plain.estimate),
                                   np.ravel(r_chunk.estimate), rtol=1e-5)

    def test_ragged_chunking(self, key):
        x = jax.random.normal(key, (1001,)) + 3.0
        r = bootstrap_chunked(x, Mean(), B=32, key=key, chunk=256)
        assert r.n == 1001
        assert np.isfinite(r.cv)

    def test_multinomial_rejected(self, key):
        with pytest.raises(ValueError):
            bootstrap_chunked(jnp.ones(10), Mean(), B=4, key=key,
                              engine="multinomial")


class TestKernelPath:
    def test_kernel_backend_matches_jnp(self, key):
        x = jax.random.normal(key, (1000, 3)) + 2.0
        r_jnp = bootstrap(x, Mean(), B=32, key=key, use_kernel=False)
        r_krn = bootstrap(x, Mean(), B=32, key=key, use_kernel=True)
        np.testing.assert_allclose(np.asarray(r_jnp.thetas),
                                   np.asarray(r_krn.thetas),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("stat_cls", [Mean, Var, Std])
    def test_kernel_path_stats(self, key, stat_cls):
        x = jax.random.normal(key, (512,)) * 1.5 + 4
        r_jnp = bootstrap(x, stat_cls(), B=16, key=key, use_kernel=False)
        r_krn = bootstrap(x, stat_cls(), B=16, key=key, use_kernel=True)
        np.testing.assert_allclose(np.asarray(r_jnp.thetas),
                                   np.asarray(r_krn.thetas),
                                   rtol=2e-3, atol=1e-4)


class TestSeedDerivation:
    """offset_seed: chunk/step stream seeds must never wrap int32."""

    def test_matches_python_modular_add(self):
        from repro.core.bootstrap import offset_seed
        m = np.iinfo(np.int32).max
        for base in (0, 5, m - 1000, m - 3, m - 1):
            for i in (0, 1, 2, 7, 1000, m - 2):
                got = int(offset_seed(base, i))
                assert got == (base + i) % m, (base, i)
                assert 0 <= got < m, (base, i)

    def test_distinct_streams_at_boundary(self):
        """Near iinfo(int32).max the naive base+i wraps negative; the
        modular form stays in range and the streams stay distinct."""
        from repro.core.bootstrap import offset_seed
        m = np.iinfo(np.int32).max
        with np.errstate(over="ignore"):
            naive = np.int32(m - 2) + np.int32(5)      # wraps
        assert naive < 0
        seeds = [int(offset_seed(m - 2, i)) for i in range(8)]
        assert len(set(seeds)) == 8
        assert all(0 <= s < m for s in seeds)

    def test_chunked_bootstrap_at_seed_boundary(self, key, monkeypatch):
        """Force the per-run base seed to the int32 boundary: every chunk
        stream must still be valid (finite, sane estimate)."""
        import importlib
        # the package re-exports the bootstrap *function* under the same
        # name, shadowing the submodule attribute — resolve the module
        bs = importlib.import_module("repro.core.bootstrap")
        m = int(np.iinfo(np.int32).max)
        monkeypatch.setattr(bs, "seed_from_key",
                            lambda k: jnp.asarray(m - 1, jnp.int32))
        x = jax.random.normal(key, (1500,)) + 4.0
        r = bs.bootstrap_chunked(x, Mean(), B=16, key=key, chunk=256,
                                 backend="fused_rng")
        assert np.isfinite(r.cv)
        assert abs(float(np.ravel(r.estimate)[0]) - 4.0) < 0.3


class TestConstructorPassthrough:
    """Median()/Quantile.with_range must forward every Quantile knob."""

    def test_median_preserves_backend_and_shape_knobs(self):
        from repro.core import Median, Quantile
        med = Median(nbins=512, lo=-2.0, hi=2.0, backend="pallas_interpret")
        assert isinstance(med, Quantile)
        assert med.q == 0.5 and med.nbins == 512
        assert med.backend == "pallas_interpret"
        assert Median().backend is None

    def test_with_range_preserves_backend(self):
        from repro.core import Median, Quantile
        for q in (Quantile(0.25, nbins=128, backend="pallas_interpret"),
                  Median(backend="pallas_interpret")):
            q2 = q.with_range(-1.0, 1.0)
            assert q2.backend == "pallas_interpret"
            assert q2.nbins == q.nbins and q2.q == q.q

    def test_median_backend_actually_routes(self, key):
        """The forwarded backend must reach Quantile.update (same counts as
        the default scatter path, via the Pallas sketch)."""
        from repro.core import Median
        x = jax.random.normal(key, (300,)) * 0.2 + 0.5
        m0 = Median(nbins=256)
        mk = Median(nbins=256, backend="pallas_interpret")
        s0 = m0.update(m0.init_state(1), x)
        sk = mk.update(mk.init_state(1), x)
        np.testing.assert_allclose(np.asarray(sk.counts),
                                   np.asarray(s0.counts),
                                   rtol=1e-5, atol=1e-4)
        assert float(m0.finalize(s0)) == pytest.approx(
            float(mk.finalize(sk)), rel=1e-6)
