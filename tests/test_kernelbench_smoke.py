"""Tier-1 kernel-dispatch gate: kernelbench --smoke must run clean.

Every fused/materialized dispatch path the benchmarks exercise (weighted
moments f32+bf16, fused Poisson moments/kmeans/histogram, Pallas interpret
sketch, scatter paths) executes at tiny shapes with no timing — so a broken
kernel wrapper fails HERE instead of only surfacing in a BENCH_*.json
refresh.  Run in-process (the shapes are tiny) but asserted to leave the
BENCH jsons untouched.
"""
import os
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.filterwarnings("ignore")
def test_kernelbench_smoke_runs_and_writes_nothing():
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks import kernelbench
    finally:
        sys.path.remove(_ROOT)

    stamps = {}
    for p in (kernelbench._BENCH_JSON, kernelbench._BENCH_KMEANS_JSON,
              kernelbench._BENCH_QUANTILE_JSON):
        stamps[p] = p.stat().st_mtime_ns if p.exists() else None

    kernelbench.run(smoke=True)

    for p, stamp in stamps.items():
        now = p.stat().st_mtime_ns if p.exists() else None
        assert now == stamp, f"smoke mode must not write {p.name}"
