"""Tier-1 kernel-dispatch gate: kernelbench --smoke must run clean.

Every fused/materialized dispatch path the benchmarks exercise (weighted
moments f32+bf16, fused Poisson moments/kmeans/histogram, Pallas interpret
sketch, scatter paths) executes at tiny shapes with no timing — so a broken
kernel wrapper fails HERE instead of only surfacing in a BENCH_*.json
refresh.  Run in-process (the shapes are tiny) but asserted to leave the
BENCH jsons untouched.
"""
import os
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.filterwarnings("ignore")
def test_kernelbench_smoke_runs_and_writes_nothing():
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks import kernelbench
    finally:
        sys.path.remove(_ROOT)

    stamps = {}
    for p in (kernelbench._BENCH_JSON, kernelbench._BENCH_KMEANS_JSON,
              kernelbench._BENCH_QUANTILE_JSON,
              kernelbench._BENCH_MULTI_JSON, kernelbench._BENCH_STREAM_JSON,
              kernelbench._BENCH_GROUPED_JSON, kernelbench._BENCH_FT_JSON,
              kernelbench._BENCH_LIVE_JSON,
              kernelbench._BENCH_DURABLE_JSON):
        stamps[p] = p.stat().st_mtime_ns if p.exists() else None

    kernelbench.run(smoke=True)

    for p, stamp in stamps.items():
        now = p.stat().st_mtime_ns if p.exists() else None
        assert now == stamp, f"smoke mode must not write {p.name}"


def test_check_regression_gate(tmp_path):
    """The nightly regression checker passes on identical BENCH jsons and
    fails when a headline speedup drops below its floor/ratio."""
    import json
    import pathlib
    import shutil

    sys.path.insert(0, _ROOT)
    try:
        from benchmarks import check_regression
    finally:
        sys.path.remove(_ROOT)

    root = pathlib.Path(_ROOT)
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir()
    cur.mkdir()
    for p in root.glob("BENCH_*.json"):
        shutil.copy(p, base / p.name)
        shutil.copy(p, cur / p.name)
    assert check_regression.check(base, cur, 0.5) == []

    d = json.loads((cur / "BENCH_multi.json").read_text())
    d["speedup_group_vs_sequential"] = 0.9      # below the 1.5 floor
    (cur / "BENCH_multi.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    shutil.copy(base / "BENCH_multi.json", cur / "BENCH_multi.json")
    d = json.loads((cur / "BENCH_grouped.json").read_text())
    d["speedup_grouped_vs_sequential"] = 1.5    # below the 2.0 floor
    (cur / "BENCH_grouped.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    d["speedup_grouped_vs_sequential"] = 3.0
    d["per_key_thetas_bitwise_equal_to_sequential"] = False
    (cur / "BENCH_grouped.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    # ISSUE-8 fault-tolerance gates: overhead ceiling + bitwise invariants
    shutil.copy(base / "BENCH_grouped.json", cur / "BENCH_grouped.json")
    d = json.loads((cur / "BENCH_ft.json").read_text())
    d["checkpoint_overhead_ratio"] = 1.25       # above the 1.10 ceiling
    (cur / "BENCH_ft.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    d["checkpoint_overhead_ratio"] = 1.02
    d["resumed_bitwise_equal"] = False
    (cur / "BENCH_ft.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    # ISSUE-9 live-ingest gates: throughput abs floor + shed/resume
    # bitwise invariants
    shutil.copy(base / "BENCH_ft.json", cur / "BENCH_ft.json")
    d = json.loads((cur / "BENCH_live.json").read_text())
    d["batches_per_sec"] = 5.0                  # below the 20.0 abs floor
    (cur / "BENCH_live.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    d["batches_per_sec"] = 500.0
    d["shed_bitwise_equal_to_oracle"] = False
    (cur / "BENCH_live.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    # ISSUE-10 durable-log gates: fsync tax ceiling + recovery invariants
    shutil.copy(base / "BENCH_live.json", cur / "BENCH_live.json")
    d = json.loads((cur / "BENCH_durable.json").read_text())
    d["fsync_tax_batch"] = 1.8                  # above the 1.5 ceiling
    (cur / "BENCH_durable.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    d["fsync_tax_batch"] = 1.2
    d["recovery_bitwise_equal"] = False
    (cur / "BENCH_durable.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)

    d["recovery_bitwise_equal"] = True
    d["torn_recovery_ok"] = False
    (cur / "BENCH_durable.json").write_text(json.dumps(d))
    assert check_regression.check(base, cur, 0.5)
