"""Integration check of deliverable (e): the dry-run matrix artifacts.

Validates that every (arch × shape × mesh) cell either compiled OK or is
an assignment-sanctioned long_500k skip, and that OK records carry the
roofline inputs.  Skipped (not failed) when the artifacts have not been
generated in this checkout (``python -m repro.launch.dryrun --all``).
"""
import glob
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import ARCH_IDS
from repro.models.config import SHAPES

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(ART, "*.json")),
    reason="dry-run artifacts not generated")


def _load(arch, shape, pod):
    path = os.path.join(ART, f"{arch}.{shape}.{pod}.json")
    assert os.path.exists(path), f"missing dry-run cell {path}"
    return json.load(open(path))


@pytest.mark.parametrize("pod", ["pod1", "pod2"])
@pytest.mark.parametrize("shape", list(SHAPES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cell_status(arch, shape, pod):
    r = _load(arch, shape, pod)
    assert r["status"] in ("ok", "skipped"), r.get("error", "")[:500]
    if r["status"] == "skipped":
        assert shape == "long_500k", "only long_500k skips are sanctioned"
        assert "full-attention" in r["reason"]
    else:
        assert r["dot_flops_per_chip"] > 0
        assert r["collective_bytes_per_chip"]["total"] >= 0
        assert r["chips"] == (512 if pod == "pod2" else 256)


def test_matrix_complete():
    from benchmarks.roofline import parse_artifact_name
    base = [f for f in glob.glob(os.path.join(ART, "*.json"))
            if parse_artifact_name(f)[3] == ""]
    assert len(base) == len(ARCH_IDS) * len(SHAPES) * 2      # 80 cells


def test_sanctioned_skip_count():
    from benchmarks.roofline import parse_artifact_name
    skips = 0
    for f in glob.glob(os.path.join(ART, "*.json")):
        if parse_artifact_name(f)[3] != "":
            continue
        if json.load(open(f))["status"] == "skipped":
            skips += 1
    assert skips == 10            # 5 full-attention archs × 2 meshes
