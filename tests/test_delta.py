"""Delta-maintained resampling (paper §4): exactness + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Mean, MultinomialDeltaBootstrap, Sum, bootstrap,
                        optimal_y, p_shared, poisson_delta_extend,
                        poisson_delta_init, poisson_delta_result,
                        shared_base_bootstrap, work_saved)


class TestPoissonDelta:
    def test_extension_equals_one_shot_distribution(self, key):
        """Poisson delta maintenance is EXACT: extending in k pieces gives a
        valid poisson bootstrap over the union (same cv scale)."""
        x = jax.random.normal(key, (3000,)) * 2 + 9
        pd = poisson_delta_init(Mean(), 128, 1, key)
        for piece in (x[:1000], x[1000:1800], x[1800:]):
            pd = poisson_delta_extend(pd, piece)
        r_delta = poisson_delta_result(pd, Mean()(x))
        r_fresh = bootstrap(x, Mean(), B=128, key=jax.random.fold_in(key, 9),
                            engine="poisson")
        assert r_delta.n == 3000
        assert abs(r_delta.cv - r_fresh.cv) / r_fresh.cv < 0.5

    def test_cv_shrinks_as_sample_grows(self, key):
        x = jax.random.normal(key, (8000,)) + 5
        pd = poisson_delta_init(Mean(), 64, 1, key)
        cvs = []
        prev = 0
        for stop in (500, 2000, 8000):
            pd = poisson_delta_extend(pd, x[prev:stop])
            prev = stop
            cvs.append(poisson_delta_result(pd, Mean()(x[:stop])).cv)
        assert cvs[2] < cvs[0]

    def test_merge_commutes_with_update(self, key):
        """The Statistic invariant that makes §4.1 maintenance valid."""
        stat = Mean()
        x = jax.random.normal(key, (100, 2))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (100,)))
        s_all = stat.update(stat.init_state(2), x, w)
        s_a = stat.update(stat.init_state(2), x[:60], w[:60])
        s_b = stat.update(stat.init_state(2), x[60:], w[60:])
        merged = stat.merge(s_a, s_b)
        np.testing.assert_allclose(np.ravel(stat.finalize(merged)),
                                   np.ravel(stat.finalize(s_all)), rtol=1e-5)


class TestMultinomialDeltaBaseline:
    def test_resample_sizes_track_sample(self):
        mdb = MultinomialDeltaBootstrap(Mean(), B=8, seed=1)
        mdb.extend(np.random.default_rng(0).normal(10, 2, (500, 1)))
        mdb.extend(np.random.default_rng(1).normal(10, 2, (300, 1)))
        assert mdb.n == 800
        for b in mdb.resamples:
            assert len(b) == 800
            assert b.min() >= 0 and b.max() < 800

    def test_estimates_sane(self):
        mdb = MultinomialDeltaBootstrap(Mean(), B=32, seed=2)
        mdb.extend(np.random.default_rng(2).normal(10, 2, (1000, 1)))
        mdb.extend(np.random.default_rng(3).normal(10, 2, (1000, 1)))
        res = mdb.result()
        assert abs(float(np.ravel(res.estimate)[0]) - 10.0) < 0.5
        assert res.cv < 0.05

    def test_sketch_reduces_disk_accesses(self):
        kw = dict(seed=3, use_gaussian=True)
        rng = np.random.default_rng(4)
        data = [rng.normal(10, 2, (800, 1)) for _ in range(3)]
        with_sketch = MultinomialDeltaBootstrap(Mean(), B=16,
                                                use_sketch=True, **kw)
        without = MultinomialDeltaBootstrap(Mean(), B=16,
                                            use_sketch=False, **kw)
        for d in data:
            with_sketch.extend(d)
            without.extend(d)
        assert with_sketch.disk_accesses < without.disk_accesses, \
            "the §4.1 sketch must cut simulated disk I/O"

    def test_gaussian_approx_close_to_binomial(self):
        """Eq. 3 approximates Eq. 2 (old-part sizes distributionally)."""
        a = MultinomialDeltaBootstrap(Mean(), B=1, seed=5, use_gaussian=True)
        b = MultinomialDeltaBootstrap(Mean(), B=1, seed=5, use_gaussian=False)
        sizes_a = [a._old_part_size(10_000, 12_000) for _ in range(300)]
        sizes_b = [b._old_part_size(10_000, 12_000) for _ in range(300)]
        assert abs(np.mean(sizes_a) - np.mean(sizes_b)) < 50


class TestIntraIteration:
    def test_eq4_values(self):
        # P(X=y) = n!/((n-yn)! n^{yn}); for n=1, y=1: 1!/0!/1 = 1
        assert p_shared(1, 1.0) == pytest.approx(1.0)
        # monotone decreasing in y for fixed n
        assert p_shared(50, 0.1) > p_shared(50, 0.5) > p_shared(50, 0.9)

    def test_paper_example_n29_y03(self):
        """§4.2: n=29, y=0.3 -> ~35% of resamples share 30% of data."""
        assert 0.15 < p_shared(29, 0.3) < 0.45

    def test_optimal_y_positive_savings(self):
        for n in (10, 50, 200, 1000):
            y, w = optimal_y(n)
            assert 0 < y < 1
            assert w > 0
            assert w == pytest.approx(work_saved(n, y))

    def test_shared_base_bootstrap_unbiased(self, key):
        x = jax.random.normal(key, (2000,)) * 2 + 8
        r_std = bootstrap(x, Mean(), B=256, key=key, engine="multinomial")
        r_int = shared_base_bootstrap(x, Mean(), B=256, key=key)
        np.testing.assert_allclose(np.ravel(r_int.estimate),
                                   np.ravel(r_std.estimate), rtol=1e-5)
        mean_std = float(np.mean(np.asarray(r_std.thetas)))
        mean_int = float(np.mean(np.asarray(r_int.thetas)))
        assert abs(mean_std - mean_int) / abs(mean_std) < 0.01
