"""Matrix-free bootstrap: in-kernel RNG fused moments + histogram sketch.

Covers the ISSUE-1 acceptance criteria:
  * fused moments == materialized implicit-weights oracle (all backends)
  * in-kernel Poisson(1) weights are statistically sound (mean/var, and the
    fused bootstrap matches the jax.random.poisson oracle distributionally)
  * poisson_delta_extend stays exact under backend="fused_rng"
  * shape-capture harness: the fused pipeline at n=2^20, B=256 contains NO
    (B, n)-sized intermediate anywhere in its jaxpr (and the harness itself
    is validated against the legacy path, which does contain one)
  * Quantile scatter-add path == one_hot+einsum oracle == Pallas sketch
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Mean, Quantile, Std, Var, bootstrap,
                        bootstrap_chunked, multinomial_counts)
from repro.core.bootstrap import seed_from_key
from repro.core.delta import (poisson_delta_extend, poisson_delta_init,
                              poisson_delta_result)
from repro.core.reduce_api import _as_2d
from repro.core.ssabe import ssabe
from repro.kernels.weighted_hist import ops as wh_ops
from repro.kernels.weighted_hist.ref import (weighted_hist_onehot_ref,
                                             weighted_hist_scatter_ref)
from repro.kernels.weighted_stats import ops as ws_ops
from repro.kernels.weighted_stats.ref import weighted_moments_ref


# ----------------------------------------------------------------------------
# jaxpr shape-capture harness
# ----------------------------------------------------------------------------
def _walk_shapes(jaxpr, out):
    """Collect every intermediate aval shape, recursing into sub-jaxprs
    (pjit/scan/pallas_call bodies)."""
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(tuple(aval.shape))
        for p in eqn.params.values():
            for q in (p if isinstance(p, (tuple, list)) else (p,)):
                if hasattr(q, "jaxpr") and hasattr(q.jaxpr, "eqns"):
                    _walk_shapes(q.jaxpr, out)       # ClosedJaxpr
                elif hasattr(q, "eqns"):
                    _walk_shapes(q, out)             # raw Jaxpr
    return out


def _max_intermediate_size(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    shapes = _walk_shapes(jaxpr.jaxpr, [])
    return max((int(np.prod(s)) for s in shapes if s), default=0)


class TestNoWeightMatrix:
    B, N = 256, 1 << 20

    def test_fused_pipeline_never_builds_Bn(self, key):
        """n=2^20, B=256: every intermediate in the traced fused pipeline is
        far smaller than the (B, n) weight matrix (268M elements)."""
        from repro.core.bootstrap import _fused_thetas
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: _fused_thetas(v, Mean(), self.B, k), x, key)
        assert biggest < self.B * self.N / 100, (
            f"largest intermediate has {biggest} elements — "
            f"(B, n) would be {self.B * self.N}")

    def test_harness_detects_legacy_weight_matrix(self, key):
        """Sanity: the same harness DOES flag the materialized-W path."""
        from repro.core.bootstrap import weights_for
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: weights_for("poisson", k, self.B, v.shape[0]),
            x, key)
        assert biggest >= self.B * self.N

    def test_quantile_scatter_never_builds_onehot(self, key):
        n, d, nbins = 1 << 15, 2, 2048
        q = Quantile(0.5, nbins=nbins)
        x = jnp.zeros((n, d), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v: q.update(q.init_state(d), v).counts, x)
        assert biggest < n * d * nbins / 100, (
            f"largest intermediate has {biggest} elements — "
            f"one_hot would be {n * d * nbins}")


# ----------------------------------------------------------------------------
# fused moments vs oracles
# ----------------------------------------------------------------------------
class TestFusedMoments:
    @pytest.mark.parametrize("B,n,d", [
        (1, 8, 1), (7, 130, 5), (32, 1000, 1), (64, 2048, 3), (129, 700, 2),
    ])
    def test_matches_implicit_weights_oracle(self, key, B, n, d):
        """Fused output == contracting the materialized implicit weights."""
        x = jax.random.normal(key, (n, d))
        W = ws_ops.implicit_weights(42, B, n)
        wt_r, s1_r, s2_r = weighted_moments_ref(W, x)
        for backend in ("scan", "pallas_interpret"):
            wt, s1, s2 = ws_ops.fused_poisson_moments(42, x, B,
                                                      backend=backend)
            np.testing.assert_allclose(wt, wt_r[:, 0], rtol=1e-6)
            # tile-sequential accumulation != one big dot, so f32 tolerance
            np.testing.assert_allclose(s1, s1_r, rtol=5e-4, atol=1e-4)
            np.testing.assert_allclose(s2, s2_r, rtol=5e-4, atol=1e-4)

    def test_implicit_weights_bit_identical_to_poisson_counts(self):
        """The fast jnp materializer must reproduce the kernel tile
        discipline exactly (same threefry folds, same ladder)."""
        from repro.kernels.poisson_counts import ops as pc_ops
        for B, n in [(5, 100), (129, 1000), (64, 512)]:
            a = ws_ops.implicit_weights(13, B, n)
            b = pc_ops.poisson_counts(13, B, n, backend="pallas_interpret")
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_scan_equals_interpret(self, key):
        x = jax.random.normal(key, (900, 4))
        a = ws_ops.fused_poisson_moments(9, x, 48, backend="scan")
        b = ws_ops.fused_poisson_moments(9, x, 48,
                                         backend="pallas_interpret")
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-6)

    def test_deterministic_and_seed_sensitive(self, key):
        x = jax.random.normal(key, (512,))
        a = ws_ops.fused_poisson_moments(5, x, 32)
        b = ws_ops.fused_poisson_moments(5, x, 32)
        c = ws_ops.fused_poisson_moments(6, x, 32)
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        assert not np.array_equal(np.asarray(a[1]), np.asarray(c[1]))

    def test_n_valid_masks_padding(self, key):
        """Zero-padded tail + n_valid == the unpadded computation."""
        n, pad = 700, 1024 - 700
        x = jax.random.normal(key, (n, 2))
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        a = ws_ops.fused_poisson_moments(3, x, 16)
        b = ws_ops.fused_poisson_moments(3, xp, 16, n_valid=n)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                                   rtol=1e-6)


class TestInKernelWeightStatistics:
    def test_moments_match_poisson1(self):
        """mean/var of in-kernel weights vs jax.random.poisson."""
        W = ws_ops.implicit_weights(7, 256, 4096)
        ref = jax.random.poisson(jax.random.PRNGKey(7), 1.0,
                                 (256, 4096)).astype(jnp.float32)
        assert abs(float(W.mean()) - float(ref.mean())) < 0.02
        assert abs(float(W.var()) - float(ref.var())) < 0.03
        assert abs(float(W.mean()) - 1.0) < 0.01
        assert abs(float(W.var()) - 1.0) < 0.02

    def test_bootstrap_fused_matches_oracle_distributionally(self, key):
        """bootstrap(..., backend="fused_rng") thetas match the
        jax.random.poisson oracle: same SE scale, same CLT prediction."""
        n = 4000
        x = jax.random.normal(key, (n,)) * 3.0 + 50.0
        r_oracle = bootstrap(x, Mean(), B=256, key=key, engine="poisson")
        r_fused = bootstrap(x, Mean(), B=256, key=key, backend="fused_rng")
        clt = float(jnp.std(x) / jnp.sqrt(n))
        assert abs(r_fused.report.se - clt) / clt < 0.25
        assert abs(r_fused.cv - r_oracle.cv) / r_oracle.cv < 0.5
        np.testing.assert_allclose(np.ravel(r_fused.estimate),
                                   np.ravel(r_oracle.estimate), rtol=1e-5)

    @pytest.mark.parametrize("stat_cls", [Mean, Var, Std])
    def test_fused_stats_agree_with_legacy(self, key, stat_cls):
        x = jax.random.normal(key, (2048,)) * 1.5 + 4
        r_jnp = bootstrap(x, stat_cls(), B=64, key=key)
        r_fus = bootstrap(x, stat_cls(), B=64, key=key, backend="fused_rng")
        assert abs(r_fus.cv - r_jnp.cv) / (abs(r_jnp.cv) + 1e-12) < 0.6
        np.testing.assert_allclose(np.ravel(r_fus.estimate),
                                   np.ravel(r_jnp.estimate), rtol=1e-5)

    def test_fused_requires_poisson_engine(self, key):
        with pytest.raises(ValueError):
            bootstrap(jnp.ones(32), Mean(), B=4, key=key,
                      engine="multinomial", backend="fused_rng")

    def test_non_moment_stat_falls_back(self, key):
        """Quantile has no moment decomposition: fused_rng still works via
        the implicit-weights fallback and matches its own oracle."""
        x = jax.random.normal(key, (1000,)) + 5
        q = Quantile(0.5, nbins=512, lo=0.0, hi=10.0)
        r = bootstrap(x, q, B=16, key=key, backend="fused_rng")
        assert np.isfinite(r.cv)
        assert abs(float(np.ravel(r.estimate)[0]) - 5.0) < 0.3


# ----------------------------------------------------------------------------
# delta maintenance + chunked + ssabe under the fused backend
# ----------------------------------------------------------------------------
class TestFusedDelta:
    def test_extend_exact_vs_explicit_weights(self, key):
        """poisson_delta_extend under fused_rng == updating with the
        materialized implicit weights of each step (bit-level key
        discipline: seed_i = offset_seed(seed_from_key(key), i), distinct
        per step by construction and int32-overflow-safe)."""
        from repro.core.bootstrap import offset_seed
        B = 32
        x = jax.random.normal(key, (900, 2))
        pieces = (x[:400], x[400:])

        pd = poisson_delta_init(Mean(), B, 2, key, backend="fused_rng")
        for piece in pieces:
            pd = poisson_delta_extend(pd, piece)
        thetas = poisson_delta_result(pd, Mean()(x)).thetas

        stat = Mean()
        states = jax.vmap(lambda _: stat.init_state(2))(jnp.arange(B))
        for step, piece in enumerate(pieces):
            w = ws_ops.implicit_weights(
                offset_seed(seed_from_key(key), step), B, piece.shape[0])
            states = jax.vmap(lambda s, wr: stat.update(s, piece, wr),
                              in_axes=(0, 0))(states, w)
        ref = jax.vmap(stat.finalize)(states)
        np.testing.assert_allclose(np.asarray(thetas), np.asarray(ref),
                                   rtol=1e-5)

    def test_cv_comparable_to_jnp_backend(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 9
        res = {}
        for backend in (None, "fused_rng"):
            pd = poisson_delta_init(Mean(), 128, 1, key, backend=backend)
            for piece in (x[:1000], x[1000:]):
                pd = poisson_delta_extend(pd, piece)
            res[backend] = poisson_delta_result(pd, Mean()(x)).cv
        assert abs(res["fused_rng"] - res[None]) / res[None] < 0.5


class TestFusedChunked:
    def test_matches_unchunked_distribution(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 5
        r_plain = bootstrap(x, Mean(), B=128, key=key, backend="fused_rng")
        r_chunk = bootstrap_chunked(x, Mean(), B=128, key=key, chunk=512,
                                    backend="fused_rng")
        assert abs(r_plain.cv - r_chunk.cv) / r_plain.cv < 0.5
        np.testing.assert_allclose(np.ravel(r_plain.estimate),
                                   np.ravel(r_chunk.estimate), rtol=1e-5)

    def test_ragged_tail_masked(self, key):
        """w_tot must ignore the zero-padded tail of the last chunk."""
        x = jax.random.normal(key, (1001,)) + 3.0
        r = bootstrap_chunked(x, Mean(), B=32, key=key, chunk=256,
                              backend="fused_rng")
        assert r.n == 1001
        assert np.isfinite(r.cv)
        assert abs(float(np.ravel(r.estimate)[0]) - 3.0) < 0.3


class TestFusedSSABE:
    def test_ssabe_fused_close_to_jnp(self, key):
        x = jax.random.normal(key, (1000,)) + 5
        r_jnp = ssabe(x, Mean(), sigma=0.05, tau=0.01, key=key)
        r_fus = ssabe(x, Mean(), sigma=0.05, tau=0.01, key=key,
                      backend="fused_rng")
        assert len(r_fus.cv_history_n) == 5
        # same stopping structure, comparable estimates
        assert r_fus.B <= r_jnp.B * 4 and r_jnp.B <= r_fus.B * 4


# ----------------------------------------------------------------------------
# histogram sketch / Quantile
# ----------------------------------------------------------------------------
class TestWeightedHist:
    @pytest.mark.parametrize("n,d,nbins", [
        (100, 1, 128), (515, 3, 256), (1000, 5, 2048),
        (300, 2, 2000),   # nbins not a 128 multiple: lane padding must
                          # not shift bin edges or drop top-bin mass
    ])
    def test_kernel_and_scatter_match_onehot_oracle(self, key, n, d, nbins):
        x = jax.random.uniform(key, (n, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
        lo, hi = jnp.zeros((d,)), jnp.ones((d,))
        ref = weighted_hist_onehot_ref(x, w, lo, hi, nbins)
        np.testing.assert_allclose(
            np.asarray(weighted_hist_scatter_ref(x, w, lo, hi, nbins)),
            np.asarray(ref), rtol=1e-5, atol=1e-5)
        for backend in ("jnp", "pallas_interpret"):
            out = wh_ops.weighted_histogram(x, w, lo, hi, nbins,
                                            backend=backend)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-4)

    def test_quantile_update_matches_onehot_oracle(self, key):
        q = Quantile(0.5, nbins=256, lo=-4.0, hi=4.0)
        x = jax.random.normal(key, (777, 2))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (777,)))
        st = q.update(q.init_state(2), x, w)
        ref = weighted_hist_onehot_ref(
            jnp.clip(x, -4.0, 4.0), w, jnp.full((2,), -4.0),
            jnp.full((2,), 4.0), 256)
        np.testing.assert_allclose(np.asarray(st.counts), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    def test_quantile_kernel_backend_matches_default(self, key):
        x = jax.random.normal(key, (513,)) * 2
        for backend in ("pallas_interpret",):
            q0 = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0)
            qk = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0, backend=backend)
            s0 = q0.update(q0.init_state(1), x)
            sk = qk.update(qk.init_state(1), x)
            np.testing.assert_allclose(np.asarray(sk.counts),
                                       np.asarray(s0.counts),
                                       rtol=1e-5, atol=1e-4)
            np.testing.assert_allclose(float(q0.finalize(s0)),
                                       float(qk.finalize(sk)), rtol=1e-6)

    def test_quantile_vmaps_over_bootstrap_axis(self, key):
        """The scatter path must batch over the B resample axis."""
        x = jax.random.normal(key, (800,)) + 7
        q = Quantile(0.5, nbins=512, lo=0.0, hi=14.0)
        r = bootstrap(x, q, B=24, key=key)
        assert r.thetas.shape[0] == 24
        assert abs(float(np.ravel(r.estimate)[0]) - 7.0) < 0.2


class TestMultinomialScatter:
    def test_single_dispatch_matches_per_row_oracle(self, key):
        """The flattened scatter must equal the old per-row vmap(hist)."""
        B, n = 16, 257
        counts = multinomial_counts(key, B=B, n=n)
        idx = jax.random.randint(key, (B, n), 0, n)

        def hist(row):
            return jnp.zeros((n,), jnp.int32).at[row].add(1)

        ref = jax.vmap(hist)(idx)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))
