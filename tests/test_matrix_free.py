"""Matrix-free bootstrap: in-kernel RNG fused moments + histogram sketch.

Covers the ISSUE-1 acceptance criteria:
  * fused moments == materialized implicit-weights oracle (all backends)
  * in-kernel Poisson(1) weights are statistically sound (mean/var, and the
    fused bootstrap matches the jax.random.poisson oracle distributionally)
  * poisson_delta_extend stays exact under backend="fused_rng"
  * shape-capture harness: the fused pipeline at n=2^20, B=256 contains NO
    (B, n)-sized intermediate anywhere in its jaxpr (and the harness itself
    is validated against the legacy path, which does contain one)
  * Quantile scatter-add path == one_hot+einsum oracle == Pallas sketch
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Mean, Quantile, Std, Var, bootstrap,
                        bootstrap_chunked, multinomial_counts)
from repro.core.bootstrap import seed_from_key
from repro.core.delta import (poisson_delta_extend, poisson_delta_init,
                              poisson_delta_result)
from repro.core.reduce_api import _as_2d
from repro.core.ssabe import ssabe
from repro.kernels.weighted_hist import ops as wh_ops
from repro.kernels.weighted_hist.ref import (weighted_hist_onehot_ref,
                                             weighted_hist_scatter_ref)
from repro.kernels.weighted_stats import ops as ws_ops
from repro.kernels.weighted_stats.ref import weighted_moments_ref


# ----------------------------------------------------------------------------
# jaxpr shape-capture harness
# ----------------------------------------------------------------------------
def _walk_shapes(jaxpr, out):
    """Collect every intermediate aval shape, recursing into sub-jaxprs
    (pjit/scan/pallas_call bodies)."""
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(tuple(aval.shape))
        for p in eqn.params.values():
            for q in (p if isinstance(p, (tuple, list)) else (p,)):
                if hasattr(q, "jaxpr") and hasattr(q.jaxpr, "eqns"):
                    _walk_shapes(q.jaxpr, out)       # ClosedJaxpr
                elif hasattr(q, "eqns"):
                    _walk_shapes(q, out)             # raw Jaxpr
    return out


def _max_intermediate_size(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    shapes = _walk_shapes(jaxpr.jaxpr, [])
    return max((int(np.prod(s)) for s in shapes if s), default=0)


class TestNoWeightMatrix:
    B, N = 256, 1 << 20

    def test_fused_pipeline_never_builds_Bn(self, key):
        """n=2^20, B=256: every intermediate in the traced fused pipeline is
        far smaller than the (B, n) weight matrix (268M elements)."""
        from repro.core.bootstrap import _fused_thetas
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: _fused_thetas(v, Mean(), self.B, k), x, key)
        assert biggest < self.B * self.N / 100, (
            f"largest intermediate has {biggest} elements — "
            f"(B, n) would be {self.B * self.N}")

    def test_harness_detects_legacy_weight_matrix(self, key):
        """Sanity: the same harness DOES flag the materialized-W path."""
        from repro.core.bootstrap import weights_for
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: weights_for("poisson", k, self.B, v.shape[0]),
            x, key)
        assert biggest >= self.B * self.N

    def test_quantile_scatter_never_builds_onehot(self, key):
        n, d, nbins = 1 << 15, 2, 2048
        q = Quantile(0.5, nbins=nbins)
        x = jnp.zeros((n, d), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v: q.update(q.init_state(d), v).counts, x)
        assert biggest < n * d * nbins / 100, (
            f"largest intermediate has {biggest} elements — "
            f"one_hot would be {n * d * nbins}")

    def test_quantile_fused_pipeline_never_builds_Bn_or_onehot(self, key):
        """ISSUE-3 acceptance: the fused Quantile bootstrap at n=2^20,
        B=256 allocates neither the (B, n) weight matrix (268M elements)
        nor any (n, d, nbins) one-hot (2.1G elements) — the largest
        intermediate is the per-tile one-hot plus the (B, d, nbins)
        sketch."""
        from repro.core.bootstrap import _fused_thetas
        nbins = 2048
        q = Quantile(0.5, nbins=nbins, lo=-8.0, hi=8.0)
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: _fused_thetas(v, q, self.B, k), x, key)
        assert biggest < self.B * self.N / 100, (
            f"largest intermediate has {biggest} elements — "
            f"(B, n) would be {self.B * self.N}")
        assert biggest < self.N * nbins / 100, (
            f"largest intermediate has {biggest} elements — "
            f"(n, d, nbins) would be {self.N * nbins}")


# ----------------------------------------------------------------------------
# fused moments vs oracles
# ----------------------------------------------------------------------------
class TestFusedMoments:
    @pytest.mark.parametrize("B,n,d", [
        (1, 8, 1), (7, 130, 5), (32, 1000, 1), (64, 2048, 3), (129, 700, 2),
    ])
    def test_matches_implicit_weights_oracle(self, key, B, n, d):
        """Fused output == contracting the materialized implicit weights."""
        x = jax.random.normal(key, (n, d))
        W = ws_ops.implicit_weights(42, B, n)
        wt_r, s1_r, s2_r = weighted_moments_ref(W, x)
        for backend in ("scan", "pallas_interpret"):
            wt, s1, s2 = ws_ops.fused_poisson_moments(42, x, B,
                                                      backend=backend)
            np.testing.assert_allclose(wt, wt_r[:, 0], rtol=1e-6)
            # tile-sequential accumulation != one big dot, so f32 tolerance
            np.testing.assert_allclose(s1, s1_r, rtol=5e-4, atol=1e-4)
            np.testing.assert_allclose(s2, s2_r, rtol=5e-4, atol=1e-4)

    def test_implicit_weights_bit_identical_to_poisson_counts(self):
        """The fast jnp materializer must reproduce the kernel tile
        discipline exactly (same threefry folds, same ladder)."""
        from repro.kernels.poisson_counts import ops as pc_ops
        for B, n in [(5, 100), (129, 1000), (64, 512)]:
            a = ws_ops.implicit_weights(13, B, n)
            b = pc_ops.poisson_counts(13, B, n, backend="pallas_interpret")
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_scan_equals_interpret(self, key):
        x = jax.random.normal(key, (900, 4))
        a = ws_ops.fused_poisson_moments(9, x, 48, backend="scan")
        b = ws_ops.fused_poisson_moments(9, x, 48,
                                         backend="pallas_interpret")
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-6)

    def test_deterministic_and_seed_sensitive(self, key):
        x = jax.random.normal(key, (512,))
        a = ws_ops.fused_poisson_moments(5, x, 32)
        b = ws_ops.fused_poisson_moments(5, x, 32)
        c = ws_ops.fused_poisson_moments(6, x, 32)
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        assert not np.array_equal(np.asarray(a[1]), np.asarray(c[1]))

    def test_n_valid_masks_padding(self, key):
        """Zero-padded tail + n_valid == the unpadded computation."""
        n, pad = 700, 1024 - 700
        x = jax.random.normal(key, (n, 2))
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        a = ws_ops.fused_poisson_moments(3, x, 16)
        b = ws_ops.fused_poisson_moments(3, xp, 16, n_valid=n)
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                                   rtol=1e-6)


class TestInKernelWeightStatistics:
    def test_moments_match_poisson1(self):
        """mean/var of in-kernel weights vs jax.random.poisson."""
        W = ws_ops.implicit_weights(7, 256, 4096)
        ref = jax.random.poisson(jax.random.PRNGKey(7), 1.0,
                                 (256, 4096)).astype(jnp.float32)
        assert abs(float(W.mean()) - float(ref.mean())) < 0.02
        assert abs(float(W.var()) - float(ref.var())) < 0.03
        assert abs(float(W.mean()) - 1.0) < 0.01
        assert abs(float(W.var()) - 1.0) < 0.02

    def test_bootstrap_fused_matches_oracle_distributionally(self, key):
        """bootstrap(..., backend="fused_rng") thetas match the
        jax.random.poisson oracle: same SE scale, same CLT prediction."""
        n = 4000
        x = jax.random.normal(key, (n,)) * 3.0 + 50.0
        r_oracle = bootstrap(x, Mean(), B=256, key=key, engine="poisson")
        r_fused = bootstrap(x, Mean(), B=256, key=key, backend="fused_rng")
        clt = float(jnp.std(x) / jnp.sqrt(n))
        assert abs(r_fused.report.se - clt) / clt < 0.25
        assert abs(r_fused.cv - r_oracle.cv) / r_oracle.cv < 0.5
        np.testing.assert_allclose(np.ravel(r_fused.estimate),
                                   np.ravel(r_oracle.estimate), rtol=1e-5)

    @pytest.mark.parametrize("stat_cls", [Mean, Var, Std])
    def test_fused_stats_agree_with_legacy(self, key, stat_cls):
        x = jax.random.normal(key, (2048,)) * 1.5 + 4
        r_jnp = bootstrap(x, stat_cls(), B=64, key=key)
        r_fus = bootstrap(x, stat_cls(), B=64, key=key, backend="fused_rng")
        assert abs(r_fus.cv - r_jnp.cv) / (abs(r_jnp.cv) + 1e-12) < 0.6
        np.testing.assert_allclose(np.ravel(r_fus.estimate),
                                   np.ravel(r_jnp.estimate), rtol=1e-5)

    def test_fused_requires_poisson_engine(self, key):
        with pytest.raises(ValueError):
            bootstrap(jnp.ones(32), Mean(), B=4, key=key,
                      engine="multinomial", backend="fused_rng")

    def test_custom_stat_falls_back(self, key):
        """A statistic WITHOUT a fused path (every built-in now has one)
        still works under fused_rng via the implicit-weights fallback."""
        from repro.core.reduce_api import Mean

        class NoFusedMean(Mean):
            def fused_poisson_states(self, seed, values, B, n_valid=None):
                return None

        x = jax.random.normal(key, (1000,)) + 5
        r_fb = bootstrap(x, NoFusedMean(), B=16, key=key,
                         backend="fused_rng")
        r_fu = bootstrap(x, Mean(), B=16, key=key, backend="fused_rng")
        # fallback materializes the SAME implicit weights → same thetas
        np.testing.assert_allclose(np.asarray(r_fb.thetas),
                                   np.asarray(r_fu.thetas), rtol=1e-5)


# ----------------------------------------------------------------------------
# delta maintenance + chunked + ssabe under the fused backend
# ----------------------------------------------------------------------------
class TestFusedDelta:
    def test_extend_exact_vs_explicit_weights(self, key):
        """poisson_delta_extend under fused_rng == updating with the
        materialized implicit weights of each step (bit-level key
        discipline: seed_i = offset_seed(seed_from_key(key), i), distinct
        per step by construction and int32-overflow-safe)."""
        from repro.core.bootstrap import offset_seed
        B = 32
        x = jax.random.normal(key, (900, 2))
        pieces = (x[:400], x[400:])

        pd = poisson_delta_init(Mean(), B, 2, key, backend="fused_rng")
        for piece in pieces:
            pd = poisson_delta_extend(pd, piece)
        thetas = poisson_delta_result(pd, Mean()(x)).thetas

        stat = Mean()
        states = jax.vmap(lambda _: stat.init_state(2))(jnp.arange(B))
        for step, piece in enumerate(pieces):
            w = ws_ops.implicit_weights(
                offset_seed(seed_from_key(key), step), B, piece.shape[0])
            states = jax.vmap(lambda s, wr: stat.update(s, piece, wr),
                              in_axes=(0, 0))(states, w)
        ref = jax.vmap(stat.finalize)(states)
        np.testing.assert_allclose(np.asarray(thetas), np.asarray(ref),
                                   rtol=1e-5)

    def test_cv_comparable_to_jnp_backend(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 9
        res = {}
        for backend in (None, "fused_rng"):
            pd = poisson_delta_init(Mean(), 128, 1, key, backend=backend)
            for piece in (x[:1000], x[1000:]):
                pd = poisson_delta_extend(pd, piece)
            res[backend] = poisson_delta_result(pd, Mean()(x)).cv
        assert abs(res["fused_rng"] - res[None]) / res[None] < 0.5


class TestFusedChunked:
    def test_matches_unchunked_distribution(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 5
        r_plain = bootstrap(x, Mean(), B=128, key=key, backend="fused_rng")
        r_chunk = bootstrap_chunked(x, Mean(), B=128, key=key, chunk=512,
                                    backend="fused_rng")
        assert abs(r_plain.cv - r_chunk.cv) / r_plain.cv < 0.5
        np.testing.assert_allclose(np.ravel(r_plain.estimate),
                                   np.ravel(r_chunk.estimate), rtol=1e-5)

    def test_ragged_tail_masked(self, key):
        """w_tot must ignore the zero-padded tail of the last chunk."""
        x = jax.random.normal(key, (1001,)) + 3.0
        r = bootstrap_chunked(x, Mean(), B=32, key=key, chunk=256,
                              backend="fused_rng")
        assert r.n == 1001
        assert np.isfinite(r.cv)
        assert abs(float(np.ravel(r.estimate)[0]) - 3.0) < 0.3


class TestFusedSSABE:
    def test_ssabe_fused_close_to_jnp(self, key):
        x = jax.random.normal(key, (1000,)) + 5
        r_jnp = ssabe(x, Mean(), sigma=0.05, tau=0.01, key=key)
        r_fus = ssabe(x, Mean(), sigma=0.05, tau=0.01, key=key,
                      backend="fused_rng")
        assert len(r_fus.cv_history_n) == 5
        # same stopping structure, comparable estimates
        assert r_fus.B <= r_jnp.B * 4 and r_jnp.B <= r_fus.B * 4


# ----------------------------------------------------------------------------
# histogram sketch / Quantile
# ----------------------------------------------------------------------------
class TestWeightedHist:
    @pytest.mark.parametrize("n,d,nbins", [
        (100, 1, 128), (515, 3, 256), (1000, 5, 2048),
        (300, 2, 2000),   # nbins not a 128 multiple: lane padding must
                          # not shift bin edges or drop top-bin mass
    ])
    def test_kernel_and_scatter_match_onehot_oracle(self, key, n, d, nbins):
        x = jax.random.uniform(key, (n, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
        lo, hi = jnp.zeros((d,)), jnp.ones((d,))
        ref = weighted_hist_onehot_ref(x, w, lo, hi, nbins)
        np.testing.assert_allclose(
            np.asarray(weighted_hist_scatter_ref(x, w, lo, hi, nbins)),
            np.asarray(ref), rtol=1e-5, atol=1e-5)
        for backend in ("jnp", "pallas_interpret"):
            out = wh_ops.weighted_histogram(x, w, lo, hi, nbins,
                                            backend=backend)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-4)

    def test_quantile_update_matches_onehot_oracle(self, key):
        q = Quantile(0.5, nbins=256, lo=-4.0, hi=4.0)
        x = jax.random.normal(key, (777, 2))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (777,)))
        st = q.update(q.init_state(2), x, w)
        ref = weighted_hist_onehot_ref(
            jnp.clip(x, -4.0, 4.0), w, jnp.full((2,), -4.0),
            jnp.full((2,), 4.0), 256)
        np.testing.assert_allclose(np.asarray(st.counts), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    def test_quantile_kernel_backend_matches_default(self, key):
        x = jax.random.normal(key, (513,)) * 2
        for backend in ("pallas_interpret",):
            q0 = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0)
            qk = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0, backend=backend)
            s0 = q0.update(q0.init_state(1), x)
            sk = qk.update(qk.init_state(1), x)
            np.testing.assert_allclose(np.asarray(sk.counts),
                                       np.asarray(s0.counts),
                                       rtol=1e-5, atol=1e-4)
            np.testing.assert_allclose(float(q0.finalize(s0)),
                                       float(qk.finalize(sk)), rtol=1e-6)

    def test_quantile_vmaps_over_bootstrap_axis(self, key):
        """The scatter path must batch over the B resample axis."""
        x = jax.random.normal(key, (800,)) + 7
        q = Quantile(0.5, nbins=512, lo=0.0, hi=14.0)
        r = bootstrap(x, q, B=24, key=key)
        assert r.thetas.shape[0] == 24
        assert abs(float(np.ravel(r.estimate)[0]) - 7.0) < 0.2


class TestFusedQuantile:
    """Quantile's fused_poisson_states: the last materialized fallback in
    fused_resample_states is gone — the histogram sketch accumulates under
    in-kernel Poisson(1) weights."""

    @pytest.mark.parametrize("B,n,d,nbins", [
        (1, 8, 1, 128), (7, 300, 2, 256), (32, 1000, 1, 2048),
        (129, 700, 3, 200),   # nbins not a 128 multiple: lane padding
    ])
    def test_matches_implicit_weights_oracle(self, key, B, n, d, nbins):
        """Fused sketch == scatter-adding the materialized implicit
        weights, on both lowerings."""
        x = jax.random.uniform(key, (n, d))
        lo, hi = jnp.zeros((d,)), jnp.ones((d,))
        W = ws_ops.implicit_weights(42, B, n)
        ref = jnp.stack([weighted_hist_scatter_ref(x, W[b], lo, hi, nbins)
                         for b in range(B)])
        for backend in ("scan", "pallas_interpret"):
            out = wh_ops.fused_poisson_hist(42, x, lo, hi, nbins, B,
                                            backend=backend)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-4)

    def test_bootstrap_fused_matches_materialized_fallback(self, key):
        """bootstrap(Quantile, fused_rng) == vmapped scatter updates under
        the SAME implicit weights (the pre-ISSUE-3 fallback semantics)."""
        x = jax.random.normal(key, (1000,)) + 5
        q = Quantile(0.5, nbins=512, lo=0.0, hi=10.0)
        r = bootstrap(x, q, B=16, key=key, backend="fused_rng")
        assert np.isfinite(r.cv)
        assert abs(float(np.ravel(r.estimate)[0]) - 5.0) < 0.3
        from repro.core.bootstrap import seed_from_key
        W = ws_ops.implicit_weights(seed_from_key(key), 16, 1000)
        x2 = x[:, None]
        ref = jax.vmap(lambda wr: q.finalize(
            q.update(q.init_state(1), x2, wr)))(W)
        np.testing.assert_allclose(np.asarray(r.thetas), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_n_valid_masks_padding(self, key):
        """Without the n_valid column mask the zero-padded tail would land
        spurious mass in bin 0 of every resample."""
        n, pad = 700, 1024 - 700
        x = jax.random.uniform(key, (n, 1)) * 0.9 + 0.05
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        a = wh_ops.fused_poisson_hist(3, x, 0.0, 1.0, 128, 16)
        b = wh_ops.fused_poisson_hist(3, xp, 0.0, 1.0, 128, 16, n_valid=n)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_chunked_quantile_streams_through_sketch(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 5
        q = Quantile(0.5, nbins=1024, lo=-5.0, hi=15.0)
        r_plain = bootstrap(x, q, B=64, key=key, backend="fused_rng")
        r_chunk = bootstrap_chunked(x, q, B=64, key=key, chunk=512,
                                    backend="fused_rng")
        assert abs(float(np.ravel(r_chunk.estimate)[0]) - 5.0) < 0.3
        assert np.isfinite(r_chunk.cv)
        assert abs(r_plain.cv - r_chunk.cv) / (r_plain.cv + 1e-12) < 1.0

    def test_delta_maintenance_fused_quantile(self, key):
        """poisson_delta_extend(Quantile, fused_rng) == scatter updates
        with the per-step materialized implicit weights."""
        from repro.core.bootstrap import offset_seed
        B = 16
        q = Quantile(0.5, nbins=256, lo=-5.0, hi=5.0)
        x = jax.random.normal(key, (900, 1))
        pieces = (x[:400], x[400:])
        pd = poisson_delta_init(q, B, 1, key, backend="fused_rng")
        for piece in pieces:
            pd = poisson_delta_extend(pd, piece)
        thetas = poisson_delta_result(pd, q(x)).thetas

        states = jax.vmap(lambda _: q.init_state(1))(jnp.arange(B))
        for step, piece in enumerate(pieces):
            w = ws_ops.implicit_weights(
                offset_seed(seed_from_key(key), step), B, piece.shape[0])
            states = jax.vmap(lambda s, wr: q.update(s, piece, wr),
                              in_axes=(0, 0))(states, w)
        ref = jax.vmap(q.finalize)(states)
        np.testing.assert_allclose(np.asarray(thetas), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_quantile_backend_routes_fused_kernel(self, key):
        """Quantile(backend="pallas_interpret") routes the fused sketch
        kernel; result matches the default scan lowering."""
        x = jax.random.normal(key, (513,)) * 2
        q0 = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0)
        qk = Quantile(0.25, nbins=512, lo=-8.0, hi=8.0,
                      backend="pallas_interpret")
        s0 = q0.fused_poisson_states(11, x[:, None], 8)
        sk = qk.fused_poisson_states(11, x[:, None], 8)
        np.testing.assert_allclose(np.asarray(sk.counts),
                                   np.asarray(s0.counts),
                                   rtol=1e-5, atol=1e-4)


class TestBinBlockedHist:
    """ROADMAP TPU-tiling knob: ``block_bins`` tiles the d·nbins OUTPUT
    axis of the fused hist kernel so one (block_b, block_bins) window is
    VMEM-resident per grid cell instead of the whole (block_b, d·out_bins)
    block.  Results must be identical to the untiled kernel and the scan
    lowering — the weight tile keying is (seed, b-tile, n-tile) only."""

    @pytest.mark.parametrize("n,d,nbins,block_bins", [
        (700, 2, 256, 128),    # 2 output blocks per dim
        (513, 3, 300, 128),    # nbins not a block multiple: 3 blocks
        (1000, 1, 512, 256),   # d=1 (dim-blocking alone could not tile)
    ])
    def test_interpret_matches_scan_with_multiple_output_blocks(
            self, key, n, d, nbins, block_bins):
        out_bins = nbins + (-nbins) % block_bins
        assert out_bins // block_bins >= 2, "shape must exercise >=2 blocks"
        x = jax.random.uniform(key, (n, d)) * 0.9 + 0.05
        ref = wh_ops.fused_poisson_hist(42, x, 0.0, 1.0, nbins, 16,
                                        backend="scan")
        out = wh_ops.fused_poisson_hist(42, x, 0.0, 1.0, nbins, 16,
                                        backend="pallas_interpret",
                                        block_bins=block_bins)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    def test_matches_untiled_kernel_and_masks_padding(self, key):
        n, pad = 700, 1024 - 700
        x = jax.random.uniform(key, (n, 1)) * 0.9 + 0.05
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        untiled = wh_ops.fused_poisson_hist(3, x, 0.0, 1.0, 256, 16,
                                            backend="pallas_interpret")
        tiled = wh_ops.fused_poisson_hist(3, xp, 0.0, 1.0, 256, 16,
                                          n_valid=n,
                                          backend="pallas_interpret",
                                          block_bins=128)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(untiled),
                                   rtol=1e-6)


class TestHistEdgePolicy:
    """Out-of-range/NaN policy (clip into edge bins, drop NaN), identical
    across scatter ref, one-hot oracle, Pallas sketch and fused paths."""

    def _all_paths(self, x, w, lo, hi, nbins):
        d = x.shape[1]
        lo = jnp.full((d,), lo, jnp.float32)
        hi = jnp.full((d,), hi, jnp.float32)
        yield "scatter", weighted_hist_scatter_ref(x, w, lo, hi, nbins)
        yield "onehot", weighted_hist_onehot_ref(x, w, lo, hi, nbins)
        yield "kernel", wh_ops.weighted_histogram(
            x, w, lo, hi, nbins, backend="pallas_interpret")

    def test_upper_edge_lands_in_top_bin(self):
        """x == hi exactly must keep its mass (top bin), not be dropped —
        on every path."""
        x = jnp.array([[0.0], [0.5], [1.0]])
        w = jnp.ones((3,))
        for name, counts in self._all_paths(x, w, 0.0, 1.0, 4):
            counts = np.asarray(counts)
            assert counts[0, -1] == 1.0, name        # x == hi → top bin
            assert counts[0, 0] == 1.0, name         # x == lo → bin 0
            assert counts.sum() == 3.0, name

    def test_out_of_range_clips_including_inf(self):
        x = jnp.array([[-7.0], [2.5], [jnp.inf], [-jnp.inf]])
        w = jnp.ones((4,))
        for name, counts in self._all_paths(x, w, 0.0, 1.0, 8):
            counts = np.asarray(counts)
            assert counts[0, 0] == 2.0, name         # -7, -inf → bin 0
            assert counts[0, -1] == 2.0, name        # 2.5, +inf → top bin
            assert counts.sum() == 4.0, name

    def test_nan_mass_dropped_everywhere(self, key):
        x = jax.random.uniform(key, (64, 2))
        x = x.at[3, 0].set(jnp.nan).at[17, 1].set(jnp.nan)
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (64,)))
        outs = dict(self._all_paths(x, w, 0.0, 1.0, 32))
        total = float(jnp.sum(w) * 2 - w[3] - w[17])
        for name, counts in outs.items():
            counts = np.asarray(counts)
            assert np.isfinite(counts).all(), name
            np.testing.assert_allclose(counts.sum(), total, rtol=1e-5,
                                       err_msg=name)
        np.testing.assert_allclose(np.asarray(outs["scatter"]),
                                   np.asarray(outs["onehot"]), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(outs["kernel"]),
                                   np.asarray(outs["onehot"]), rtol=1e-5,
                                   atol=1e-4)

    def test_nan_dropped_in_fused_sketch(self, key):
        """The fused bootstrap sketch must drop NaN mass identically on
        both lowerings (f32→int32 NaN casts are platform-defined — only
        the mask keeps this deterministic)."""
        x = jax.random.uniform(key, (300, 1))
        x = x.at[5, 0].set(jnp.nan)
        outs = [wh_ops.fused_poisson_hist(9, x, 0.0, 1.0, 64, 8,
                                          backend=b)
                for b in ("scan", "pallas_interpret")]
        W = np.asarray(ws_ops.implicit_weights(9, 8, 300))
        expect = W.sum(axis=1) - W[:, 5]             # row totals minus NaN
        for out in outs:
            assert np.isfinite(np.asarray(out)).all()
            np.testing.assert_allclose(np.asarray(out).sum(axis=(1, 2)),
                                       expect, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(outs[0]),
                                   np.asarray(outs[1]), rtol=1e-6)

    def test_quantile_update_drops_nan(self, key):
        q = Quantile(0.5, nbins=64, lo=0.0, hi=1.0)
        x = jnp.array([0.2, jnp.nan, 0.8])
        st = q.update(q.init_state(1), x)
        assert float(np.asarray(st.counts).sum()) == 2.0
        assert np.isfinite(float(q.finalize(st)))


class TestBf16Moments:
    """ROADMAP bf16 study: x/w enter the dots in bf16, accumulators f32."""

    def test_close_to_f32_and_wtot_exact(self, key):
        x = jax.random.normal(key, (4096, 4)) * 3 + 7
        wt32, s1_32, s2_32 = ws_ops.fused_poisson_moments(5, x, 64)
        wtbf, s1_bf, s2_bf = ws_ops.fused_poisson_moments(
            5, x, 64, dtype=jnp.bfloat16)
        assert all(a.dtype == jnp.float32 for a in (wtbf, s1_bf, s2_bf))
        # weight totals never touch bf16 — bit-exact
        np.testing.assert_array_equal(np.asarray(wt32), np.asarray(wtbf))
        # bf16 has ~3 decimal digits; summed over tiles the relative error
        # stays well under 1% for n=4096
        np.testing.assert_allclose(np.asarray(s1_bf), np.asarray(s1_32),
                                   rtol=1e-2)
        np.testing.assert_allclose(np.asarray(s2_bf), np.asarray(s2_32),
                                   rtol=1e-2)

    def test_scan_equals_interpret_bf16(self, key):
        x = jax.random.normal(key, (900, 2))
        a = ws_ops.fused_poisson_moments(9, x, 32, backend="scan",
                                         dtype=jnp.bfloat16)
        b = ws_ops.fused_poisson_moments(9, x, 32,
                                         backend="pallas_interpret",
                                         dtype=jnp.bfloat16)
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=2e-3)

    def test_f32_default_unchanged(self, key):
        """dtype defaults to f32 — bit-identical to an explicit f32 ask."""
        x = jax.random.normal(key, (700, 3))
        a = ws_ops.fused_poisson_moments(4, x, 16)
        b = ws_ops.fused_poisson_moments(4, x, 16, dtype=jnp.float32)
        for u, v in zip(a, b):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


class TestShardedOracle:
    """Single-device coverage of sharded_fused_states (the mesh run is
    bit-compared against this oracle in tests/test_sharded_bootstrap.py)."""

    def test_chunk_and_step_mutually_exclusive(self):
        """Stream index (step + c)·nshards + shard aliases across (step,
        chunk) pairs — the combination must raise, not correlate."""
        from repro.core import Mean, sharded_fused_states
        x = jnp.ones((64, 1))
        with pytest.raises(ValueError, match="mutually exclusive"):
            sharded_fused_states(Mean(), 7, x, 8, nshards=2, chunk=16,
                                 step=1)

    def test_nshards1_matches_unsharded(self, key):
        from repro.core import Mean, sharded_fused_states
        from repro.core.bootstrap import fused_resample_states
        x = jax.random.normal(key, (300, 2))
        a = sharded_fused_states(Mean(), 7, x, 16, nshards=1)
        b = fused_resample_states(Mean(), jnp.int32(7), x, 16)
        for u, v in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


class TestMultinomialScatter:
    def test_single_dispatch_matches_per_row_oracle(self, key):
        """The flattened scatter must equal the old per-row vmap(hist)."""
        B, n = 16, 257
        counts = multinomial_counts(key, B=B, n=n)
        idx = jax.random.randint(key, (B, n), 0, n)

        def hist(row):
            return jnp.zeros((n,), jnp.int32).at[row].add(1)

        ref = jax.vmap(hist)(idx)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))
