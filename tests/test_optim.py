"""Optimizer substrate: AdamW, compression, EARL-adaptive accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.adaptive_accum import (earl_accumulate_gradients,
                                        gradient_cv)
from repro.optim.compression import (compress_decompress,
                                     error_feedback_compress, init_residual)


class TestAdamW:
    def test_converges_on_quadratic(self, key):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, m = adamw_update(params, grads, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clipping(self, key):
        params = {"w": jnp.zeros(4)}
        cfg = AdamWConfig(grad_clip=1.0)
        state = adamw_init(params, cfg)
        _, _, metrics = adamw_update(params, {"w": jnp.full(4, 1e6)},
                                     state, cfg)
        assert float(metrics["grad_norm"]) > 1.0   # reported pre-clip

    def test_bf16_states(self, key):
        params = {"w": jnp.zeros(4)}
        cfg = AdamWConfig(state_dtype="bfloat16")
        state = adamw_init(params, cfg)
        assert state.m["w"].dtype == jnp.bfloat16
        p2, s2, _ = adamw_update(params, {"w": jnp.ones(4)}, state, cfg)
        assert s2.v["w"].dtype == jnp.bfloat16


class TestCompression:
    def test_bf16_roundtrip_error_small(self, key):
        g = {"a": jax.random.normal(key, (1000,))}
        gq = compress_decompress(g)
        rel = float(jnp.linalg.norm(gq["a"] - g["a"]) /
                    jnp.linalg.norm(g["a"]))
        assert rel < 0.01

    def test_error_feedback_preserves_sum(self, key):
        """Over many steps, Σ sent ≈ Σ g (residual stays bounded)."""
        g = {"a": jax.random.normal(key, (500,)) * 1e-3}
        res = init_residual(g)
        total_sent = jnp.zeros(500)
        for i in range(50):
            sent, res = error_feedback_compress(g, res)
            total_sent = total_sent + sent["a"].astype(jnp.float32)
        drift = float(jnp.linalg.norm(total_sent - 50 * g["a"]) /
                      jnp.linalg.norm(50 * g["a"]))
        assert drift < 0.01, "error feedback must not lose gradient mass"


class TestAdaptiveAccum:
    def test_stops_early_on_low_variance(self):
        def grad_fn(params, mb):
            g = {"w": jnp.full(8, float(mb))}
            return g, jnp.linalg.norm(g["w"])
        mbs = [1.0 + 1e-4 * i for i in range(16)]     # ~identical grads
        grads, dec = earl_accumulate_gradients(grad_fn, {}, mbs, sigma=0.02)
        assert dec.stop
        assert dec.microbatches_used < 16

    def test_runs_full_on_high_variance(self, rng):
        vals = rng.normal(1.0, 2.0, 16)
        def grad_fn(params, mb):
            g = {"w": jnp.full(8, float(mb))}
            return g, jnp.linalg.norm(g["w"])
        grads, dec = earl_accumulate_gradients(grad_fn, {}, list(vals),
                                               sigma=1e-6)
        assert dec.microbatches_used == 16

    def test_mean_gradient_correct(self):
        def grad_fn(params, mb):
            g = {"w": jnp.full(2, float(mb))}
            return g, jnp.linalg.norm(g["w"])
        mbs = [1.0, 2.0, 3.0, 4.0]
        grads, dec = earl_accumulate_gradients(grad_fn, {}, mbs, sigma=0.0)
        np.testing.assert_allclose(
            np.asarray(grads["w"]),
            np.full(2, np.mean(mbs[:dec.microbatches_used])), rtol=1e-6)

    def test_gradient_cv_decreasing_in_n(self, rng):
        small = gradient_cv(rng.normal(5, 1, 4))
        large = gradient_cv(rng.normal(5, 1, 64))
        assert large < small
