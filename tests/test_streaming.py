"""Streaming bootstrap driver + counted-store iteration.

The contracts under test:

* ``bootstrap_streaming`` is BITWISE equal to ``bootstrap_chunked`` over
  ``store.read_all()`` under the same (key, chunk) — same per-chunk seeds
  (``offset_seed(base, i)``), same ragged-tail padding, same single-pass
  unweighted estimate.
* The per-chunk jitted update's intermediates are O(B·d + chunk·d) —
  independent of n (the driver's device footprint can't grow with the
  store).
* ``ShardedStore.iter_batches`` yields the store in order as fixed-size
  batches (ragged tail), opens each split exactly once, and
  ``ReadStats`` stays consistent under concurrent mutation (the prefetch
  thread and main thread both touch it).
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bootstrap import bootstrap_chunked
from repro.core.reduce_api import (KMeansStep, Mean, Quantile,
                                   StatisticGroup, Var)
from repro.core.streaming import bootstrap_streaming
from repro.data.store import ReadStats, ShardedStore


def _store(n=10_000, d=3, split_size=1234, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, d)).astype(np.float32)
    return ShardedStore.from_array(data, split_size, interleave=False)


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


# ----------------------------------------------------------------------------
# store iteration
# ----------------------------------------------------------------------------
class TestIterBatches:
    def test_batches_reassemble_the_store(self):
        store = _store()
        batches = list(store.iter_batches(3000))
        assert [len(b) for b in batches] == [3000, 3000, 3000, 1000]
        np.testing.assert_array_equal(np.concatenate(batches),
                                      np.concatenate(store.splits))

    def test_each_split_opened_exactly_once(self):
        store = _store()
        store.stats.reset()
        list(store.iter_batches(3000))
        assert store.stats.splits_opened == len(store.splits)
        assert store.stats.rows_read == store.N

    def test_chunk_smaller_than_split_and_larger_than_store(self):
        store = _store(n=100, split_size=40)
        assert [len(b) for b in store.iter_batches(7)] == [7] * 14 + [2]
        whole = list(store.iter_batches(10_000))
        assert len(whole) == 1 and len(whole[0]) == 100

    def test_exact_multiple_has_no_ragged_tail(self):
        store = _store(n=120, split_size=40)
        assert [len(b) for b in store.iter_batches(60)] == [60, 60]

    def test_nonpositive_chunk_raises(self):
        store = _store(n=10, split_size=5)
        with pytest.raises(ValueError, match="chunk"):
            next(store.iter_batches(0))

    def test_start_row_resumes_mid_stream(self):
        store = _store()
        full = list(store.iter_batches(3000))
        store.stats.reset()
        tail = list(store.iter_batches(3000, start_row=6000))
        np.testing.assert_array_equal(np.concatenate(tail),
                                      np.concatenate(full[2:]))
        # splits entirely before the cursor are never opened: the resumed
        # pass pays only for the rows it still needs
        assert store.stats.splits_opened < len(store.splits)
        assert store.stats.rows_read < store.N

    def test_start_row_bounds(self):
        store = _store(n=10, split_size=5)
        with pytest.raises(ValueError, match="start_row"):
            next(store.iter_batches(4, start_row=11))
        with pytest.raises(ValueError, match="start_row"):
            next(store.iter_batches(4, start_row=-1))
        assert list(store.iter_batches(4, start_row=10)) == []

    def test_read_all_matches_concatenated_splits(self):
        store = _store()
        np.testing.assert_array_equal(store.read_all(),
                                      np.concatenate(store.splits))

    def test_read_all_counts_one_pass(self):
        store = _store()
        store.stats.reset()
        store.read_all()
        assert store.stats.splits_opened == len(store.splits)
        assert store.stats.rows_read == store.N


class TestReadStatsThreadSafety:
    def test_concurrent_adds_lose_nothing(self):
        stats = ReadStats()
        PER, THREADS = 5000, 8

        def hammer():
            for _ in range(PER):
                stats.add(splits=1, rows=3)

        ts = [threading.Thread(target=hammer) for _ in range(THREADS)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert stats.splits_opened == PER * THREADS
        assert stats.rows_read == 3 * PER * THREADS


# ----------------------------------------------------------------------------
# the streaming driver
# ----------------------------------------------------------------------------
class TestStreamingBitwiseEqualsChunked:
    KEY = jax.random.PRNGKey(5)
    CHUNK = 3000              # store.N = 10000 → ragged 1000-row tail

    def _both(self, stat):
        store = _store()
        vals = jnp.asarray(store.read_all())
        rc = bootstrap_chunked(vals, stat, B=16, key=self.KEY,
                               chunk=self.CHUNK, backend="fused_rng")
        rs = bootstrap_streaming(store, stat, B=16, key=self.KEY,
                                 chunk=self.CHUNK)
        return rc, rs

    @pytest.mark.parametrize("stat", [
        Mean(), Var(),
        Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
        StatisticGroup([Mean(), Quantile(0.25, lo=-4.0, hi=4.0, nbins=32)]),
        KMeansStep(jnp.asarray(np.random.default_rng(2)
                               .normal(size=(4, 3)).astype(np.float32))),
    ], ids=lambda s: type(s).__name__)
    def test_thetas_and_estimate_bitwise(self, stat):
        rc, rs = self._both(stat)
        _tree_bitwise(rc.thetas, rs.thetas)
        _tree_bitwise(rc.estimate, rs.estimate)
        assert rc.n == rs.n

    def test_1d_values_and_chunk_equal_to_n(self):
        rng = np.random.default_rng(9)
        store = ShardedStore.from_array(
            rng.normal(size=4096).astype(np.float32), 1000,
            interleave=False)
        rc = bootstrap_chunked(jnp.asarray(store.read_all()), Mean(), B=8,
                               key=self.KEY, chunk=4096,
                               backend="fused_rng")
        rs = bootstrap_streaming(store, Mean(), B=8, key=self.KEY,
                                 chunk=4096)
        _tree_bitwise(rc.thetas, rs.thetas)
        _tree_bitwise(rc.estimate, rs.estimate)

    def test_stream_report_populated(self):
        _, rs = self._both(Mean())
        sr = rs.stream
        assert sr.n_chunks == 4 and sr.rows == 10_000
        assert sr.wall_s > 0 and sr.dispatch_s >= 0 and sr.wait_s >= 0

    def test_reads_store_exactly_once(self):
        store = _store()
        store.stats.reset()
        bootstrap_streaming(store, Mean(), B=8, key=self.KEY,
                            chunk=self.CHUNK)
        assert store.stats.splits_opened == len(store.splits)
        assert store.stats.rows_read == store.N


class TestStreamingValidation:
    def test_rejects_materialized_backend(self):
        with pytest.raises(ValueError, match="fused_rng"):
            bootstrap_streaming(_store(n=100, split_size=50), Mean(), B=8,
                                key=jax.random.PRNGKey(0), chunk=64,
                                backend=None)

    def test_rejects_empty_store(self):
        with pytest.raises(ValueError, match="non-empty"):
            bootstrap_streaming(ShardedStore([]), Mean(), B=8,
                                key=jax.random.PRNGKey(0), chunk=64)

    def test_rejects_bad_queue_depth(self):
        with pytest.raises(ValueError, match="queue_depth"):
            bootstrap_streaming(_store(n=100, split_size=50), Mean(), B=8,
                                key=jax.random.PRNGKey(0), chunk=64,
                                queue_depth=0)

    def test_store_error_propagates_from_prefetch_thread(self):
        store = _store(n=100, split_size=50)

        def boom(i):
            raise OSError("split unreadable")

        store.read_split = boom
        with pytest.raises(OSError, match="split unreadable"):
            bootstrap_streaming(store, Mean(), B=8,
                                key=jax.random.PRNGKey(0), chunk=64)


class TestProducerLifecycle:
    """A consumer-side failure must not strand the prefetch thread: before
    the stop-event fix, a chunk that poisoned the consumer's jitted update
    left the producer blocked forever in ``Queue.put`` on the full hand-off
    queue — a thread (and its staged device buffers) leaked per failure."""

    @staticmethod
    def _prefetch_threads():
        return [t for t in threading.enumerate()
                if t.name == "earl-stream-prefetch" and t.is_alive()]

    def test_poisoned_chunk_does_not_leak_producer_thread(self):
        rng = np.random.default_rng(3)
        splits = [rng.normal(size=(64, 3)).astype(np.float32)
                  for _ in range(8)]
        # batch 1 has the wrong width: the consumer's update raises at
        # trace time while the producer still has 6 batches to stage
        # through a depth-2 queue (i.e. it WOULD block without the fix)
        splits[1] = rng.normal(size=(64, 2)).astype(np.float32)
        store = ShardedStore(splits)
        assert not self._prefetch_threads()
        with pytest.raises(Exception):
            bootstrap_streaming(store, Mean(), B=8,
                                key=jax.random.PRNGKey(0), chunk=64,
                                queue_depth=2)
        # the driver's cleanup (stop + drain + join) already ran: no
        # prefetch thread may survive the call
        assert not self._prefetch_threads()


class TestStreamingDeviceFootprint:
    """The per-chunk update's intermediates are bounded by the chunk and
    state sizes — NOT by n.  The streamed carry never holds anything of
    size n on device: trace the chunk update and cap every aval."""

    def test_chunk_update_intermediates_are_n_independent(self):
        from test_matrix_free import _max_intermediate_size

        from repro.core.reduce_api import split_params
        from repro.core.streaming import _stream_chunk_jit

        B, chunk, d = 64, 4096, 2
        stat = Mean()
        spec, params = split_params(stat)
        states = jax.vmap(lambda _: stat.init_state(d))(jnp.arange(B))
        est = stat.init_state(d)
        xi = jnp.zeros((chunk, d), jnp.float32)
        vi = jnp.ones((chunk,), jnp.float32)

        biggest = _max_intermediate_size(
            lambda st, e, x: _stream_chunk_jit(
                st, e, x, vi, jnp.int32(0), jnp.int32(0),
                params, spec, B),
            states, est, xi)
        # the (B, chunk) per-chunk weight matrix would be 262144 elements;
        # the largest legitimate intermediate is the (B, block_n=512)
        # weight tile — and, the streaming contract, nothing here depends
        # on the store's n at all (n never enters the trace).
        assert biggest <= B * 512, (
            f"largest per-chunk intermediate has {biggest} elements")

    def test_trace_has_no_n_sized_aval(self):
        """Same trace, explicit shape scan: no aval's leading axis exceeds
        the chunk (i.e. nothing scales with the 10^6-row store this chunk
        might be drawn from)."""
        from test_matrix_free import _walk_shapes

        from repro.core.reduce_api import split_params
        from repro.core.streaming import _stream_chunk_jit

        B, chunk, d = 64, 4096, 2
        stat = Mean()
        spec, params = split_params(stat)
        states = jax.vmap(lambda _: stat.init_state(d))(jnp.arange(B))
        est = stat.init_state(d)
        xi = jnp.zeros((chunk, d), jnp.float32)
        vi = jnp.ones((chunk,), jnp.float32)
        jaxpr = jax.make_jaxpr(
            lambda st, e, x: _stream_chunk_jit(
                st, e, x, vi, jnp.int32(0), jnp.int32(0),
                params, spec, B))(states, est, xi)
        shapes = _walk_shapes(jaxpr.jaxpr, [])
        assert max((max(s) for s in shapes if s), default=0) <= chunk


class TestPinnedExtent:
    """``n_rows=`` pins a pass to the store's first n_rows rows — the
    stable-prefix contract a growing ingest log (live.DurableIngestLog)
    needs: the result must be bitwise what a store holding ONLY those
    rows would produce, and the extent must be validated."""

    KEY = jax.random.PRNGKey(11)

    def test_pinned_run_equals_prefix_store_bitwise(self):
        rng = np.random.default_rng(4)
        splits = [rng.normal(size=(64, 3)).astype(np.float32)
                  for _ in range(6)]
        grown = ShardedStore([s.copy() for s in splits])
        n_rows = 64 * 4
        prefix = ShardedStore([s.copy() for s in splits[:4]])
        r_pin = bootstrap_streaming(grown, Mean(), B=16, key=self.KEY,
                                    chunk=100, n_rows=n_rows)
        r_ref = bootstrap_streaming(prefix, Mean(), B=16, key=self.KEY,
                                    chunk=100)
        _tree_bitwise(r_pin.thetas, r_ref.thetas)
        _tree_bitwise(r_pin.estimate, r_ref.estimate)
        assert r_pin.n == r_ref.n == n_rows
        assert r_pin.stream.rows == n_rows

    def test_pin_mid_split_trims_the_straddling_chunk(self):
        rng = np.random.default_rng(5)
        data = rng.normal(size=(300, 2)).astype(np.float32)
        grown = ShardedStore.from_array(data, 128, interleave=False)
        r_pin = bootstrap_streaming(grown, Mean(), B=8, key=self.KEY,
                                    chunk=64, n_rows=200)
        prefix = ShardedStore.from_array(data[:200], 128, interleave=False)
        r_ref = bootstrap_streaming(prefix, Mean(), B=8, key=self.KEY,
                                    chunk=64)
        _tree_bitwise(r_pin.thetas, r_ref.thetas)
        assert r_pin.n == 200

    def test_n_rows_out_of_range_raises(self):
        store = _store(n=100, split_size=40)
        for bad in (0, -1, 101):
            with pytest.raises(ValueError, match="n_rows"):
                bootstrap_streaming(store, Mean(), B=8,
                                    key=self.KEY, chunk=64, n_rows=bad)
