"""SSABE + EarlSession + earl_eval + pipeline restartability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EarlSession, Mean
from repro.core.ssabe import fit_cv_curve, invert_cv_curve, ssabe
from repro.data import synthetic_tokens
from repro.data.pipeline import EvalSamplePipeline, TokenBatchPipeline


class TestSSABE:
    def test_fit_recovers_planted_curve(self):
        ns = np.array([50, 100, 200, 400, 800])
        a_true, c_true = 0.8, 0.01
        cvs = a_true / np.sqrt(ns) + c_true
        a, c = fit_cv_curve(ns, cvs)
        assert a == pytest.approx(a_true, rel=1e-6)
        assert c == pytest.approx(c_true, abs=1e-8)

    def test_invert_curve(self):
        # a/sqrt(n) + c <= sigma  ->  n >= (a/(sigma-c))^2
        n = invert_cv_curve(a=1.0, c=0.0, sigma=0.1, n_cap=10**9)
        assert n == 100

    def test_invert_impossible_sigma_caps(self):
        assert invert_cv_curve(a=1.0, c=0.2, sigma=0.1, n_cap=1234) == 1234

    def test_histories_recorded(self, key):
        x = jax.random.normal(key, (1000,)) + 5
        res = ssabe(x, Mean(), sigma=0.05, tau=0.01, key=key, N=10**6)
        assert len(res.cv_history_B) >= 1
        assert len(res.cv_history_n) == 5          # l = 5 (paper)
        ns = [h[0] for h in res.cv_history_n]
        assert ns == sorted(ns)                    # nested n_i = n/2^(l-i)

    def test_single_iteration_typical(self, key):
        """Paper §5: 'a single iteration is usually required'."""
        class Perm:
            def __init__(self, data):
                self.data = np.asarray(data)
                self.N = len(data)
            def take(self, a, b):
                return jnp.asarray(self.data[a:b])
        data = np.random.default_rng(1).normal(50, 5, 400_000).astype(
            np.float32)
        sess = EarlSession(Perm(data), Mean(), sigma=0.01)
        out = sess.run(key)
        assert out.iterations <= 2
        assert not out.fell_back

    def test_no_prefix_reread_per_iteration(self, key):
        """The point estimate is delta-maintained (PoissonDelta.est_state):
        each main-loop round must read only Δs, never re-read the [0, n)
        prefix — total rows touched == pilot + final n (the old
        stat(take(0, n_have)) per round read O(n) extra each time)."""
        class CountingPerm:
            def __init__(self, data):
                self.data = np.asarray(data)
                self.N = len(data)
                self.rows = 0
            def take(self, a, b):
                self.rows += b - a
                return jnp.asarray(self.data[a:b])

        data = np.random.default_rng(2).normal(50, 5, 400_000).astype(
            np.float32)
        s = CountingPerm(data)
        sess = EarlSession(s, Mean(), sigma=0.005)
        out = sess.run(key)
        assert not out.fell_back
        n_pilot = min(s.N, sess.max_pilot,
                      max(sess.min_pilot, int(sess.p_pilot * s.N)))
        assert s.rows == n_pilot + out.n_used, (
            f"read {s.rows} rows for pilot={n_pilot}, n_used={out.n_used} "
            f"— the session is re-reading the sample prefix")
        # and the delta-maintained estimate equals the prefix recompute
        ref = float(np.mean(data[:out.n_used]))
        assert abs(float(np.ravel(out.result)[0]) - ref) < 1e-3


class TestPipelines:
    def test_token_pipeline_restart(self):
        docs = synthetic_tokens(64, 33, 128, seed=0)
        p1 = TokenBatchPipeline(docs, batch=4, seq_len=32, seed=5)
        for _ in range(3):
            p1.next_batch()
        saved = p1.state_dict()
        want_t, want_l = p1.next_batch()

        p2 = TokenBatchPipeline(docs, batch=4, seq_len=32, seed=5)
        p2.load_state_dict(saved)
        got_t, got_l = p2.next_batch()
        np.testing.assert_array_equal(np.asarray(want_t), np.asarray(got_t))
        np.testing.assert_array_equal(np.asarray(want_l), np.asarray(got_l))

    def test_epoch_rollover_reshuffles(self):
        docs = synthetic_tokens(8, 33, 128, seed=0)
        p = TokenBatchPipeline(docs, batch=4, seq_len=32, seed=5)
        first_epoch = [np.asarray(p.next_batch()[0]) for _ in range(2)]
        second_epoch = [np.asarray(p.next_batch()[0]) for _ in range(2)]
        assert p.state.epoch == 1
        same = all((a == b).all() for a, b in zip(first_epoch, second_epoch))
        assert not same, "new epoch must reshuffle"

    def test_eval_pipeline_prefix(self):
        docs = synthetic_tokens(128, 65, 100, seed=2)
        ep = EvalSamplePipeline(docs, seq_len=64)
        t1, l1 = ep.take(0, 8)
        t2, l2 = ep.take(0, 16)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2)[:8])
        assert l1.shape == (8, 64)


class TestEarlEvalIntegration:
    def test_eval_speedup_and_accuracy(self, key):
        from repro.configs import get_config
        from repro.models import init_params
        from repro.train import EarlEval, make_eval_step

        cfg = get_config("stablelm-3b", smoke=True)
        params = init_params(key, cfg)
        docs = synthetic_tokens(3000, 33, cfg.vocab, seed=3)
        pipe = EvalSamplePipeline(docs, seq_len=32)
        ev = EarlEval(jax.jit(make_eval_step(cfg)), params, pipe,
                      sigma=0.01, tau=0.05, eval_batch=64)
        res = ev.run(key)
        info = res.history[-1]
        assert info["model_forwards"] < 0.5 * info["full_pass_forwards"], \
            "earl_eval must certify accuracy from a fraction of the corpus"
        assert res.cv <= 0.01
