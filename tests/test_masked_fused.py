"""Masked-weight fused kernels: interior validity holes on the matrix-free
backend.

``valid_mask`` multiplies the implicit Poisson(1) weight tiles by an exact
0.0/1.0 validity vector, which must

* match the materialized-weights oracle (``implicit_weights * mask``) for
  EVERY built-in statistic and for a StatisticGroup,
* be bitwise identical between the Pallas kernels and the scan lowerings
  (the two lowerings share ``implicit_weight_tile``/``_poisson_tile``),
* reproduce the historical ``n_valid`` prefix masking bit for bit when the
  mask is prefix-shaped (f32 multiply by exactly 1.0/0.0 is exact, so
  ``w * mask`` ≡ ``where(col < n_valid, w, 0)``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bootstrap import fused_resample_states
from repro.core.reduce_api import (Count, KMeansStep, Mean, Median, Quantile,
                                   Statistic, StatisticGroup, Std, Sum, Var)
from repro.kernels.weighted_stats.ops import implicit_weights

N, D, B, SEED = 700, 3, 32, 1234


@pytest.fixture(scope="module")
def x2():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))


@pytest.fixture(scope="module")
def interior_mask():
    rng = np.random.default_rng(1)
    m = (rng.random(N) > 0.3).astype(np.float32)
    m[0] = 0.0          # hole at the very first row
    m[-1] = 0.0         # and past any prefix interpretation
    return jnp.asarray(m)


def _stats():
    cent = jnp.asarray(np.random.default_rng(2)
                       .normal(size=(4, D)).astype(np.float32))
    return [
        Mean(), Sum(), Count(), Var(), Std(),
        Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
        Median(lo=-4.0, hi=4.0, nbins=64),
        KMeansStep(cent),
        StatisticGroup([Mean(), Var(),
                        Quantile(0.25, lo=-4.0, hi=4.0, nbins=32)]),
    ]


def _oracle_thetas(stat, x2, mask):
    """Materialized implicit weights × mask, per-row update — the oracle
    every fused masked path must reproduce."""
    w = np.asarray(implicit_weights(SEED, B, N)) * np.asarray(mask)[None, :]

    def one(wr):
        return stat.finalize(stat.update(stat.init_state(D), x2, wr))

    return jax.vmap(one)(jnp.asarray(w))


def _tree_allclose(a, b, **kw):
    jax.tree_util.tree_map(
        lambda u, v: np.testing.assert_allclose(np.asarray(u),
                                                np.asarray(v), **kw), a, b)


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


class TestInteriorMaskVsOracle:
    @pytest.mark.parametrize("stat", _stats(),
                             ids=lambda s: type(s).__name__)
    def test_fused_matches_materialized_oracle(self, stat, x2,
                                               interior_mask):
        states = fused_resample_states(stat, SEED, x2, B,
                                       valid_mask=interior_mask)
        thetas = jax.vmap(stat.finalize)(states)
        _tree_allclose(thetas, _oracle_thetas(stat, x2, interior_mask),
                       rtol=2e-4, atol=2e-4)

    def test_mask_actually_changes_the_result(self, x2, interior_mask):
        masked = jax.vmap(Mean().finalize)(
            fused_resample_states(Mean(), SEED, x2, B,
                                  valid_mask=interior_mask))
        unmasked = jax.vmap(Mean().finalize)(
            fused_resample_states(Mean(), SEED, x2, B))
        assert not np.allclose(np.asarray(masked), np.asarray(unmasked))


class TestPrefixMaskBitwiseEquivalence:
    """A prefix mask must reproduce n_valid masking BIT FOR BIT — this is
    what lets distributed.py switch to valid_mask without changing any
    pre-existing (prefix-masked) output."""

    @pytest.mark.parametrize("stat", _stats(),
                             ids=lambda s: type(s).__name__)
    def test_prefix_equals_n_valid(self, stat, x2):
        k = 500
        prefix = (jnp.arange(N) < k).astype(jnp.float32)
        a = fused_resample_states(stat, SEED, x2, B, n_valid=k)
        b = fused_resample_states(stat, SEED, x2, B, valid_mask=prefix)
        _tree_bitwise(a, b)


class TestKernelScanParity:
    """Masked Pallas kernels ≡ masked scan lowerings, bitwise (same
    shared tile math on both sides)."""

    def test_moments(self, x2, interior_mask):
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        s = fused_poisson_moments(SEED, x2, B, valid_mask=interior_mask,
                                  backend="scan")
        k = fused_poisson_moments(SEED, x2, B, valid_mask=interior_mask,
                                  backend="pallas_interpret")
        _tree_bitwise(s, k)

    def test_moments_stream_kernel(self, x2, interior_mask):
        """The DMA double-buffered n-loop kernel produces the same bits as
        the grid kernel — masked and unmasked."""
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        for m in (None, interior_mask):
            grid = fused_poisson_moments(SEED, x2, B, valid_mask=m,
                                         backend="pallas_interpret")
            stream = fused_poisson_moments(SEED, x2, B, valid_mask=m,
                                           backend="pallas_interpret",
                                           stream=True)
            _tree_bitwise(grid, stream)

    def test_hist(self, x2, interior_mask):
        from repro.kernels.weighted_hist.ops import fused_poisson_hist
        args = (SEED, x2, -4.0, 4.0, 33, B)
        s = fused_poisson_hist(*args, backend="scan",
                               valid_mask=interior_mask)
        k = fused_poisson_hist(*args, backend="pallas_interpret",
                               valid_mask=interior_mask)
        bb = fused_poisson_hist(*args, backend="pallas_interpret",
                                valid_mask=interior_mask, block_bins=128)
        _tree_bitwise(s, k)
        _tree_bitwise(s, bb)

    def test_kmeans(self, x2, interior_mask):
        from repro.kernels.kmeans_assign.ops import fused_poisson_kmeans
        cent = jnp.asarray(np.random.default_rng(3)
                           .normal(size=(5, D)).astype(np.float32))
        s = fused_poisson_kmeans(SEED, x2, cent, B, backend="scan",
                                 valid_mask=interior_mask)
        k = fused_poisson_kmeans(SEED, x2, cent, B,
                                 backend="pallas_interpret",
                                 valid_mask=interior_mask)
        # sums/counts are bitwise (integer-weighted dot sums); inertia's
        # matvec-vs-dot reduction differs by ulps between the lowerings
        # (pre-existing, mask-independent) — allclose there.
        _tree_bitwise(s[:2], k[:2])
        _tree_allclose(s[2], k[2], rtol=1e-5)

    def test_multi(self, x2, interior_mask):
        from repro.kernels.fused_multi.ops import fused_poisson_multi
        g = StatisticGroup([Mean(),
                            Quantile(0.5, lo=-4.0, hi=4.0, nbins=33)])
        s = fused_poisson_multi(g, SEED, x2, B, backend="scan",
                                valid_mask=interior_mask)
        k = fused_poisson_multi(g, SEED, x2, B, backend="pallas_interpret",
                                valid_mask=interior_mask)
        _tree_bitwise(s, k)


class _NoFusedPath(Statistic):
    """Custom statistic predating both the fused hook and valid_mask."""
    moment_powers = None

    def init_state(self, dim):
        return (jnp.zeros(()), jnp.zeros((dim,)))

    def update(self, state, x, w):
        wt, s1 = state
        return wt + jnp.sum(w), s1 + w @ jnp.asarray(x, jnp.float32)

    def merge(self, a, b):
        return a[0] + b[0], a[1] + b[1]

    def finalize(self, state):
        return state[1] / jnp.maximum(state[0], 1.0)


class TestCustomStatisticFallback:
    def test_masked_fallback_matches_oracle(self, x2, interior_mask):
        stat = _NoFusedPath()
        states = fused_resample_states(stat, SEED, x2, B,
                                       valid_mask=interior_mask)
        thetas = jax.vmap(stat.finalize)(states)
        _tree_allclose(thetas, _oracle_thetas(stat, x2, interior_mask),
                       rtol=1e-5, atol=1e-5)


class TestDistributedInteriorHoles:
    """ft/ failed-shard interior holes now run on the fused backend and
    match the default-backend oracle (beyond the 1-device regression in
    test_distributed.py: multi-shard, hole confined to one shard)."""

    def test_fused_matches_default_backend(self):
        from jax.sharding import Mesh

        from repro.core import DistributedEarl, Mean
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        mask = np.ones(64, np.float32)
        mask[10:20] = 0.0                     # interior block hole
        mask = jnp.asarray(mask)
        key = jax.random.PRNGKey(3)
        fused = DistributedEarl(mesh, Mean(), B=16, backend="fused_rng") \
            .estimate_with_loss_mask(x, mask, key)
        oracle = DistributedEarl(mesh, Mean(), B=16, backend=None) \
            .estimate_with_loss_mask(x, mask, key)
        np.testing.assert_allclose(np.ravel(fused.estimate),
                                   np.ravel(oracle.estimate), rtol=1e-6)
        assert fused.n == oracle.n == 54

    def test_ft_recovery_runs_on_fused_backend(self):
        """The ft/ entry point itself: an interior lost shard (not the
        trailing one, so the mask is NOT a prefix) on the fused backend,
        matching the default backend."""
        from jax.sharding import Mesh

        from repro.core import DistributedEarl, Mean
        from repro.ft.recovery import estimate_with_failures
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(80,)).astype(np.float32) + 2.0)
        key = jax.random.PRNGKey(7)
        rep_f = estimate_with_failures(
            DistributedEarl(mesh, Mean(), B=16, backend="fused_rng"),
            x, lost_shards=[1], n_shards=4, sigma=0.5, key=key)
        rep_o = estimate_with_failures(
            DistributedEarl(mesh, Mean(), B=16, backend=None),
            x, lost_shards=[1], n_shards=4, sigma=0.5, key=key)
        np.testing.assert_allclose(np.ravel(rep_f.result),
                                   np.ravel(rep_o.result), rtol=1e-6)
        assert rep_f.p_surviving == rep_o.p_surviving == 0.75
        assert np.isfinite(rep_f.cv)
