"""Robustness contract for live ingest (live/log.py + live/session.py).

Everything the module docstring of ``live/session.py`` promises is
asserted here, bitwise where the promise is bitwise:

* kill-and-resume of a ``LiveSession`` at EVERY batch boundary equals
  the uninterrupted run — for every mergeable statistic family, with
  cumulative, tumbling and sliding windows;
* duplicated / reordered delivery folds each batch exactly once and
  lands on the same bits as clean in-order delivery;
* the pane ring never exceeds its memory bound, under any delivery;
* sample shedding is bitwise equal to handing the shed mask to the
  kernels as a dedicated ``valid_mask`` (the oracle), and the report's
  ``p_eff`` is exactly the surviving fraction;
* the watermark converts missing batches into invalid rows (CI widens,
  never a silent hole), and late arrivals obey ``LagPolicy.late``;
* ``IngestLog`` backpressure blocks/raises when consumers fall behind.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core.bootstrap import (fused_resample_states, offset_seed,
                                  seed_from_key)
from repro.core.reduce_api import (GroupedStatistic, Mean, Quantile,
                                   SlidingWindow, Statistic, StatisticGroup,
                                   TumblingWindow, Var, Window)
from repro.core.streaming import bootstrap_streaming
from repro.data.store import ShardedStore
from repro.ft.inject import FaultyStore
from repro.ft.policy import LagPolicy
from repro.live import (BackpressureError, IngestLog, LiveSession, LogBatch)

KEY = jax.random.PRNGKey(13)
B = 8
ROWS = 32                      # rows per appended batch
N_BATCHES = 6


class _Kill(Exception):
    """The simulated mid-stream death."""


class _DyingManager(CheckpointManager):
    """Commits its first ``die_after`` saves, then kills the run — with
    ``checkpoint_every=1`` that is SIGKILL at fold boundary ``die_after``."""

    def __init__(self, root, die_after, **kw):
        kw.setdefault("async_save", False)
        super().__init__(root, **kw)
        self.die_after = die_after
        self.saves = 0

    def save(self, *a, **kw):
        super().save(*a, **kw)
        self.saves += 1
        if self.saves >= self.die_after:
            raise _Kill(f"simulated crash after save #{self.saves}")


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


STATS = [
    Mean(), Var(),
    Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
    StatisticGroup([Mean(), Var()]),
    GroupedStatistic(Mean(), 4),
]
_IDS = [("Grouped" if getattr(s, "num_groups", None) is not None
         else type(s).__name__) for s in STATS]


def _batch_data(stat, i, rows=ROWS):
    rng = np.random.default_rng((17, i))
    if getattr(stat, "num_groups", None) is not None:
        x = rng.normal(size=(rows, 1)).astype(np.float32)
        k = rng.integers(0, stat.num_groups,
                         size=(rows, 1)).astype(np.float32)
        return np.concatenate([x, k], axis=1)
    return rng.normal(size=(rows, 2)).astype(np.float32)


def _fill_log(stat, n=N_BATCHES):
    log = IngestLog()
    for i in range(n):
        log.append(_batch_data(stat, i))
    return log


# windows sized against ROWS=32 batches: tumbling pane = 2 batches,
# sliding pane = 1 batch with a 4-pane ring
def _wrap(stat, wkind):
    if wkind == "cumulative":
        return stat
    if wkind == "tumbling":
        return TumblingWindow(stat, 64)
    return SlidingWindow(stat, 128, 32)


_CLEAN = {}


def _clean_report(stat_i, wkind):
    """Uninterrupted reference run, cached across the kill parametrize."""
    k = (stat_i, wkind)
    if k not in _CLEAN:
        stat = STATS[stat_i]
        s = LiveSession(_fill_log(stat), _wrap(stat, wkind), B=B, key=KEY)
        s.poll()
        _CLEAN[k] = s.report()
    return _CLEAN[k]


class TestKillResumeBitwise:
    """Acceptance gate: kill at every batch boundary, resume, compare
    bitwise — thetas, estimate, and the accounting the CI rides on."""

    @pytest.mark.parametrize("die_after", range(1, N_BATCHES + 1))
    @pytest.mark.parametrize("wkind", ["cumulative", "tumbling", "sliding"])
    @pytest.mark.parametrize("stat_i", range(len(STATS)), ids=_IDS)
    def test_every_boundary(self, stat_i, wkind, die_after, tmp_path):
        stat = STATS[stat_i]
        base = _clean_report(stat_i, wkind)

        log = _fill_log(stat)
        root = str(tmp_path / "ckpt")
        dying = LiveSession(log, _wrap(stat, wkind), B=B, key=KEY,
                            checkpoint=_DyingManager(root, die_after),
                            checkpoint_every=1)
        with pytest.raises(_Kill):
            dying.poll()

        resumed = LiveSession(
            log, _wrap(stat, wkind), B=B, key=KEY, resume=True,
            checkpoint=CheckpointManager(root, async_save=False))
        assert resumed.counters.folded == die_after
        resumed.poll()
        rep = resumed.report()
        assert resumed.counters.folded == N_BATCHES     # exactly once
        _tree_bitwise(base.thetas, rep.thetas)
        _tree_bitwise(base.estimate, rep.estimate)
        assert (rep.rows, rep.valid_rows, rep.p_eff) == \
            (base.rows, base.valid_rows, base.p_eff)
        assert (rep.watermark_seq, rep.watermark_row, rep.window_start) == \
            (base.watermark_seq, base.watermark_row, base.window_start)

    def test_checkpointing_is_an_observer(self, tmp_path):
        """An uninterrupted checkpointed run returns the same bits as a
        plain run (string checkpoint= exercises the for_run scoping)."""
        base = _clean_report(0, "sliding")
        log = _fill_log(STATS[0])
        s = LiveSession(log, _wrap(STATS[0], "sliding"), B=B, key=KEY,
                        checkpoint=str(tmp_path / "ckpt"),
                        checkpoint_every=2)
        s.poll()
        rep = s.report()
        _tree_bitwise(base.thetas, rep.thetas)
        _tree_bitwise(base.estimate, rep.estimate)


class TestResumeValidation:
    def test_resume_needs_checkpoint(self):
        with pytest.raises(ValueError, match="resume"):
            LiveSession(IngestLog(), Mean(), B=B, key=KEY, resume=True)

    def test_fingerprint_rejects_different_window(self, tmp_path):
        log = _fill_log(Mean(), n=2)
        root = str(tmp_path / "ckpt")
        s = LiveSession(log, SlidingWindow(Mean(), 128, 32), B=B, key=KEY,
                        checkpoint=CheckpointManager(root, async_save=False))
        s.poll()
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            LiveSession(log, TumblingWindow(Mean(), 128), B=B, key=KEY,
                        resume=True,
                        checkpoint=CheckpointManager(root, async_save=False))

    def test_fingerprint_rejects_different_key(self, tmp_path):
        log = _fill_log(Mean(), n=2)
        root = str(tmp_path / "ckpt")
        s = LiveSession(log, Mean(), B=B, key=KEY,
                        checkpoint=CheckpointManager(root, async_save=False))
        s.poll()
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            LiveSession(log, Mean(), B=B, key=jax.random.PRNGKey(99),
                        resume=True,
                        checkpoint=CheckpointManager(root, async_save=False))

    def test_foreign_checkpoint_rejected(self, tmp_path):
        root = str(tmp_path / "ckpt")
        mgr = CheckpointManager(root, async_save=False)
        mgr.save(0, {"weights": jnp.zeros(3)}, extra={"note": "training"})
        with pytest.raises(ValueError, match="cursor"):
            LiveSession(IngestLog(), Mean(), B=B, key=KEY, resume=True,
                        checkpoint=mgr)

    def test_constructor_validation(self):
        with pytest.raises(TypeError, match="Statistic"):
            LiveSession(IngestLog(), object(), B=B, key=KEY)
        with pytest.raises(ValueError, match="checkpoint_every"):
            LiveSession(IngestLog(), Mean(), B=B, key=KEY,
                        checkpoint_every=0)
        with pytest.raises(ValueError, match="poll"):
            LiveSession(None, Mean(), B=B, key=KEY).poll()


class TestFaultedDelivery:
    """Duplicated + reordered delivery (ft.FaultyStore's seeded plan)
    must fold exactly once per batch and land on the clean run's bits."""

    def _store(self, n_splits=10):
        rng = np.random.default_rng(5)
        data = rng.normal(size=(n_splits * ROWS, 2)).astype(np.float32)
        return ShardedStore.from_array(data, ROWS, interleave=False)

    def _run_plan(self, inner, plan_iter, **session_kw):
        s = LiveSession(None, SlidingWindow(Var(), 128, 32), B=B, key=KEY,
                        **session_kw)
        for sq, data in plan_iter:
            s.feed(LogBatch(seq=sq, row0=int(inner.offsets[sq]), data=data))
            assert s.panes_live <= s.memory_bound
        return s

    def test_exactly_once_and_bitwise(self):
        inner = self._store()
        clean = self._run_plan(
            inner, ((i, inner.read_split(i)) for i in range(10)))
        faulty = FaultyStore(inner)
        deliveries = list(faulty.iter_delivery(seed=42, p_duplicate=0.3,
                                               max_reorder=3))
        assert faulty.injected.duplicates > 0
        assert faulty.injected.reordered > 0
        s = self._run_plan(inner, iter(deliveries))
        assert s.counters.folded == 10                   # exactly once
        assert s.counters.duplicates == faulty.injected.duplicates
        a, b = clean.report(), s.report()
        _tree_bitwise(a.thetas, b.thetas)
        _tree_bitwise(a.estimate, b.estimate)
        assert a.p_eff == b.p_eff == 1.0

    def test_reorder_buffer_stays_within_memory_bound(self):
        """Even delivered fully backwards (within the lag budget) the
        ring obeys its bound — buffered batches are raw rows, pane
        states only exist for folded panes."""
        inner = self._store(8)
        plan = list(range(7, -1, -1))
        s = self._run_plan(
            inner, ((i, inner.read_split(i)) for i in plan),
            policy=LagPolicy(max_lag_batches=16))
        assert s.counters.folded == 8
        assert s.counters.reordered == 7
        clean = self._run_plan(
            inner, ((i, inner.read_split(i)) for i in range(8)))
        _tree_bitwise(clean.report().thetas, s.report().thetas)


class TestWatermarkAndLate:
    def _batches(self, n=8):
        inner = ShardedStore.from_array(
            np.random.default_rng(9).normal(
                size=(n * ROWS, 2)).astype(np.float32),
            ROWS, interleave=False)
        return inner, [LogBatch(seq=i, row0=i * ROWS,
                                data=inner.read_split(i))
                       for i in range(n)]

    def test_gap_skip_charges_invalid_rows(self):
        _, bs = self._batches()
        s = LiveSession(None, Mean(), B=B, key=KEY,
                        policy=LagPolicy(max_lag_batches=3))
        for b in bs[:2] + bs[3:]:           # seq 2 never arrives
            s.feed(b)
        assert s.counters.gaps_skipped == 1
        assert s.counters.gap_rows == ROWS
        assert s.counters.folded == 7
        rep = s.report()
        assert rep.rows == 8 * ROWS
        assert rep.valid_rows == 7 * ROWS
        assert rep.p_eff == pytest.approx(7 / 8)
        assert rep.watermark_seq == 7

    def test_late_drop_policy(self):
        _, bs = self._batches()
        s = LiveSession(None, Mean(), B=B, key=KEY,
                        policy=LagPolicy(max_lag_batches=3, late="drop"))
        for b in bs[:2] + bs[3:]:
            s.feed(b)
        assert s.feed(bs[2]) == []          # too late: counted, dropped
        assert s.counters.late_dropped == 1
        assert s.report().p_eff == pytest.approx(7 / 8)

    def test_late_fold_restores_p_eff(self):
        _, bs = self._batches()
        s = LiveSession(None, Mean(), B=B, key=KEY,
                        policy=LagPolicy(max_lag_batches=3, late="fold"))
        for b in bs[:2] + bs[3:]:
            s.feed(b)
        out = s.feed(bs[2])                 # pane 0 (cumulative) still live
        assert len(out) == 1
        assert s.counters.late_folded == 1
        rep = s.report()
        assert rep.p_eff == 1.0
        # all 8 batches contributed; estimate matches clean in-order run
        # (fold ORDER differs, so this is allclose, not bitwise — the
        # documented limit of late folding)
        clean = LiveSession(None, Mean(), B=B, key=KEY)
        for b in bs:
            clean.feed(b)
        np.testing.assert_allclose(np.asarray(rep.estimate),
                                   np.asarray(clean.report().estimate),
                                   rtol=1e-5)

    def test_late_fold_into_evicted_pane_drops(self):
        _, bs = self._batches()
        s = LiveSession(None, SlidingWindow(Mean(), 64, 32), B=B, key=KEY,
                        policy=LagPolicy(max_lag_batches=2, late="fold"))
        for b in bs[:1] + bs[2:]:           # seq 1 lost, window slides on
            s.feed(b)
        assert s.feed(bs[1]) == []          # its pane was evicted long ago
        assert s.counters.late_dropped == 1

    def test_duplicate_after_fold_is_dropped(self):
        _, bs = self._batches(4)
        s = LiveSession(None, Mean(), B=B, key=KEY)
        for b in bs:
            s.feed(b)
        before = s.report()
        assert s.feed(bs[1]) == []
        assert s.counters.duplicates == 1
        _tree_bitwise(before.thetas, s.report().thetas)


class TestShedding:
    def test_shed_bitwise_equals_valid_mask_oracle(self):
        """The acceptance oracle: a backlogged poll sheds early batches
        with a seeded mask; the emitted thetas/estimate/p_eff must be
        bitwise equal to folding the SAME masks through
        ``fused_resample_states(valid_mask=...)`` by hand."""
        policy = LagPolicy(max_lag_batches=16, shed_backlog=2,
                           p_shed=0.5, shed_seed=99)
        window = SlidingWindow(Var(), 128, 32)
        log = _fill_log(Var(), n=10)
        s = LiveSession(log, window, B=B, key=KEY, policy=policy)
        reports = s.poll()
        assert len(reports) == 10
        # backlog at fold of seq q is 9-q: seqs 0..6 shed, 7..9 clean
        assert [r.shed for r in reports] == [True] * 7 + [False] * 3
        assert s.counters.shed_batches == 7
        assert s.counters.shed_rows > 0
        rep = s.report()
        assert rep.p_eff < 1.0

        # oracle: final window = panes 6..9 = batches 6..9, one pane each
        stat = window.stat
        base_seed = seed_from_key(KEY)
        states = jax.vmap(lambda _: stat.init_state(2))(jnp.arange(B))
        est = stat.init_state(2)
        rows = valid = 0
        for sq in range(6, 10):
            xb = log.store.read_split(sq)
            if sq <= 6:
                rng = np.random.default_rng((99, sq))
                m = (rng.random(ROWS) < 0.5).astype(np.float32)
            else:
                m = np.ones(ROWS, np.float32)
            est = stat.update(est, xb, m)
            delta = fused_resample_states(
                stat, offset_seed(base_seed, jnp.asarray(sq, jnp.int32)),
                xb, B, valid_mask=m)
            states = jax.vmap(stat.merge)(states, delta)
            rows += ROWS
            valid += int(m.sum())
        p_eff = valid / rows
        thetas = stat.correct(jax.vmap(stat.finalize)(states), p_eff)
        estimate = stat.correct(stat.finalize(est), p_eff)
        assert rep.p_eff == p_eff
        _tree_bitwise(rep.thetas, thetas)
        _tree_bitwise(rep.estimate, estimate)

    def test_shed_deterministic_across_resume(self, tmp_path):
        """Kill mid-backlog: the resumed poll observes the same log state
        and re-derives the same (seed, seq)-keyed shed masks — bitwise."""
        policy = LagPolicy(max_lag_batches=16, shed_backlog=2,
                           p_shed=0.5, shed_seed=7)
        base_log = _fill_log(Mean(), n=10)
        clean = LiveSession(base_log, Mean(), B=B, key=KEY, policy=policy)
        clean.poll()
        base = clean.report()

        log = _fill_log(Mean(), n=10)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            LiveSession(log, Mean(), B=B, key=KEY, policy=policy,
                        checkpoint=_DyingManager(root, 4),
                        checkpoint_every=1).poll()
        r = LiveSession(log, Mean(), B=B, key=KEY, policy=policy,
                        resume=True,
                        checkpoint=CheckpointManager(root, async_save=False))
        r.poll()
        rep = r.report()
        assert rep.p_eff == base.p_eff
        assert r.counters.shed_rows == clean.counters.shed_rows
        _tree_bitwise(base.thetas, rep.thetas)
        _tree_bitwise(base.estimate, rep.estimate)


class TestBackpressure:
    def test_append_blocks_then_raises(self):
        log = IngestLog(capacity=2)
        s = LiveSession(log, Mean(), B=B, key=KEY)
        log.append(_batch_data(Mean(), 0))
        log.append(_batch_data(Mean(), 1))
        with pytest.raises(BackpressureError, match="backlog"):
            log.append(_batch_data(Mean(), 2), timeout=0.05)
        s.poll()                            # folds + acks both batches
        assert log.append(_batch_data(Mean(), 2), timeout=0.05) == 2

    def test_unregistered_log_never_gates(self):
        log = IngestLog(capacity=1)
        for i in range(5):                  # no consumers: cannot measure
            log.append(_batch_data(Mean(), i), timeout=0.01)
        assert log.next_seq == 5

    def test_capacity_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            IngestLog(capacity=0)


class TestWindowGeometry:
    def test_tumbling_is_sliding_with_slide_eq_size(self):
        w = TumblingWindow(Mean(), 96)
        assert (w.size, w.slide, w.panes) == (96, 96, 1)

    def test_sliding_panes_and_rows(self):
        w = SlidingWindow(Mean(), 128, 32)
        assert w.panes == 4
        assert w.pane_rows(3) == (96, 128)
        assert w.pane_of(95) == 2

    def test_validation(self):
        with pytest.raises(ValueError, match="multiple"):
            SlidingWindow(Mean(), 100, 32)
        with pytest.raises(ValueError, match="slide"):
            SlidingWindow(Mean(), 32, 0)
        with pytest.raises(ValueError, match="size"):
            SlidingWindow(Mean(), 16, 32)
        with pytest.raises(TypeError, match="Statistic"):
            SlidingWindow(object(), 64, 32)

    def test_window_tracks_slide_and_bound(self):
        """As the stream advances, the report covers exactly the window
        and the ring holds at most ``panes`` panes."""
        log = _fill_log(Mean(), n=8)        # 256 rows total
        s = LiveSession(log, SlidingWindow(Mean(), 128, 32), B=B, key=KEY)
        reports = s.poll()
        assert s.memory_bound == 4
        for r in reports:
            assert r.panes_live <= 4
            assert r.window_end - r.window_start <= 128
        last = reports[-1]
        assert (last.window_start, last.window_end) == (128, 256)
        # the window's estimate is the mean of exactly the last 128 rows
        tail = log.store.read_all()[128:]
        np.testing.assert_allclose(np.asarray(last.estimate),
                                   tail.mean(axis=0), rtol=1e-5)

    def test_cumulative_matches_streaming_bootstrap(self):
        """Cross-layer contract: a cumulative LiveSession over the log is
        the same estimator as ``bootstrap_streaming`` over the log's
        store with chunk == batch size — bitwise."""
        log = _fill_log(Var(), n=N_BATCHES)
        s = LiveSession(log, Var(), B=B, key=KEY)
        s.poll()
        rep = s.report()
        ref = bootstrap_streaming(log.store, Var(), B=B, key=KEY,
                                  chunk=ROWS)
        _tree_bitwise(rep.thetas, ref.thetas)
        _tree_bitwise(rep.estimate, ref.estimate)
