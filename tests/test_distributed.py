"""Multi-device tests (8 forced host devices, run in a subprocess so the
main pytest process keeps its single device)."""
import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import DistributedEarl, Mean, Sum
from repro.core.bootstrap import bootstrap

out = {}
assert jax.device_count() == 8

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

# --- distributed poisson bootstrap == sane accuracy ---------------------
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (32768,)) * 2.0 + 10.0
earl = DistributedEarl(mesh, Mean(), B=128, data_axes=("data",))
res = earl.estimate(x, key)
local = bootstrap(x, Mean(), B=128, key=key, engine="poisson")
out["dist_est"] = float(np.ravel(res.estimate)[0])
out["dist_cv"] = res.cv
out["local_cv"] = local.cv
out["true"] = float(x.mean())

# --- ragged global sample (padding mask) --------------------------------
x2 = jax.random.normal(key, (1001,)) + 5.0
res2 = earl.estimate(x2, key)
out["ragged_est"] = float(np.ravel(res2.estimate)[0])
out["ragged_true"] = float(x2.mean())

# --- small-mesh dry-run: lower+compile a smoke train step ----------------
from repro.configs import get_config
from repro.launch.sharding import TRAIN_RULES, resolve_tree
from repro.models.act_shard import activation_sharding, mapping_from_mesh
from repro.models.partitioning import batch_axes
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step, \
    train_state_axes

cfg = get_config("granite-3-2b", smoke=True)
opt = AdamWConfig()
specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
ss = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg,
                                             opt))
st_sh = resolve_tree(ss, train_state_axes(ss), mesh, TRAIN_RULES)
b_sh = resolve_tree(specs, batch_axes(specs), mesh, TRAIN_RULES)
with mesh, activation_sharding(mapping_from_mesh(mesh, TRAIN_RULES)):
    compiled = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None)
                       ).lower(ss, specs).compile()
out["compiled"] = True
out["hlo_has_collectives"] = ("all-reduce" in compiled.as_text()
                              or "all-gather" in compiled.as_text())

# --- and actually RUN the sharded train step on 8 devices ---------------
state = init_train_state(jax.random.PRNGKey(1), cfg, opt)
state = jax.device_put(state, st_sh)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
batch = jax.device_put(batch, b_sh)
with mesh, activation_sharding(mapping_from_mesh(mesh, TRAIN_RULES)):
    step = jax.jit(make_train_step(cfg, opt), in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None))
    state2, metrics = step(state, batch)
out["sharded_loss"] = float(metrics["loss"])

# --- shard_map group-local MoE == GSPMD global routing (no drops) --------
import dataclasses
cfg0 = get_config("mixtral-8x22b", smoke=True)
cfg_g = dataclasses.replace(cfg0, moe_impl="gspmd", capacity_factor=8.0)
cfg_s = dataclasses.replace(cfg0, moe_impl="shard_map", capacity_factor=8.0)
from repro.models import init_params, loss_fn
mparams = init_params(jax.random.PRNGKey(2), cfg_g)
mtoks = jax.random.randint(key, (4, 33), 0, cfg0.vocab)
mbatch = {"tokens": mtoks[:, :32], "labels": mtoks[:, 1:]}
with mesh, activation_sharding(mapping_from_mesh(mesh, TRAIN_RULES),
                               mesh=mesh):
    lg, _ = jax.jit(lambda p, b: loss_fn(cfg_g, p, b))(mparams, mbatch)
    ls, _ = jax.jit(lambda p, b: loss_fn(cfg_s, p, b))(mparams, mbatch)
out["moe_gspmd_loss"] = float(lg)
out["moe_shard_map_loss"] = float(ls)

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_distributed_bootstrap_estimate(subproc_result):
    r = subproc_result
    assert abs(r["dist_est"] - r["true"]) < 0.1
    assert 0 < r["dist_cv"] < 0.05


def test_distributed_cv_comparable_to_local(subproc_result):
    r = subproc_result
    assert abs(r["dist_cv"] - r["local_cv"]) / r["local_cv"] < 1.0


def test_ragged_sample_masked_correctly(subproc_result):
    r = subproc_result
    assert abs(r["ragged_est"] - r["ragged_true"]) < 1e-3


def test_small_mesh_dryrun_compiles(subproc_result):
    assert subproc_result["compiled"]
    assert subproc_result["hlo_has_collectives"]


def test_sharded_train_step_runs(subproc_result):
    assert subproc_result["sharded_loss"] > 0


def test_shard_map_moe_matches_gspmd(subproc_result):
    """Group-local routing (H2) == global routing in the no-drop regime."""
    r = subproc_result
    assert abs(r["moe_gspmd_loss"] - r["moe_shard_map_loss"]) < 2e-3


class TestFusedPrefixMaskGuard:
    """The fused backend used to express masking as an n_valid prefix
    count and REFUSE interior masks (the old ROADMAP "known modeling
    limits" entry).  Masks now multiply the implicit weight tiles, so
    interior holes (ft/ failed shards) run on the fused backend and must
    MATCH the default-backend oracle (runs in-process on a 1-device mesh;
    the 8-device behavior is identical since the mask rides shard-local)."""

    @staticmethod
    def _earl(backend):
        import jax
        import numpy as np
        from jax.sharding import Mesh

        from repro.core import DistributedEarl, Mean
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return DistributedEarl(mesh, Mean(), B=8, backend=backend)

    def test_interior_mask_accepted_and_matches_oracle(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        earl = self._earl("fused_rng")
        oracle = self._earl(None)
        x = jnp.arange(16.0) + 1.0
        mask = jnp.ones((16,)).at[3].set(0.0)          # interior zero
        res = earl.estimate_with_loss_mask(x, mask, jax.random.PRNGKey(0))
        ref = oracle.estimate_with_loss_mask(x, mask, jax.random.PRNGKey(0))
        exp = float(jnp.sum(x * mask) / jnp.sum(mask))
        assert abs(float(np.ravel(res.estimate)[0]) - exp) < 1e-5
        # same estimator as the default backend (estimates agree exactly:
        # both are the mask-weighted statistic of the same rows)
        np.testing.assert_allclose(np.ravel(res.estimate),
                                   np.ravel(ref.estimate), rtol=1e-6)
        assert res.n == ref.n == 15
        assert np.isfinite(np.asarray(res.thetas)).all()

    def test_prefix_mask_accepted(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        earl = self._earl("fused_rng")
        x = jnp.arange(16.0) + 1.0
        mask = (jnp.arange(16) < 10).astype(jnp.float32)
        res = earl.estimate_with_loss_mask(x, mask, jax.random.PRNGKey(0))
        ref = float(jnp.mean(x[:10]))
        assert abs(float(np.ravel(res.estimate)[0]) - ref) < 1e-5

    def test_default_backend_still_handles_interior_masks(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        earl = self._earl(None)
        x = jnp.arange(16.0) + 1.0
        mask = jnp.ones((16,)).at[3].set(0.0)
        res = earl.estimate_with_loss_mask(x, mask, jax.random.PRNGKey(0))
        ref = float(jnp.sum(x * mask) / jnp.sum(mask))
        assert abs(float(np.ravel(res.estimate)[0]) - ref) < 1e-5
