"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Mean, Quantile, Sum, Var, coefficient_of_variation,
                        p_shared, work_saved)
from repro.core.reduce_api import _as_2d

_settings = settings(max_examples=30, deadline=None)

floats = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   width=32)
arrays = st.lists(floats, min_size=4, max_size=60)
weights_st = st.lists(st.floats(min_value=0, max_value=5, allow_nan=False,
                                width=32), min_size=4, max_size=60)


@_settings
@given(arrays, st.integers(min_value=1, max_value=59))
def test_statistic_merge_associative(vals, split):
    """merge(update(s0, A), update(s0, B)) == update over A++B."""
    x = np.asarray(vals, np.float32)[:, None]
    split = min(split, len(x) - 1)
    for stat in (Mean(), Sum(), Var()):
        s_all = stat.update(stat.init_state(1), x)
        s_ab = stat.merge(stat.update(stat.init_state(1), x[:split]),
                          stat.update(stat.init_state(1), x[split:]))
        np.testing.assert_allclose(np.ravel(stat.finalize(s_ab)),
                                   np.ravel(stat.finalize(s_all)),
                                   rtol=1e-3, atol=1e-3)


@_settings
@given(arrays)
def test_sum_correct_scaling(vals):
    """correct(result, p) = result / p exactly for SUM (paper §2.1)."""
    x = np.asarray(vals, np.float32)
    stat = Sum()
    res = stat(jnp.asarray(x))
    for p in (0.1, 0.5, 1.0):
        np.testing.assert_allclose(np.ravel(stat.correct(res, p)),
                                   np.ravel(res) / p, rtol=1e-6)


@_settings
@given(arrays, st.floats(min_value=0.01, max_value=100))
def test_cv_scale_invariant(vals, scale):
    """c_v(a·X) == c_v(X) for a > 0 (relative error measure)."""
    t = np.abs(np.asarray(vals, np.float32)) + 1.0
    cv1 = float(coefficient_of_variation(jnp.asarray(t)))
    cv2 = float(coefficient_of_variation(jnp.asarray(t * scale)))
    assert abs(cv1 - cv2) < 1e-3 * max(cv1, 1.0)


@_settings
@given(st.integers(min_value=2, max_value=500),
       st.floats(min_value=0.01, max_value=0.99))
def test_p_shared_is_probability(n, y):
    p = p_shared(n, y)
    assert 0.0 <= p <= 1.0
    assert 0.0 <= work_saved(n, y) <= 1.0


@_settings
@given(st.lists(st.floats(min_value=0, max_value=1, allow_nan=False,
                          width=32), min_size=10, max_size=80),
       st.floats(min_value=0.05, max_value=0.95))
def test_quantile_histogram_close_to_exact(vals, q):
    """The histogram sketch implements the inverted-CDF quantile (first
    bin where CDF >= q) — compare against numpy's matching method, not its
    default linear interpolation (they differ on atomic distributions)."""
    x = np.asarray(vals, np.float32)
    stat = Quantile(q, nbins=4096, lo=-0.01, hi=1.01)
    est = float(np.ravel(stat(jnp.asarray(x)))[0])
    exact = float(np.quantile(x, q, method="inverted_cdf"))
    assert abs(est - exact) <= 2 * (1.02 / 4096)


@_settings
@given(weights_st)
def test_weighted_update_equals_repeat(ws):
    """Integer-weighted update == updating with repeated rows — the
    identity that makes counts-based resampling valid (DESIGN.md §2)."""
    w = np.floor(np.asarray(ws, np.float32))
    x = np.arange(len(w), dtype=np.float32)[:, None] / 7.0
    if w.sum() < 1:
        return
    stat = Mean()
    s_w = stat.update(stat.init_state(1), x, w)
    reps = np.repeat(x[:, 0], w.astype(int))[:, None]
    s_r = stat.update(stat.init_state(1), reps)
    np.testing.assert_allclose(np.ravel(stat.finalize(s_w)),
                               np.ravel(stat.finalize(s_r)), rtol=1e-4)


@_settings
@given(st.integers(min_value=1, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=300))
def test_poisson_kernel_always_valid(seed, B, n):
    """Kernel output is integral, nonnegative, bounded by the ladder."""
    from repro.kernels.poisson_counts import ops as pc_ops
    c = np.asarray(pc_ops.poisson_counts(seed, B, n,
                                         backend="pallas_interpret"))
    assert c.shape == (B, n)
    assert (c >= 0).all() and (c <= 10).all()
    np.testing.assert_array_equal(c, np.round(c))
