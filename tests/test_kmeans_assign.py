"""Fused k-means assignment kernel + session/statistic bugfix sweep (ISSUE 2).

Covers the acceptance criteria:
  * kmeans_assign (weighted + implicit-weight variants) == materialized
    (n, k) oracle on every lowering; scan == interpret
  * fused_poisson_kmeans == contracting the materialized implicit weights
    resample-by-resample (same counter-based tile discipline as
    weighted_stats)
  * shape-capture harness: bootstrap-over-k-means on the fused path at
    n=2^20 contains NO (n, k) or (B, n) intermediate anywhere in its jaxpr
    (and the harness itself flags the materialized KMeansStep.update)
  * statistical equivalence of fused bootstrap-over-k-means cv vs the
    materialized oracle
  * Lloyd loops compile once: fresh same-shaped KMeansStep instances hit
    one _bootstrap_jit / _pd_extend_jit / _kmeans_fit_jit cache entry
    (centroids are traced params, not jit-static constants keyed by id())
  * inertia stays >= 0 for points at/near centroids (d² clamp)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KMeansStep, bootstrap, bootstrap_chunked,
                        kmeans_fit)
from repro.core.bootstrap import _bootstrap_jit
from repro.core.delta import (_pd_extend_jit, poisson_delta_extend,
                              poisson_delta_init, poisson_delta_result)
from repro.core.reduce_api import _kmeans_fit_jit
from repro.kernels.kmeans_assign import ops as ka_ops
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.weighted_stats import ops as ws_ops
from test_matrix_free import _max_intermediate_size


# ----------------------------------------------------------------------------
# single-state assignment pass vs the materialized oracle
# ----------------------------------------------------------------------------
class TestAssignParity:
    @pytest.mark.parametrize("n,k,d", [
        (64, 2, 1), (500, 5, 2), (1030, 7, 3), (256, 16, 5),
    ])
    def test_weighted_matches_ref(self, key, n, k, d):
        x = jax.random.normal(key, (n, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
        cent = jax.random.normal(jax.random.fold_in(key, 2), (k, d)) * 2
        ref = kmeans_assign_ref(x, w, cent)
        for backend in ("scan", "pallas_interpret"):
            out = ka_ops.kmeans_assign(x, w, cent, backend=backend)
            for a, b in zip(out, ref):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-5, atol=2e-5,
                                           err_msg=backend)

    def test_implicit_weights_variant(self, key):
        """weights=None == all-ones weights."""
        x = jax.random.normal(key, (700, 3))
        cent = x[:5]
        a = ka_ops.kmeans_assign(x, None, cent, backend="scan")
        b = ka_ops.kmeans_assign(x, jnp.ones((700,)), cent, backend="scan")
        for u, v in zip(a, b):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))

    def test_scan_equals_interpret(self, key):
        x = jax.random.normal(key, (900, 4))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (900,)))
        cent = jax.random.normal(jax.random.fold_in(key, 2), (6, 4))
        a = ka_ops.kmeans_assign(x, w, cent, backend="scan")
        b = ka_ops.kmeans_assign(x, w, cent, backend="pallas_interpret")
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-6)

    def test_kmeans_step_backend_matches_jnp(self, key):
        x = jax.random.normal(key, (513, 2)) + 3
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (513,)))
        cent = x[:4]
        s_jnp = KMeansStep(cent)
        s_ker = KMeansStep(cent, backend="scan")
        a = s_jnp.update(s_jnp.init_state(2), x, w)
        b = s_ker.update(s_ker.init_state(2), x, w)
        np.testing.assert_allclose(np.asarray(a.sums), np.asarray(b.sums),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(a.counts),
                                   np.asarray(b.counts), rtol=1e-6)
        np.testing.assert_allclose(float(a.inertia), float(b.inertia),
                                   rtol=2e-5)

    def test_rejects_unknown_backend(self):
        with pytest.raises(ValueError):
            KMeansStep(jnp.zeros((2, 2)), backend="cuda")


# ----------------------------------------------------------------------------
# matrix-free bootstrap path vs the implicit-weights oracle
# ----------------------------------------------------------------------------
class TestFusedPoissonKMeans:
    @pytest.mark.parametrize("B,n,k,d", [
        (7, 130, 3, 2), (24, 700, 5, 2), (129, 1000, 9, 4),
    ])
    def test_matches_implicit_weights_oracle(self, key, B, n, k, d):
        """Fused output == per-resample contraction of the materialized
        implicit weight matrix (same threefry tile discipline)."""
        x = jax.random.normal(key, (n, d))
        cent = jax.random.normal(jax.random.fold_in(key, 3), (k, d))
        W = ws_ops.implicit_weights(42, B, n)
        ref = jax.vmap(lambda wr: kmeans_assign_ref(x, wr, cent))(W)
        for backend in ("scan", "pallas_interpret"):
            out = ka_ops.fused_poisson_kmeans(42, x, cent, B,
                                              backend=backend)
            for a, b in zip(out, ref):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-4, atol=1e-3,
                                           err_msg=backend)

    def test_n_valid_masks_padding(self, key):
        n, pad = 700, 1024 - 700
        x = jax.random.normal(key, (n, 2))
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        a = ka_ops.fused_poisson_kmeans(3, x, x[:4], 16)
        b = ka_ops.fused_poisson_kmeans(3, xp, x[:4], 16, n_valid=n)
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-6)

    def test_deterministic_and_seed_sensitive(self, key):
        x = jax.random.normal(key, (512, 2))
        a = ka_ops.fused_poisson_kmeans(5, x, x[:3], 16)
        b = ka_ops.fused_poisson_kmeans(5, x, x[:3], 16)
        c = ka_ops.fused_poisson_kmeans(6, x, x[:3], 16)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


# ----------------------------------------------------------------------------
# statistical equivalence through bootstrap / chunked / delta
# ----------------------------------------------------------------------------
class TestBootstrapOverKMeans:
    def test_fused_cv_matches_materialized(self, key):
        x = jax.random.normal(key, (3000, 2)) * 0.3 \
            + jnp.array([[4.0, -4.0]])
        cent = x[:5]
        r_mat = bootstrap(x, KMeansStep(cent), B=64, key=key)
        r_fus = bootstrap(x, KMeansStep(cent), B=64, key=key,
                          backend="fused_rng")
        # same estimator on the unweighted sample, bit-for-bit comparable
        np.testing.assert_allclose(np.asarray(r_mat.estimate),
                                   np.asarray(r_fus.estimate), rtol=1e-5)
        assert abs(r_fus.cv - r_mat.cv) / (r_mat.cv + 1e-12) < 0.5

    def test_chunked_fused_matches_unchunked(self, key):
        x = jax.random.normal(key, (2001, 2)) + 5
        cent = x[:4]
        r_plain = bootstrap(x, KMeansStep(cent), B=32, key=key,
                            backend="fused_rng")
        r_chunk = bootstrap_chunked(x, KMeansStep(cent), B=32, key=key,
                                    chunk=512, backend="fused_rng")
        assert r_chunk.n == 2001
        np.testing.assert_allclose(np.asarray(r_plain.estimate),
                                   np.asarray(r_chunk.estimate), rtol=1e-5)
        assert abs(r_plain.cv - r_chunk.cv) / (r_plain.cv + 1e-12) < 0.5

    def test_delta_extend_fused(self, key):
        x = jax.random.normal(key, (900, 2)) + 2
        cent = x[:3]
        pd = poisson_delta_init(KMeansStep(cent), 24, 2, key,
                                backend="fused_rng")
        for piece in (x[:400], x[400:]):
            pd = poisson_delta_extend(pd, piece)
        res = poisson_delta_result(pd)
        assert np.isfinite(res.cv)
        assert res.thetas.shape[0] == 24


# ----------------------------------------------------------------------------
# jaxpr shape capture: no (n, k) / (B, n) HBM intermediate
# ----------------------------------------------------------------------------
class TestNoAssignmentMatrix:
    B, N, K = 256, 1 << 20, 8

    def test_fused_pipeline_never_builds_nk_or_Bn(self, key):
        """n=2^20, B=256, k=8: every intermediate in the traced fused
        bootstrap-over-k-means pipeline is far smaller than both the (n, k)
        one-hot (8.4M elements) and the (B, n) weight matrix (268M)."""
        from repro.core.bootstrap import _fused_thetas
        x = jnp.zeros((self.N, 1), jnp.float32)
        cent = jnp.zeros((self.K, 1), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: _fused_thetas(v, KMeansStep(cent), self.B, k),
            x, key)
        # the (N, 1) input itself is the largest legitimate buffer
        assert biggest <= self.N, (
            f"largest intermediate has {biggest} elements — (n, k) would "
            f"be {self.N * self.K}, (B, n) would be {self.B * self.N}")

    def test_harness_detects_materialized_onehot(self, key):
        """Sanity: the same harness DOES flag the jnp KMeansStep.update."""
        x = jnp.zeros((self.N, 1), jnp.float32)
        cent = jnp.zeros((self.K, 1), jnp.float32)
        step = KMeansStep(cent)
        biggest = _max_intermediate_size(
            lambda v: step.update(step.init_state(1), v).counts, x)
        assert biggest >= self.N * self.K


# ----------------------------------------------------------------------------
# compilation-count regression: centroids are traced, not id()-keyed
# ----------------------------------------------------------------------------
class TestCompileOnce:
    def test_bootstrap_compiles_once_across_lloyd_iterations(self, key):
        """Fresh same-shaped KMeansStep per Lloyd iteration must hit ONE
        _bootstrap_jit entry (historically _static_key keyed centroids by
        id(), so every instance recompiled)."""
        x = jax.random.normal(key, (400, 2))
        cents = x[:4]
        _bootstrap_jit._clear_cache()
        for _ in range(3):
            bootstrap(x, KMeansStep(cents), B=8, key=key,
                      backend="fused_rng")
            step = KMeansStep(cents)
            cents = step.finalize(step.update(step.init_state(2), x))
        assert _bootstrap_jit._cache_size() == 1

    def test_delta_extend_compiles_once(self, key):
        x = jax.random.normal(key, (256, 2))
        _pd_extend_jit._clear_cache()
        for i in range(3):
            cent = x[i:i + 4]          # fresh array each time
            pd = poisson_delta_init(KMeansStep(cent), 8, 2, key,
                                    backend="fused_rng")
            poisson_delta_extend(pd, x)
        assert _pd_extend_jit._cache_size() == 1

    def test_kmeans_fit_compiles_once(self, key):
        x = jax.random.normal(key, (300, 2))
        _kmeans_fit_jit._clear_cache()
        kmeans_fit(x, 4, 3, key)
        kmeans_fit(x + 1.0, 4, 3, jax.random.fold_in(key, 1))
        assert _kmeans_fit_jit._cache_size() == 1

    def test_same_shape_steps_equal_as_static_keys(self):
        """split_params specs of same-shaped KMeansSteps compare equal; the
        bound statistics themselves still don't (different centroids)."""
        from repro.core.reduce_api import split_params
        a = KMeansStep(jnp.zeros((3, 2)))
        b = KMeansStep(jnp.ones((3, 2)))
        assert a != b
        sa, pa = split_params(a)
        sb, pb = split_params(b)
        assert sa == sb and hash(sa) == hash(sb)
        assert set(pa) == {"centroids"} and pb["centroids"].shape == (3, 2)


# ----------------------------------------------------------------------------
# inertia clamp
# ----------------------------------------------------------------------------
class TestInertiaClamp:
    def _near_centroid_data(self, rng):
        """Points jittered ~1e-4 around magnitude-100 centroids: the
        expanded ‖x‖² − 2x·c + ‖c‖² goes below 0 in f32 for ~30% of them
        (verified against the unclamped formula below)."""
        cent = rng.normal(0, 100, (5, 2)).astype(np.float32)
        idx = rng.integers(0, 5, 400)
        x = (cent[idx].astype(np.float64)
             + rng.normal(0, 1e-4, (400, 2))).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(cent)

    def test_expanded_form_does_go_negative(self, rng):
        """The regression is real: without the clamp this data yields a
        negative min-d² somewhere (else the clamp test is vacuous)."""
        x, cent = self._near_centroid_data(rng)
        raw = (jnp.sum(x * x, -1, keepdims=True) - 2.0 * x @ cent.T
               + jnp.sum(cent * cent, -1))
        assert float(jnp.min(raw)) < 0.0

    def test_inertia_nonnegative_everywhere(self, rng):
        x, cent = self._near_centroid_data(rng)
        for stat in (KMeansStep(cent), KMeansStep(cent, backend="scan"),
                     KMeansStep(cent, backend="pallas_interpret")):
            st = stat.update(stat.init_state(2), x)
            assert float(st.inertia) >= 0.0, stat.backend
        _, _, inertia = kmeans_assign_ref(x, jnp.ones((x.shape[0],)), cent)
        assert float(inertia) >= 0.0
        _, _, fused_inertia = ka_ops.fused_poisson_kmeans(11, x, cent, 16)
        assert float(jnp.min(fused_inertia)) >= 0.0
