"""Checkpointing + fault tolerance: roundtrips, keep-k, shard-loss
recovery with error bounds, straggler deadline, elastic mesh."""
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core import DistributedEarl, Mean, Sum
from repro.data import synthetic_numeric
from repro.ft import (DeadlineReducer, estimate_with_failures, failure_mask,
                      mesh_for_devices)


def _one_device_mesh():
    import numpy as np
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


class TestCheckpoint:
    def _state(self, key):
        return {"params": {"w": jax.random.normal(key, (32, 8)),
                           "b": jnp.zeros(8)},
                "step": jnp.int32(7)}

    def test_roundtrip(self, key, tmp_path):
        state = self._state(key)
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(7, state, extra={"pipeline": {"epoch": 1, "step": 40}})
        template = jax.eval_shape(lambda: state)
        restored, extra = mgr.restore(template)
        np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                      np.asarray(restored["params"]["w"]))
        assert extra == {"pipeline": {"epoch": 1, "step": 40}}

    def test_keep_last_k(self, key, tmp_path):
        state = self._state(key)
        mgr = CheckpointManager(str(tmp_path), keep_last=2,
                                async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.steps() == [3, 4]

    def test_async_save_waits(self, key, tmp_path):
        state = self._state(key)
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, state)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_atomicity_no_tmp_dirs(self, key, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(3, self._state(key))
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]

    def test_restore_with_shardings(self, key, tmp_path):
        state = self._state(key)
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, state)
        mesh = _one_device_mesh()
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), state)
        restored, _ = mgr.restore(jax.eval_shape(lambda: state),
                                  shardings=sh)
        assert restored["params"]["w"].sharding == NamedSharding(mesh, P())

    def test_shape_mismatch_rejected(self, key, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._state(key))
        bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(8)},
               "step": jnp.int32(0)}
        with pytest.raises(ValueError):
            mgr.restore(jax.eval_shape(lambda: bad))


class TestCheckpointHygiene:
    def _state(self):
        return {"w": jnp.arange(8, dtype=jnp.float32)}

    def test_orphaned_tmp_dirs_collected_on_init(self, tmp_path):
        """A crash between staging and the atomic rename leaves a
        ``.tmp_ckpt_*`` dir that no committed checkpoint owns — a fresh
        manager over the same root must sweep it."""
        orphan = tmp_path / ".tmp_ckpt_00000007"
        orphan.mkdir()
        (orphan / "arrays.npz").write_bytes(b"partial write")
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        assert not [d for d in os.listdir(tmp_path)
                    if d.startswith(".tmp_ckpt_")]
        mgr.save(1, self._state())
        assert mgr.steps() == [1]

    def test_steps_skips_malformed_entries(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(4, self._state())
        (tmp_path / "ckpt_old").mkdir()              # non-numeric suffix
        (tmp_path / "ckpt_").mkdir()                 # empty suffix
        (tmp_path / "ckpt_00000009").write_text("a stray FILE, not a dir")
        (tmp_path / "notes.txt").write_text("unrelated")
        assert mgr.steps() == [4]
        assert mgr.latest_step() == 4
        restored, _ = mgr.restore(jax.eval_shape(self._state))
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(8, dtype=np.float32))

    def test_close_flushes_pending_async_write(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(2, self._state())
        mgr.close()
        assert CheckpointManager(str(tmp_path)).latest_step() == 2
        mgr.close()                                  # idempotent

    def test_context_manager_commits_on_exit(self, tmp_path):
        with CheckpointManager(str(tmp_path), async_save=True) as mgr:
            mgr.save(5, self._state())
        assert CheckpointManager(str(tmp_path)).latest_step() == 5

    def test_meta_reads_cursor_without_arrays(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._state(), extra={"cursor": {"next_chunk": 3}})
        mgr.save(2, self._state(), extra={"cursor": {"next_chunk": 9}})
        assert mgr.meta() == {"cursor": {"next_chunk": 9}}
        assert mgr.meta(step=1) == {"cursor": {"next_chunk": 3}}
        with pytest.raises(FileNotFoundError):
            CheckpointManager(str(tmp_path / "empty")).meta()


class TestConcurrentManagers:
    """Two standing sessions may share one checkpoint directory — scoping
    by run fingerprint (``for_run``) and pid-aware orphan GC must keep
    them from clobbering or garbage-collecting each other."""

    def _state(self, v):
        return {"w": jnp.full(4, float(v))}

    def test_for_run_scopes_by_fingerprint(self, tmp_path):
        a = CheckpointManager.for_run(str(tmp_path), "a" * 64,
                                      async_save=False)
        b = CheckpointManager.for_run(str(tmp_path), "b" * 64,
                                      async_save=False)
        assert a.root != b.root
        assert a.root.startswith(str(tmp_path))
        a.save(1, self._state(1.0))
        b.save(1, self._state(2.0))
        ra, _ = a.restore(jax.eval_shape(lambda: self._state(0)))
        rb, _ = b.restore(jax.eval_shape(lambda: self._state(0)))
        assert float(np.asarray(ra["w"])[0]) == 1.0
        assert float(np.asarray(rb["w"])[0]) == 2.0

    def test_same_fingerprint_shares_a_root(self, tmp_path):
        a = CheckpointManager.for_run(str(tmp_path), "f" * 64,
                                      async_save=False)
        b = CheckpointManager.for_run(str(tmp_path), "f" * 64,
                                      async_save=False)
        assert a.root == b.root          # same run resumes the same dir

    def test_peer_keep_k_gc_does_not_cross_runs(self, tmp_path):
        """Manager A cycling through keep_last=2 steps must never delete
        manager B's (older) steps in the shared parent directory."""
        a = CheckpointManager.for_run(str(tmp_path), "a" * 64,
                                      keep_last=2, async_save=False)
        b = CheckpointManager.for_run(str(tmp_path), "b" * 64,
                                      keep_last=2, async_save=False)
        b.save(1, self._state(9.0))
        for s in range(1, 6):
            a.save(s, self._state(s))
        assert a.steps() == [4, 5]
        assert b.steps() == [1], "peer GC crossed run boundaries"

    def test_orphan_gc_spares_live_peer_tmp_dir(self, tmp_path):
        """A ``.tmp_ckpt_*.<pid>`` staging dir whose pid is ALIVE belongs
        to a peer mid-save — a fresh manager must not sweep it.  A dead
        pid or the old unsuffixed format is a crash leftover: reaped."""
        live = tmp_path / f".tmp_ckpt_00000003.{os.getpid()}"
        live.mkdir()
        proc = subprocess.Popen(["sleep", "0"])
        proc.wait()                      # this pid is now definitely dead
        dead = tmp_path / f".tmp_ckpt_00000004.{proc.pid}"
        dead.mkdir()
        old = tmp_path / ".tmp_ckpt_00000005"
        old.mkdir()
        CheckpointManager(str(tmp_path), async_save=False)
        assert live.exists(), "swept a live peer's in-flight save"
        assert not dead.exists(), "kept a dead process's leftover"
        assert not old.exists(), "kept an unattributable leftover"
        live.rmdir()

    def test_two_live_sessions_share_a_root(self, tmp_path):
        """End to end: two LiveSessions with different statistics pointed
        at the SAME checkpoint path both checkpoint and both resume."""
        from repro.core import Mean, Var
        from repro.live import IngestLog, LiveSession

        key = jax.random.PRNGKey(21)
        rng = np.random.default_rng(0)
        log = IngestLog()
        for _ in range(4):
            log.append(rng.normal(size=(32, 2)).astype(np.float32))
        root = str(tmp_path / "shared")
        s1 = LiveSession(log, Mean(), B=8, key=key, checkpoint=root,
                         name="mean")
        s2 = LiveSession(log, Var(), B=8, key=key, checkpoint=root,
                         name="var")
        s1.poll()
        s2.poll()
        s1.checkpoint.wait()             # resume reads COMMITTED snapshots;
        s2.checkpoint.wait()             # the last async save may be in flight
        assert s1.checkpoint.root != s2.checkpoint.root
        r1 = LiveSession(log, Mean(), B=8, key=key, checkpoint=root,
                         resume=True, name="mean")
        r2 = LiveSession(log, Var(), B=8, key=key, checkpoint=root,
                         resume=True, name="var")
        assert r1.counters.folded == r2.counters.folded == 4
        for s, r in ((s1, r1), (s2, r2)):
            a, b = s.report(), r.report()
            np.testing.assert_array_equal(np.asarray(a.estimate),
                                          np.asarray(b.estimate))


class TestCheckpointCrashSafety:
    """Crash-safety hardening: stale-pid orphan GC and ENOSPC-safe save
    (a failed snapshot must leave the previous checkpoint loadable)."""

    def _state(self, v=1.0):
        return {"w": jnp.full(4, float(v))}

    def test_stale_live_pid_tmp_dir_is_reaped(self, tmp_path):
        """Pid recycling: a staging dir whose pid LOOKS alive but whose
        mtime is hours old is a crashed writer's leftover under a reused
        pid, not a peer mid-write — it must be reaped (in-flight writes
        are seconds old)."""
        from repro.checkpoint.manager import STALE_TMP_S

        stale = tmp_path / f".tmp_ckpt_00000001.{os.getpid()}"
        stale.mkdir()
        old = time.time() - STALE_TMP_S - 60.0
        os.utime(stale, (old, old))
        fresh = tmp_path / f".tmp_ckpt_00000002.{os.getpid()}"
        fresh.mkdir()
        CheckpointManager(str(tmp_path), async_save=False)
        assert not stale.exists(), "kept a recycled pid's stale staging dir"
        assert fresh.exists(), "swept a live peer's in-flight save"
        fresh.rmdir()

    def test_absurd_pid_suffix_is_swept_not_fatal(self, tmp_path):
        """A staging dir named with a huge bogus pid must be reaped, not
        raise OverflowError out of the GC sweep."""
        bogus = tmp_path / ".tmp_ckpt_00000001.99999999999999999999"
        bogus.mkdir()
        CheckpointManager(str(tmp_path), async_save=False)
        assert not bogus.exists()

    def test_crash_mid_swap_backup_is_reaped_and_invisible(self, tmp_path):
        """A death between the two commit renames leaves the old snapshot
        as ``ckpt_*.old.<pid>``: steps() must not see it, and a fresh
        manager must reap it once the writer is dead."""
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._state(1.0))
        proc = subprocess.Popen(["sleep", "0"])
        proc.wait()
        backup = tmp_path / f"ckpt_00000001.old.{proc.pid}"
        backup.mkdir()
        (backup / "meta.json").write_text("{}")
        assert mgr.steps() == [1]            # backups are not checkpoints
        CheckpointManager(str(tmp_path), async_save=False)
        assert not backup.exists(), "kept a dead writer's commit backup"
        restored, _ = mgr.restore(jax.eval_shape(lambda: self._state(0)))
        assert float(np.asarray(restored["w"])[0]) == 1.0

    def test_enospc_save_raises_and_previous_checkpoint_survives(
            self, tmp_path, monkeypatch):
        """A save that dies mid-write (ENOSPC / partial write) must raise
        loudly, leave no staging debris, and leave the PREVIOUS
        checkpoint fully loadable."""
        import errno

        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._state(1.0))

        def _no_space(*a, **k):
            raise OSError(errno.ENOSPC, "No space left on device")

        monkeypatch.setattr(np, "savez", _no_space)
        with pytest.raises(OSError):
            mgr.save(2, self._state(2.0))
        monkeypatch.undo()

        assert not [d for d in os.listdir(tmp_path)
                    if d.startswith(".tmp_ckpt_")], "staging debris left"
        assert mgr.steps() == [1]
        restored, _ = mgr.restore(jax.eval_shape(lambda: self._state(0)))
        assert float(np.asarray(restored["w"])[0]) == 1.0
        # and the manager is not wedged: the next save commits normally
        mgr.save(3, self._state(3.0))
        assert mgr.latest_step() == 3

    def test_enospc_async_save_surfaces_on_wait(self, tmp_path,
                                                monkeypatch):
        import errno

        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, self._state(1.0))
        mgr.wait()

        def _no_space(*a, **k):
            raise OSError(errno.ENOSPC, "No space left on device")

        monkeypatch.setattr(np, "savez", _no_space)
        mgr.save(2, self._state(2.0))
        with pytest.raises(OSError):
            mgr.wait()
        monkeypatch.undo()
        assert mgr.steps() == [1]


class TestShardLossRecovery:
    def _earl(self):
        return DistributedEarl(_one_device_mesh(), Mean(), B=64,
                               data_axes=("data",))

    def test_survivors_estimate_unbiased(self, key):
        data = synthetic_numeric(32_768, 10, 2, seed=1)
        rep = estimate_with_failures(self._earl(), jnp.asarray(data),
                                     lost_shards=[0, 3, 7], n_shards=16,
                                     sigma=0.05, key=key)
        assert rep.shards_lost == 3
        assert rep.p_surviving == pytest.approx(13 / 16, abs=0.01)
        assert abs(float(np.ravel(rep.result)[0]) - 10.0) < 0.2
        assert rep.meets_bound            # mean is easy: bound met
        assert "defer node recovery" in rep.recommendation

    def test_sum_rescaled_by_survivors(self, key):
        data = synthetic_numeric(16_384, 10, 2, seed=2)
        earl = DistributedEarl(_one_device_mesh(), Sum(), B=64,
                               data_axes=("data",))
        rep = estimate_with_failures(earl, jnp.asarray(data),
                                     lost_shards=[1], n_shards=8,
                                     sigma=0.05, key=key)
        true = float(data.sum())
        assert abs(float(np.ravel(rep.result)[0]) - true) / true < 0.05, \
            "§3.4 + correct(1/p): survivors-only SUM must be rescaled"

    def test_catastrophic_loss_triggers_recovery(self, key):
        data = synthetic_numeric(4096, 10, 200, seed=3)   # high variance
        rep = estimate_with_failures(self._earl(), jnp.asarray(data),
                                     lost_shards=list(range(15)),
                                     n_shards=16, sigma=0.001, key=key)
        assert not rep.meets_bound
        assert "restart" in rep.recommendation

    def test_failure_mask(self):
        m = np.asarray(failure_mask(100, 10, [0, 9]))
        assert m[:10].sum() == 0 and m[90:].sum() == 0
        assert m.sum() == 80

    def test_failure_mask_ragged_rows_align_with_shard_extents(self):
        """n % n_shards != 0: extents must mirror ``pad_to_shards``' ceil
        division — shard s owns rows [s·m, min((s+1)·m, n)) with
        m = ceil(n/n_shards).  The old floor-division extents drifted off
        the real shard boundaries and the tail rows were unmaskable."""
        n, shards = 103, 10
        m = -(-n // shards)                          # 11
        for s in range(shards):
            mask = np.asarray(failure_mask(n, shards, [s]))
            lo, hi = s * m, min((s + 1) * m, n)
            assert mask[lo:hi].sum() == 0
            assert mask.sum() == n - (hi - lo), f"shard {s}"
        # the LAST shard's (short) extent is maskable at all
        last = np.asarray(failure_mask(n, shards, [shards - 1]))
        assert last[99:].sum() == 0 and last.sum() == 99

    def test_failure_mask_validates_inputs(self):
        with pytest.raises(ValueError, match="n_shards"):
            failure_mask(100, 0, [])
        with pytest.raises(ValueError, match="out of range"):
            failure_mask(100, 10, [10])


class TestStraggler:
    def test_deadline_reduce(self, key):
        data = synthetic_numeric(16_384, 10, 2, seed=4)
        earl = DistributedEarl(_one_device_mesh(), Mean(), B=64,
                               data_axes=("data",))
        red = DeadlineReducer(earl, n_shards=8, sigma=0.05)
        times = [0.1] * 7 + [9.9]                  # one straggler
        rep = red.reduce(jnp.asarray(data), times, deadline_s=1.0, key=key)
        assert rep.late == 1 and rep.on_time == 7
        assert rep.report.meets_bound


class TestElastic:
    def test_mesh_for_devices_shrinks_model_axis(self):
        m = mesh_for_devices(1, model_parallel=16)
        assert m.shape["model"] == 1 and m.shape["data"] == 1
