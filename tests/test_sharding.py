"""Sharding resolver + HLO analysis unit tests (no multi-device needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.hlo_analysis import (collective_bytes, split_computations,
                                       while_trip_counts, _shape_bytes)
from repro.launch.hlo_flops import dot_flops
from repro.launch.sharding import (SERVE_RULES, TRAIN_RULES, resolve_spec)


class _FakeMesh:
    """Duck-typed mesh: resolver only reads .shape (name -> size)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


SINGLE = _FakeMesh(data=16, model=16)
MULTI = _FakeMesh(pod=2, data=16, model=16)


class TestResolver:
    def test_fsdp_weight(self):
        spec = resolve_spec((2048, 8192), ("embed", "mlp"), SINGLE,
                            TRAIN_RULES)
        assert spec == P("data", "model")

    def test_kv_heads_fallback_replicates(self):
        # 8 kv heads unsplittable over model=16 -> replicated
        spec = resolve_spec((2048, 8, 128), ("embed", "kv_heads",
                                             "head_dim"), SINGLE,
                            TRAIN_RULES)
        assert spec == P("data", None, None)

    def test_batch_takes_pod_and_data(self):
        spec = resolve_spec((256, 4096), ("batch", "seq"), MULTI,
                            TRAIN_RULES)
        assert spec == P(("pod", "data"), None)

    def test_batch_partial_prefix(self):
        # batch 2 divisible by pod(2) but not pod*data(32)
        spec = resolve_spec((2, 128), ("batch", "seq"), MULTI, TRAIN_RULES)
        assert spec == P("pod", None)

    def test_flash_decode_fallback(self):
        """batch=1 can't shard -> the cache sequence axis claims data."""
        spec = resolve_spec((1, 8, 524288, 128),
                            ("batch", "kv_heads", "cache_seq", "head_dim"),
                            SINGLE, SERVE_RULES)
        assert spec == P(None, None, "data", None)

    def test_no_double_use_of_axis(self):
        spec = resolve_spec((128, 16, 32768, 128),
                            ("batch", "kv_heads", "cache_seq", "head_dim"),
                            SINGLE, SERVE_RULES)
        # batch grabbed data; kv got model; cache_seq must NOT reuse either
        assert spec == P("data", "model", None, None)

    def test_padded_vocab_divisible(self):
        from repro.configs import ARCH_IDS, get_config
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            assert cfg.padded_vocab % 16 == 0, arch
            assert cfg.padded_vocab >= cfg.vocab

    def test_all_dims_product_divides(self):
        """Property: any resolved spec's axis product divides the dim."""
        rng = np.random.default_rng(0)
        for _ in range(200):
            dims = tuple(int(d) for d in rng.integers(1, 4096, 3))
            axes = tuple(rng.choice(list(TRAIN_RULES)) for _ in range(3))
            spec = resolve_spec(dims, axes, MULTI, TRAIN_RULES)
            for dim, part in zip(dims, spec):
                if part is None:
                    continue
                parts = part if isinstance(part, tuple) else (part,)
                prod = int(np.prod([MULTI.shape[p] for p in parts]))
                assert dim % prod == 0


_FAKE_HLO = """
HloModule jit_step

%body.1 (arg.1: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(f32[64,128]{1,0} %x), replica_groups={}
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ar)
}

%cond.1 (arg.2: (s32[], f32[64,128])) -> pred[] {
  %p2 = (s32[], f32[64,128]) parameter(0)
  %bound = s32[] constant(12)
  ROOT %cmp = pred[] compare(%it, %bound), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,256]{1,0} all-gather(f32[64,128]{1,0} %a), dimensions={1}
  %w = (s32[], f32[64,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""


class TestHloAnalysis:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[2,3]") == 24
        assert _shape_bytes("bf16[10]") == 20
        assert _shape_bytes("(f32[2], s32[4])") == 24

    def test_split_computations(self):
        comps = split_computations(_FAKE_HLO)
        assert set(comps) == {"body.1", "cond.1", "main"}
        assert comps["main"].is_entry

    def test_trip_counts(self):
        trips = dict(while_trip_counts(_FAKE_HLO))
        assert trips["body.1"] == 12

    def test_collective_bytes_trip_multiplied(self):
        out = collective_bytes(_FAKE_HLO)
        # all-gather: 64*256*4 = 65536; all-reduce: 2 * 64*128*4 * 12 trips
        assert out["all-gather"] == 65536
        assert out["all-reduce"] == 2 * 64 * 128 * 4 * 12
        assert out["total"] == out["all-gather"] + out["all-reduce"]

    def test_dot_flops_on_real_module(self, key):
        """Parse a real lowered module: matmul in a scan of length 5."""
        import jax.numpy as jnp

        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y

        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        hlo = jax.jit(f).lower(x, w).compile().as_text()
        out = dot_flops(hlo)
        expected = 2 * 8 * 16 * 16 * 5
        assert out["flops"] == pytest.approx(expected, rel=0.01), out
