"""Sharded matrix-free bootstrap on 8 forced host devices.

Runs in a subprocess (XLA_FLAGS=--xla_force_host_platform_device_count=8
must be set before jax imports; the main pytest process keeps its single
device — see tests/conftest.py) and asserts the ISSUE-3 acceptance
criteria:

  * sharded fused states are BITWISE equal to the single-device oracle
    (``sharded_fused_states(..., mesh=None, nshards=8)``: same per-shard
    streams, sequential left-fold merge) for all three statistic families
    — Moments, Quantile (histogram psum), KMeansStep;
  * the chunked sharded path (streams keyed (base, shard, chunk)) is
    bitwise equal to its oracle too;
  * per-shard streams are pairwise distinct;
  * an nshards=1 mesh reproduces the single-device unsharded fused path
    bitwise (the seed discipline collapses to the chunk/step counter);
  * delta maintenance, SSABE and EarlSession run end-to-end with mesh=,
    with sane accuracy vs the local path;
  * DistributedEarl(backend="fused_rng") works, including for Quantile
    (whose lo/hi state leaves a raw tree-psum would have scaled 8×).
"""
import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import (DistributedEarl, GroupedStatistic, KMeansStep, Mean,
                        Quantile, StatisticGroup, Var, bootstrap,
                        bootstrap_chunked, sharded_fused_states)
from repro.core.bootstrap import (fused_resample_states, offset_seed,
                                  seed_from_key)
from repro.core.delta import (poisson_delta_extend, poisson_delta_init,
                              poisson_delta_result)
from repro.core.session import EarlSession
from repro.kernels.weighted_stats import ops as ws_ops

out = {}
assert jax.device_count() == 8
mesh = Mesh(np.array(jax.devices()), ("data",))
mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4097, 2)) * 2.0 + 10.0   # ragged: 4097 % 8 != 0

def leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(la, lb))

# --- bitwise: mesh vs single-device oracle, all three stat families -----
stats = {
    "moments": Mean(),
    "quantile": Quantile(0.5, nbins=256, lo=0.0, hi=20.0),
    "kmeans": KMeansStep(jnp.array([[9.0, 9.0], [11.0, 11.0]])),
}
for name, stat in stats.items():
    s_mesh = sharded_fused_states(stat, 77, jnp.asarray(x), 32, mesh=mesh)
    s_one = sharded_fused_states(stat, 77, jnp.asarray(x), 32, nshards=8)
    out[f"bitwise_{name}"] = leaves_equal(s_mesh, s_one)

# --- StatisticGroup: bitwise under mesh sharding (ISSUE-5) --------------
grp = StatisticGroup((Mean(), Var(),
                      Quantile(0.5, nbins=256, lo=0.0, hi=20.0),
                      KMeansStep(jnp.array([[9.0, 9.0], [11.0, 11.0]]))))
g_mesh = sharded_fused_states(grp, 77, jnp.asarray(x), 32, mesh=mesh)
g_one = sharded_fused_states(grp, 77, jnp.asarray(x), 32, nshards=8)
out["bitwise_group"] = leaves_equal(g_mesh, g_one)
# and the sharded group's member states equal each member's own sharded
# run (one shared stream -> same resamples, even across the mesh)
g_fin = jax.vmap(grp.finalize)(g_mesh)
for i, m in enumerate(grp.members):
    m_mesh = sharded_fused_states(m, 77, jnp.asarray(x), 32, mesh=mesh)
    out[f"bitwise_group_member{i}"] = leaves_equal(
        jax.vmap(m.finalize)(m_mesh), g_fin[i])

# --- ISSUE-7: GroupedStatistic over the mesh ----------------------------
G = 4
gids = jax.random.randint(jax.random.fold_in(key, 21),
                          (x.shape[0],), 0, G).astype(jnp.float32)
vk = jnp.concatenate([jnp.asarray(x), gids[:, None]], axis=1)
gstat = GroupedStatistic(Mean(), G)
gs_mesh = sharded_fused_states(gstat, 77, vk, 32, mesh=mesh)
gs_one = sharded_fused_states(gstat, 77, vk, 32, nshards=8)
out["bitwise_grouped_mesh"] = leaves_equal(gs_mesh, gs_one)
# per-key thetas == per-key-alone sharded runs: shard the rows the same
# way and run the INNER statistic with the shard's key mask composed
# onto its validity prefix, under the same per-shard streams.
gth = jax.vmap(gstat.finalize)(gs_mesh)
nrows = vk.shape[0]
m = -(-nrows // 8)
xkp = jnp.pad(vk, ((0, 8 * m - nrows), (0, 0)))
ok = True
for g in range(G):
    acc = None
    for i in range(8):
        loc = xkp[i * m:(i + 1) * m]
        nv = min(max(nrows - i * m, 0), m)
        maskg = (jnp.arange(m) < nv).astype(jnp.float32) * (loc[:, 2] == g)
        si = fused_resample_states(Mean(), offset_seed(77, i),
                                   loc[:, :2], 32, valid_mask=maskg)
        acc = si if acc is None else jax.vmap(Mean().merge)(acc, si)
    ok = ok and leaves_equal(jax.vmap(Mean().finalize)(acc), gth[:, g])
out["bitwise_grouped_per_key_mesh"] = ok

# --- bitwise: chunked sharded (streams keyed (base, shard, chunk)) ------
st_m = sharded_fused_states(Mean(), 77, jnp.asarray(x), 32, mesh=mesh,
                            chunk=256)
st_o = sharded_fused_states(Mean(), 77, jnp.asarray(x), 32, nshards=8,
                            chunk=256)
out["bitwise_chunked"] = leaves_equal(st_m, st_o)

# --- nshards=1 mesh == the plain single-device fused path ---------------
s_1mesh = sharded_fused_states(Mean(), 77, jnp.asarray(x), 32, mesh=mesh1)
s_plain = fused_resample_states(Mean(), jnp.int32(77), jnp.asarray(x), 32)
out["bitwise_nshards1"] = leaves_equal(s_1mesh, s_plain)

# --- distinct per-shard streams -----------------------------------------
ws = [np.asarray(ws_ops.implicit_weights(offset_seed(77, i), 16, 512))
      for i in range(8)]
out["streams_distinct"] = all(
    not np.array_equal(ws[i], ws[j])
    for i in range(8) for j in range(i + 1, 8))

# --- bootstrap()/bootstrap_chunked() with mesh: sane accuracy -----------
xb = jax.random.normal(key, (32768,)) * 2.0 + 10.0
r_local = bootstrap(xb, Mean(), B=128, key=key, backend="fused_rng")
r_mesh = bootstrap(xb, Mean(), B=128, key=key, backend="fused_rng",
                   mesh=mesh)
r_ck = bootstrap_chunked(xb, Mean(), B=128, key=key, chunk=1024,
                         backend="fused_rng", mesh=mesh)
out["mesh_est"] = float(np.ravel(r_mesh.estimate)[0])
out["mesh_cv"] = r_mesh.cv
out["chunked_cv"] = r_ck.cv
out["local_cv"] = r_local.cv
out["true"] = float(xb.mean())

# --- sharded quantile composes (per-shard sketches psum) ----------------
q = Quantile(0.5, nbins=512, lo=0.0, hi=20.0)
rq = bootstrap(xb, q, B=64, key=key, backend="fused_rng", mesh=mesh)
out["quantile_est"] = float(np.ravel(rq.estimate)[0])
out["quantile_cv"] = rq.cv

# --- sharded delta maintenance == oracle extend-by-extend ---------------
pd = poisson_delta_init(Mean(), 32, 2, key, backend="fused_rng", mesh=mesh)
pd = poisson_delta_extend(pd, x[:2000])
pd = poisson_delta_extend(pd, x[2000:])
base = seed_from_key(key)
ref = None
for step, piece in enumerate((x[:2000], x[2000:])):
    si = sharded_fused_states(Mean(), base, jnp.asarray(piece), 32,
                              nshards=8, step=step)
    ref = si if ref is None else jax.vmap(Mean().merge)(ref, si)
out["bitwise_delta"] = leaves_equal(pd.states, ref)
out["delta_cv"] = poisson_delta_result(pd).cv

# --- EarlSession end-to-end over the mesh -------------------------------
class _Sampler:
    def __init__(self, data):
        self.data = data
        self.N = data.shape[0]
    def take(self, a, b):
        return self.data[a:b]

big = jax.random.normal(jax.random.fold_in(key, 9), (200_000,)) * 5 + 100
sess = EarlSession(_Sampler(big), Mean(), sigma=0.01,
                   backend="fused_rng", mesh=mesh)
er = sess.run(jax.random.PRNGKey(3))
out["session_result"] = float(np.ravel(er.result)[0])
out["session_cv"] = er.cv
out["session_fell_back"] = er.fell_back

# --- DistributedEarl fused backend, incl. Quantile lo/hi psum fix -------
earl = DistributedEarl(mesh, Mean(), B=128, backend="fused_rng")
res = earl.estimate(xb, key)
out["dearl_est"] = float(np.ravel(res.estimate)[0])
out["dearl_cv"] = res.cv
earl_q = DistributedEarl(mesh, Quantile(0.5, nbins=512, lo=0.0, hi=20.0),
                         B=64, backend="fused_rng")
res_q = earl_q.estimate(xb, key)
out["dearl_q_est"] = float(np.ravel(res_q.estimate)[0])

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("fam", ["moments", "quantile", "kmeans"])
def test_sharded_states_bitwise_equal_single_device(subproc_result, fam):
    assert subproc_result[f"bitwise_{fam}"]


def test_chunked_sharded_bitwise_equal(subproc_result):
    assert subproc_result["bitwise_chunked"]


def test_grouped_bitwise_under_mesh(subproc_result):
    """ISSUE-7: a GroupedStatistic's sharded states equal the single-device
    oracle bitwise, and each key's thetas equal a per-key-alone sharded
    run of the inner statistic (shard-composed key masks, same streams)."""
    assert subproc_result["bitwise_grouped_mesh"]
    assert subproc_result["bitwise_grouped_per_key_mesh"]


def test_group_bitwise_under_mesh(subproc_result):
    """ISSUE-5: a StatisticGroup's sharded states equal the single-device
    oracle bitwise, and every member's finalized thetas equal the member's
    own sharded run (one shared stream across the mesh)."""
    assert subproc_result["bitwise_group"]
    for i in range(4):
        assert subproc_result[f"bitwise_group_member{i}"], f"member {i}"


def test_single_shard_mesh_matches_unsharded_path(subproc_result):
    assert subproc_result["bitwise_nshards1"]


def test_per_shard_streams_distinct(subproc_result):
    assert subproc_result["streams_distinct"]


def test_sharded_bootstrap_accuracy(subproc_result):
    r = subproc_result
    assert abs(r["mesh_est"] - r["true"]) < 0.1
    assert 0 < r["mesh_cv"] < 0.05
    assert abs(r["mesh_cv"] - r["local_cv"]) / r["local_cv"] < 1.0
    assert 0 < r["chunked_cv"] < 0.05


def test_sharded_quantile_sketch(subproc_result):
    r = subproc_result
    assert abs(r["quantile_est"] - 10.0) < 0.2
    assert 0 < r["quantile_cv"] < 0.05


def test_sharded_delta_bitwise_and_sane(subproc_result):
    assert subproc_result["bitwise_delta"]
    assert 0 < subproc_result["delta_cv"] < 0.1


def test_sharded_session_end_to_end(subproc_result):
    r = subproc_result
    assert not r["session_fell_back"]
    assert abs(r["session_result"] - 100.0) < 1.0
    assert r["session_cv"] <= 0.01 * 1.5


def test_distributed_earl_fused_backend(subproc_result):
    r = subproc_result
    assert abs(r["dearl_est"] - r["true"]) < 0.1
    assert 0 < r["dearl_cv"] < 0.05
    assert abs(r["dearl_q_est"] - 10.0) < 0.2
