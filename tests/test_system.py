"""End-to-end behaviour tests for the EARL system (paper-level claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EarlSession, Mean, Median, Quantile, Sum, bootstrap,
                        ssabe)
from repro.data import (PreMapSampler, ShardedStore, synthetic_numeric)


def _store(n=200_000, mean=10.0, std=2.0, seed=0):
    data = synthetic_numeric(n, mean, std, seed=seed)
    return ShardedStore.from_array(data, 8192, seed=seed)


class TestEarlyAccurateResults:
    """C1: early results within the user bound, from a fraction of data."""

    def test_mean_within_bound(self, key):
        store = _store()
        sess = EarlSession(PreMapSampler(store, seed=1), Mean(), sigma=0.01)
        out = sess.run(key)
        true = np.concatenate([s for s in store.splits]).mean()
        assert not out.fell_back
        assert out.fraction < 0.25, "early result should use a fraction"
        # cv <= sigma certified; sanity: estimate near truth
        assert out.cv <= 0.01
        assert abs(float(np.ravel(out.result)[0]) - true) / true < 0.05

    def test_sum_corrected_by_p(self, key):
        store = _store(n=100_000)
        sess = EarlSession(PreMapSampler(store, seed=2), Sum(), sigma=0.02)
        out = sess.run(key)
        true = np.concatenate([s for s in store.splits]).sum()
        est = float(np.ravel(out.result)[0])
        assert abs(est - true) / abs(true) < 0.05, \
            "correct(1/p) must rescale the sampled SUM (paper §2.1)"

    def test_small_data_falls_back_to_exact(self, key):
        """Paper §6.1: below the profitability point EARL switches to the
        full computation."""
        store = _store(n=300)
        sess = EarlSession(PreMapSampler(store, seed=3), Mean(),
                           sigma=0.0005)
        out = sess.run(key)
        assert out.fell_back
        true = np.concatenate([s for s in store.splits]).mean()
        np.testing.assert_allclose(np.ravel(out.result)[0], true, rtol=1e-5)

    def test_median_early(self, key):
        store = _store(n=150_000)
        q = Quantile(0.5, lo=0.0, hi=20.0)
        sess = EarlSession(PreMapSampler(store, seed=4), q, sigma=0.02)
        out = sess.run(key)
        data = np.concatenate([s for s in store.splits])
        true = np.median(data)
        assert not out.fell_back
        assert abs(float(np.ravel(out.result)[0]) - true) / true < 0.05

    def test_read_savings(self, key):
        """The pre-map sampler must not read the whole store."""
        store = _store(n=200_000)
        sampler = PreMapSampler(store, seed=5)
        sess = EarlSession(sampler, Mean(), sigma=0.01)
        sess.run(key)
        assert store.stats.rows_read < 0.75 * store.N


class TestPaperConstants:
    """Fig 2 / §6.4: ~30 bootstraps, ~1% sample for 5% error on the mean."""

    def test_about_30_bootstraps_suffice(self, key):
        x = jnp.asarray(synthetic_numeric(5000, 10, 2, seed=7))
        res = ssabe(x[:2000], Mean(), sigma=0.05, tau=0.01, key=key,
                    N=10_000_000)
        assert 4 <= res.B <= 128, f"B-hat={res.B} out of the paper's regime"

    def test_small_sample_for_5pct(self, key):
        x = jnp.asarray(synthetic_numeric(5000, 10, 2, seed=8))
        res = ssabe(x[:2000], Mean(), sigma=0.05, tau=0.01, key=key,
                    N=1_000_000)
        # for N(10, 2) the CLT needs (0.2/0.05)^2 = 16 samples; SSABE must
        # land well under 1% of N
        assert res.n <= 0.01 * 1_000_000

    def test_cv_decreases_with_B_and_n(self, key):
        x = jnp.asarray(synthetic_numeric(4000, 10, 2, seed=9))
        cv_small_n = bootstrap(x[:100], Mean(), B=64, key=key).cv
        cv_large_n = bootstrap(x, Mean(), B=64, key=key).cv
        assert cv_large_n < cv_small_n, "Fig 2b: larger n -> lower c_v"
