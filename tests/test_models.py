"""Per-arch smoke tests (reduced configs): shapes, finiteness, decode
consistency, MoE routing semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (cache_axes, decode_step, forward_hidden,
                          init_params, init_serve_cache, logits_from_hidden,
                          loss_fn, param_axes, per_example_loss, prefill)
from repro.models.config import SMOKE_SHAPES
from repro.models.layers import init_moe, moe_ffn
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["aux"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.is_encdec:
        batch["aux"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, key, arch):
        cfg = get_config(arch, smoke=True)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)
        state = init_train_state(key, cfg, ocfg)
        batch = _batch(cfg, key)
        step = jax.jit(make_train_step(cfg, ocfg))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"])), arch
        assert float(metrics["loss"]) > 0
        # params stay finite after one update
        for leaf in jax.tree_util.tree_leaves(state.params):
            assert bool(jnp.isfinite(leaf).all()), arch

    def test_per_example_loss_shape(self, key, arch):
        cfg = get_config(arch, smoke=True)
        params = init_params(key, cfg)
        batch = _batch(cfg, key, B=3)
        pel = per_example_loss(cfg, params, batch)
        assert pel.shape == (3,)
        assert bool(jnp.isfinite(pel).all())

    def test_axes_tables_cover_all_leaves(self, key, arch):
        cfg = get_config(arch, smoke=True)
        params = jax.eval_shape(lambda: init_params(key, cfg))
        param_axes(params)     # raises on unknown leaf
        cache = jax.eval_shape(lambda: init_serve_cache(cfg, 2, 64))
        cache_axes(cache)

    def test_padded_vocab_logits_masked(self, key, arch):
        cfg = get_config(arch, smoke=True)
        assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
        params = init_params(key, cfg)
        h = jax.random.normal(key, (1, 2, cfg.d_model))
        logits = logits_from_hidden(cfg, params, h)
        pad = np.asarray(logits[..., cfg.vocab:])
        assert (pad <= -1e29).all(), "padding vocab columns must be -inf"


@pytest.mark.parametrize("arch", ["gemma3-27b", "mixtral-8x22b",
                                  "recurrentgemma-2b", "whisper-small"])
def test_decode_matches_teacher_forcing(key, arch):
    cfg = get_config(arch, smoke=True)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = init_params(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab)
    aux = None
    if cfg.is_encdec:
        aux = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    h, _ = forward_hidden(cfg, params, toks, aux=aux, mode="train")
    full = logits_from_hidden(cfg, params, h)
    lg, cache = prefill(cfg, params, toks[:, :S], aux=aux, cache_len=S + 3)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=1e-3)
    for t in range(3):
        lg, cache = decode_step(cfg, params, cache, toks[:, S + t:S + t + 1],
                                jnp.int32(S + t))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, S + t]),
                                   atol=2e-4, rtol=1e-3)


class TestMoE:
    def _cfg(self, **kw):
        base = get_config("mixtral-8x22b", smoke=True)
        return dataclasses.replace(base, **kw)

    def test_single_expert_equals_dense(self, key):
        """E=1 top-1 with huge capacity must equal a plain MLP with the
        expert's weights."""
        from repro.models.layers import mlp
        cfg = self._cfg(num_experts=1, top_k=1, capacity_factor=4.0)
        p = init_moe(key, cfg)
        x = jax.random.normal(key, (2, 16, cfg.d_model))
        y_moe = moe_ffn(cfg, p, x)
        dense_p = {"w_gate": p["we_gate"][0], "w_up": p["we_up"][0],
                   "w_down": p["we_down"][0]}
        y_mlp = mlp(cfg, dense_p, x)
        np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_mlp),
                                   atol=1e-4, rtol=1e-3)

    def test_capacity_drops_tokens(self, key):
        """With tiny capacity most contributions are dropped -> output much
        smaller in norm than with ample capacity."""
        cfg_small = self._cfg(capacity_factor=0.05)
        cfg_big = self._cfg(capacity_factor=8.0)
        p = init_moe(key, cfg_big)
        x = jax.random.normal(key, (2, 32, cfg_big.d_model))
        y_small = moe_ffn(cfg_small, p, x)
        y_big = moe_ffn(cfg_big, p, x)
        assert float(jnp.linalg.norm(y_small)) < \
            0.8 * float(jnp.linalg.norm(y_big))

    def test_gate_normalization(self, key):
        """Permutation of experts leaves output invariant (router symm)."""
        cfg = self._cfg(capacity_factor=8.0)
        p = init_moe(key, cfg)
        x = jax.random.normal(key, (1, 8, cfg.d_model))
        perm = np.array([2, 0, 3, 1])
        p2 = dict(p)
        p2["router"] = p["router"][:, perm]
        p2["we_gate"] = p["we_gate"][perm]
        p2["we_up"] = p["we_up"][perm]
        p2["we_down"] = p["we_down"][perm]
        y1 = moe_ffn(cfg, p, x)
        y2 = moe_ffn(cfg, p2, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-3)


class TestRingCache:
    def test_swa_cache_is_window_sized(self, key):
        cfg = get_config("h2o-danube-3-4b", smoke=True)
        cache = init_serve_cache(cfg, batch=2, cache_len=128)
        k = cache["groups"]["0"]["attn"]["k"]
        # leading dim = groups; cache seq dim = window (16), not 128
        assert k.shape[3] == cfg.window

    def test_full_cache_is_context_sized(self, key):
        cfg = get_config("stablelm-3b", smoke=True)
        cache = init_serve_cache(cfg, batch=2, cache_len=128)
        k = cache["groups"]["0"]["attn"]["k"]
        assert k.shape[3] == 128
