"""GROUP BY for the bootstrap (ISSUE-7): keyed states through every layer.

The load-bearing contract: under ``backend="fused_rng"`` a
``GroupedStatistic``'s key-g thetas are BITWISE equal to running the inner
statistic alone with ``valid_mask = (key == g)`` under the same seed —
one shared implicit Poisson(1) weight stream (common random numbers),
segment-reduced per key by exact 0/1 mask multiplies.  Verified here on
the single-device, chunked, and streaming drivers (the 8-shard mesh lives
in tests/test_sharded_bootstrap.py's subprocess), plus the keyed accuracy
reports, the early-validation satellites, ``Quantile.with_range``
preservation, and a jaxpr capture proving no (B, n) or (n, G)
intermediate exists at n=2^20, B=256, G=64.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (GroupedStatistic, KeyedAccuracyReport, Mean,
                        Quantile, StatisticGroup, bootstrap,
                        bootstrap_chunked, bootstrap_streaming,
                        sharded_fused_states)
from repro.core.accuracy import report_for
from repro.core.bootstrap import (fused_resample_states, offset_seed,
                                  seed_from_key)
from repro.core.reduce_api import (Count, KMeansStep, Statistic, Sum, Var,
                                   bind_params, split_params)
from repro.data.store import ShardedStore

N, D, G, B, SEED = 700, 2, 4, 32, 1234


@pytest.fixture(scope="module")
def keyed():
    """(values_with_key_column, data_columns, key_column) fixture."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N, D)).astype(np.float32)
    gid = rng.integers(0, G, size=N).astype(np.float32)
    vals = jnp.asarray(np.concatenate([x, gid[:, None]], axis=1))
    return vals, vals[:, :D], vals[:, D]


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


class _CustomInner(Statistic):
    """Mergeable custom statistic with NO fused hook — exercises the
    GroupedStatistic -> fused_poisson_tiled generic tile path."""
    moment_powers = None

    def init_state(self, dim):
        return (jnp.zeros(()), jnp.zeros((dim,)))

    def update(self, state, x, w=None):
        from repro.core.reduce_api import _w
        w = _w(x, w)
        wt, s1 = state
        return wt + jnp.sum(w), s1 + w @ jnp.asarray(x, jnp.float32)

    def merge(self, a, b):
        return a[0] + b[0], a[1] + b[1]

    def finalize(self, state):
        return state[1] / jnp.maximum(state[0], 1.0)


class _NonMergeable(Mean):
    mergeable = False


def _inners():
    cent = jnp.asarray(np.random.default_rng(2)
                       .normal(size=(3, D)).astype(np.float32))
    return [Mean(), Sum(), Count(), Var(),
            Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
            KMeansStep(cent), _CustomInner()]


# ---------------------------------------------------------------------------
# construction / protocol
# ---------------------------------------------------------------------------
class TestConstruction:
    def test_rejects_nesting(self):
        with pytest.raises(TypeError, match="nest"):
            GroupedStatistic(GroupedStatistic(Mean(), 2), 3)

    def test_rejects_group_inner(self):
        with pytest.raises(TypeError, match="StatisticGroup"):
            GroupedStatistic(StatisticGroup([Mean()]), 2)

    def test_rejects_non_statistic(self):
        with pytest.raises(TypeError):
            GroupedStatistic(lambda x: x, 2)

    def test_rejects_bad_backend(self):
        with pytest.raises(ValueError, match="backend"):
            GroupedStatistic(Mean(), 2, backend="cuda")

    def test_rejects_bad_num_groups(self):
        with pytest.raises(ValueError, match="num_groups"):
            GroupedStatistic(Mean(), 0)

    def test_needs_key_column(self):
        with pytest.raises(ValueError, match="key"):
            GroupedStatistic(Mean(), 2)._split_key(jnp.ones((5,)))

    def test_mergeable_follows_inner(self):
        assert GroupedStatistic(Mean(), 2).mergeable
        assert not GroupedStatistic(_NonMergeable(), 2).mergeable

    def test_split_bind_params_roundtrip(self):
        cent = jnp.asarray(np.random.default_rng(3)
                           .normal(size=(3, D)).astype(np.float32))
        stat = GroupedStatistic(KMeansStep(cent), G)
        spec, params = split_params(stat)
        assert params, "KMeansStep centroids must be threaded as params"
        rebound = bind_params(spec, params)
        assert isinstance(rebound, GroupedStatistic)
        np.testing.assert_array_equal(np.asarray(rebound.inner.centroids),
                                      np.asarray(cent))

    def test_update_matches_per_key_update(self, keyed):
        vals, x, gid = keyed
        stat = GroupedStatistic(Mean(), G)
        st = stat.update(stat.init_state(D + 1), vals)
        for g in range(G):
            ref = Mean().update(Mean().init_state(D), x,
                                (gid == g).astype(jnp.float32))
            _tree_bitwise(jax.tree_util.tree_map(lambda a: a[g], st), ref)


# ---------------------------------------------------------------------------
# the bitwise per-key contract, driver by driver
# ---------------------------------------------------------------------------
class TestSingleDevicePerKeyBitwise:
    @pytest.mark.parametrize("inner", _inners(),
                             ids=lambda s: type(s).__name__)
    def test_fused_thetas_per_key(self, inner, keyed):
        from repro.kernels.fused_multi.ops import fused_poisson_tiled
        vals, x, gid = keyed
        stat = GroupedStatistic(inner, G)
        thetas = jax.vmap(stat.finalize)(
            fused_resample_states(stat, SEED, vals, B))
        for g in range(G):
            mask = (gid == g).astype(jnp.float32)
            if inner.accumulator_key() is None and \
                    not hasattr(inner, "centroids"):
                # custom inner: its per-key-alone fused run is the same
                # generic tile scan (a whole-array update would sum the
                # n axis in one go — a different reduction order)
                ref_states = fused_poisson_tiled(inner, SEED, x, B,
                                                 valid_mask=mask)
            else:
                ref_states = fused_resample_states(inner, SEED, x, B,
                                                   valid_mask=mask)
            ref = jax.vmap(inner.finalize)(ref_states)
            _tree_bitwise(jax.tree_util.tree_map(lambda a: a[:, g], thetas),
                          ref)

    def test_interior_mask_composes(self, keyed):
        """valid_mask holes compose with key masks exactly:
        (w·valid)·keymask ≡ w·(valid·keymask) for 0/1 masks."""
        vals, x, gid = keyed
        rng = np.random.default_rng(1)
        hole = jnp.asarray((rng.random(N) > 0.3).astype(np.float32))
        stat = GroupedStatistic(Mean(), G)
        thetas = jax.vmap(stat.finalize)(
            fused_resample_states(stat, SEED, vals, B, valid_mask=hole))
        for g in range(G):
            ref = jax.vmap(Mean().finalize)(fused_resample_states(
                Mean(), SEED, x, B,
                valid_mask=hole * (gid == g).astype(jnp.float32)))
            _tree_bitwise(thetas[:, g], ref)

    def test_prefix_equals_n_valid(self, keyed):
        vals, _, _ = keyed
        stat = GroupedStatistic(Mean(), G)
        k = 500
        prefix = (jnp.arange(N) < k).astype(jnp.float32)
        a = fused_resample_states(stat, SEED, vals, B, n_valid=k)
        b = fused_resample_states(stat, SEED, vals, B, valid_mask=prefix)
        _tree_bitwise(a, b)

    def test_bootstrap_driver_keyed_report(self, keyed):
        vals, _, _ = keyed
        res = bootstrap(vals, GroupedStatistic(Mean(), G), B=B,
                        key=jax.random.PRNGKey(7), backend="fused_rng")
        assert res.thetas.shape[:2] == (B, G)
        assert isinstance(res.report, KeyedAccuracyReport)
        assert len(res.report.members) == G
        assert res.report.cv == max(res.report.cvs)
        assert res.report.cvs[res.report.worst_key] == res.report.cv


class TestScanPallasParity:
    def test_grouped_moments_scan_vs_pallas(self, keyed):
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        _, x, gid = keyed
        s = fused_poisson_moments(SEED, x, B, backend="scan",
                                  group_ids=gid, num_groups=G)
        k = fused_poisson_moments(SEED, x, B, backend="pallas_interpret",
                                  group_ids=gid, num_groups=G)
        _tree_bitwise(s, k)

    def test_grouped_moments_masked_parity(self, keyed):
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        _, x, gid = keyed
        rng = np.random.default_rng(5)
        hole = jnp.asarray((rng.random(N) > 0.4).astype(np.float32))
        s = fused_poisson_moments(SEED, x, B, backend="scan",
                                  valid_mask=hole, group_ids=gid,
                                  num_groups=G)
        k = fused_poisson_moments(SEED, x, B, backend="pallas_interpret",
                                  valid_mask=hole, group_ids=gid,
                                  num_groups=G)
        _tree_bitwise(s, k)

    def test_grouped_hist_pallas_raises(self, keyed):
        from repro.kernels.weighted_hist.ops import fused_poisson_hist
        _, x, gid = keyed
        with pytest.raises(ValueError, match="scan-only"):
            fused_poisson_hist(SEED, x, -4.0, 4.0, 32, B,
                               backend="pallas_interpret",
                               group_ids=gid, num_groups=G)

    def test_grouped_kmeans_pallas_raises(self, keyed):
        from repro.kernels.kmeans_assign.ops import fused_poisson_kmeans
        _, x, gid = keyed
        cent = jnp.zeros((3, D))
        with pytest.raises(ValueError, match="scan"):
            fused_poisson_kmeans(SEED, x, cent, B,
                                 backend="pallas_interpret",
                                 group_ids=gid, num_groups=G)

    def test_grouped_stream_mode_raises(self, keyed):
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        _, x, gid = keyed
        with pytest.raises(ValueError, match="group"):
            fused_poisson_moments(SEED, x, B, backend="pallas_interpret",
                                  stream=True, group_ids=gid, num_groups=G)


class TestChunkedAndStreamingPerKey:
    CHUNK = 256

    def test_chunked_per_key_oracle(self, keyed):
        """Chunked grouped thetas per key == the per-chunk per-key oracle
        (same offset_seed(base, i) streams, key mask composed with each
        chunk's validity prefix, merged)."""
        vals, _, _ = keyed
        key = jax.random.PRNGKey(11)
        stat = GroupedStatistic(Mean(), G)
        res = bootstrap_chunked(vals, stat, B=B, key=key, chunk=self.CHUNK,
                                backend="fused_rng")
        base = seed_from_key(key)
        pad = (-N) % self.CHUNK
        vp = jnp.pad(vals, ((0, pad), (0, 0)))
        nchunks = vp.shape[0] // self.CHUNK
        for g in range(G):
            acc = None
            for i in range(nchunks):
                ci = vp[i * self.CHUNK:(i + 1) * self.CHUNK]
                nv = min(max(N - i * self.CHUNK, 0), self.CHUNK)
                m = (jnp.arange(self.CHUNK) < nv).astype(jnp.float32) \
                    * (ci[:, D] == g)
                si = fused_resample_states(Mean(), offset_seed(base, i),
                                           ci[:, :D], B, valid_mask=m)
                acc = si if acc is None else \
                    jax.vmap(Mean().merge)(acc, si)
            ref = jax.vmap(Mean().finalize)(acc)
            _tree_bitwise(res.thetas[:, g], ref)

    def test_streaming_bitwise_equals_chunked(self, keyed):
        vals, _, _ = keyed
        key = jax.random.PRNGKey(11)
        store = ShardedStore.from_array(np.asarray(vals), split_size=123)
        sv = jnp.asarray(store.read_all())
        for inner in (Mean(), Quantile(0.5, lo=-4.0, hi=4.0, nbins=64)):
            stat = GroupedStatistic(inner, G)
            rc = bootstrap_chunked(sv, stat, B=B, key=key,
                                   chunk=self.CHUNK, backend="fused_rng")
            rs = bootstrap_streaming(store, stat, B=B, key=key,
                                     chunk=self.CHUNK)
            _tree_bitwise(rc.thetas, rs.thetas)
            _tree_bitwise(rc.estimate, rs.estimate)
            assert isinstance(rs.report, KeyedAccuracyReport)

    def test_sharded_sequential_per_key(self, keyed):
        vals, _, _ = keyed
        stat = GroupedStatistic(Mean(), G)
        st = sharded_fused_states(stat, SEED, vals, B, nshards=4)
        th = jax.vmap(stat.finalize)(st)
        m = -(-N // 4)
        vp = jnp.pad(vals, ((0, 4 * m - N), (0, 0)))
        for g in range(G):
            acc = None
            for i in range(4):
                loc = vp[i * m:(i + 1) * m]
                nv = min(max(N - i * m, 0), m)
                mask = (jnp.arange(m) < nv).astype(jnp.float32) \
                    * (loc[:, D] == g)
                si = fused_resample_states(Mean(), offset_seed(SEED, i),
                                           loc[:, :D], B, valid_mask=mask)
                acc = si if acc is None else \
                    jax.vmap(Mean().merge)(acc, si)
            _tree_bitwise(th[:, g], jax.vmap(Mean().finalize)(acc))


# ---------------------------------------------------------------------------
# keyed accuracy reports
# ---------------------------------------------------------------------------
class TestKeyedAccuracyReport:
    def test_report_for_splits_axis1(self):
        rng = np.random.default_rng(7)
        thetas = jnp.asarray(rng.normal(size=(16, 3, 2)).astype(np.float32)
                             + 5.0)
        rep = report_for(thetas, num_groups=3)
        assert isinstance(rep, KeyedAccuracyReport)
        assert len(rep.members) == 3
        from repro.core.accuracy import AccuracyReport
        for g in range(3):
            solo = AccuracyReport.from_thetas(thetas[:, g])
            assert rep.members[g].cv == solo.cv
        assert rep.cv == max(rep.cvs)
        assert rep.worst_key == int(np.argmax(rep.cvs))

    def test_report_for_without_groups_unchanged(self):
        t = jnp.ones((8, 2)) + jnp.arange(8)[:, None] * 0.01
        from repro.core.accuracy import AccuracyReport
        assert isinstance(report_for(t), AccuracyReport)


# ---------------------------------------------------------------------------
# satellite 1: early validation
# ---------------------------------------------------------------------------
class TestEarlyValidation:
    def _store(self):
        rng = np.random.default_rng(3)
        return ShardedStore.from_array(
            rng.normal(size=(200, 2)).astype(np.float32), split_size=50)

    def test_streaming_rejects_non_mergeable_naming_statistic(self):
        with pytest.raises(ValueError, match="_NonMergeable"):
            bootstrap_streaming(self._store(), _NonMergeable(), B=8,
                                key=jax.random.PRNGKey(0))

    def test_streaming_rejects_grouped_non_mergeable(self):
        with pytest.raises(ValueError, match="GroupedStatistic"):
            bootstrap_streaming(self._store(),
                                GroupedStatistic(_NonMergeable(), 2),
                                B=8, key=jax.random.PRNGKey(0))

    def test_streaming_backend_error_names_supported(self):
        with pytest.raises(ValueError, match="fused_rng") as ei:
            bootstrap_streaming(self._store(), Mean(), B=8,
                                key=jax.random.PRNGKey(0), backend="jnp")
        assert "'jnp'" in str(ei.value)

    def test_sharded_rejects_non_mergeable_naming_statistic(self):
        with pytest.raises(ValueError, match="_NonMergeable"):
            sharded_fused_states(_NonMergeable(), SEED,
                                 jnp.ones((64, 2)), 8, nshards=4)

    def test_grouped_kernel_validates_num_groups(self, keyed):
        from repro.kernels.weighted_stats.ops import fused_poisson_moments
        _, x, gid = keyed
        with pytest.raises(ValueError, match="num_groups"):
            fused_poisson_moments(SEED, x, B, group_ids=gid, num_groups=0)


# ---------------------------------------------------------------------------
# satellite 2: Quantile.with_range preserves every knob
# ---------------------------------------------------------------------------
class TestWithRangePreservesKnobs:
    def test_knobs_survive(self):
        q = Quantile(0.9, nbins=96, lo=0.0, hi=1.0,
                     backend="pallas_interpret", block_bins=32)
        r = q.with_range(-2.0, 2.0)
        assert (r.q, r.nbins, r.backend, r.block_bins) == \
            (0.9, 96, "pallas_interpret", 32)
        # with_range pads the requested range by its 1% pilot margin
        assert r.lo < -2.0 < 2.0 < r.hi

    def test_re_ranged_quantiles_share_slot_in_group(self):
        qa = Quantile(0.25, nbins=64, lo=0.0, hi=1.0).with_range(-4.0, 4.0)
        qb = Quantile(0.75, nbins=64, lo=qa.lo, hi=qa.hi)
        grp = StatisticGroup([qa, qb])
        assert len(grp.slots) == 1, \
            "re-ranged quantile must share the sketch accumulator slot"
        assert qa.accumulator_key() == qb.accumulator_key()


# ---------------------------------------------------------------------------
# jaxpr capture: the acceptance-scale memory contract
# ---------------------------------------------------------------------------
class TestNoMaterializedIntermediates:
    def test_no_Bn_or_nG_aval_at_scale(self):
        n, B_, G_ = 1 << 20, 256, 64
        stat = GroupedStatistic(Mean(), G_)
        big = jax.ShapeDtypeStruct((n, 3), jnp.float32)
        jaxpr = jax.make_jaxpr(
            lambda v: stat.fused_poisson_states(jnp.int32(7), v, B_))(big)
        shapes = []

        def visit(jx):
            for eqn in jx.eqns:
                for v in list(eqn.invars) + list(eqn.outvars):
                    aval = getattr(v, "aval", None)
                    if getattr(aval, "shape", None) is not None:
                        shapes.append(tuple(int(s) for s in aval.shape))
                for p in eqn.params.values():
                    if hasattr(p, "jaxpr"):
                        visit(p.jaxpr)
                    elif isinstance(p, (list, tuple)):
                        for q_ in p:
                            if hasattr(q_, "jaxpr"):
                                visit(q_.jaxpr)

        visit(jaxpr.jaxpr)
        bad = [s for s in shapes
               if (B_ in s and n in s) or (n in s and G_ in s)]
        assert not bad, f"materialized intermediates: {bad[:5]}"
        # nothing bigger than the input itself ever exists
        assert max(int(np.prod(s)) if s else 1 for s in shapes) <= n * 3


# ---------------------------------------------------------------------------
# keyed end-to-end: StratifiedSampler -> SSABE -> EarlSession worst-key stop
# ---------------------------------------------------------------------------
class TestKeyedSession:
    def _keyed_store(self, n=6000, g=3, seed=0):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, g, size=n)
        data = np.stack([rng.normal(loc=keys * 2.0, scale=0.5),
                         keys], axis=1).astype(np.float32)
        return ShardedStore.from_array(data, 512)

    def test_session_stops_on_worst_key(self):
        from repro.core.session import EarlSession
        from repro.data import StratifiedSampler

        G_ = 3
        store = self._keyed_store(g=G_)
        sampler = StratifiedSampler(store, num_groups=G_, seed=1)
        stat = GroupedStatistic(Mean(), G_)
        sess = EarlSession(sampler, stat, sigma=0.1, backend="fused_rng",
                           max_pilot=512)
        res = sess.run(jax.random.PRNGKey(0))
        assert res.reports is not None and len(res.reports) == G_
        if not res.fell_back:
            # the sigma gate is the WORST key's c_v: every key met it
            assert res.cv == max(r.cv for r in res.reports)
            assert all(r.cv <= sess.sigma for r in res.reports)
            assert res.history[-1]["member_cvs"] == \
                tuple(r.cv for r in res.reports)
        # per-key means of loc = 2*key survive the keyed pipeline
        est = np.asarray(res.result)
        for g in range(G_):
            assert abs(est[g, 0] - 2.0 * g) < 0.25

    def test_ssabe_gates_on_worst_key(self):
        from repro.core.ssabe import ssabe

        G_ = 3
        store = self._keyed_store(g=G_)
        pilot = jnp.asarray(store.read_all()[:1024])
        stat = GroupedStatistic(Mean(), G_)
        est = ssabe(pilot, stat, 0.1, 0.01, jax.random.PRNGKey(3),
                    N=store.N, backend="fused_rng")
        assert est.B >= 1 and est.n >= 1


class TestStratifiedPerKeyCorrection:
    """Per-key ``correct`` under stratified sampling (ISSUE-9 satellite):
    a stratified prefix samples key g at its OWN rate p_g, so keyed
    results must be corrected per key (``correct_per_key``) — a scalar
    whole-table p mis-scales every count-like inner."""

    def _skewed_store(self, n=8000, seed=0):
        """Key frequencies ~[0.75, 0.2, 0.05] — rare key 2 is what
        stratification oversamples relative to its frequency."""
        rng = np.random.default_rng(seed)
        keys = rng.choice(3, size=n, p=[0.75, 0.2, 0.05])
        data = np.stack([rng.normal(loc=1.0 + keys, scale=0.3),
                         keys], axis=1).astype(np.float32)
        return ShardedStore.from_array(data, 512)

    def test_correct_per_key_scales_each_slice_by_its_own_p(self):
        stat = GroupedStatistic(Sum(), 3)
        est = jnp.asarray([[10.0], [20.0], [30.0]])       # (G, ...) axis 0
        out = np.asarray(stat.correct_per_key(est, [0.5, 0.25, 1.0]))
        np.testing.assert_allclose(out[:, 0], [20.0, 80.0, 30.0])
        thetas = jnp.ones((B, 3, 1))                      # (B, G, ...) axis 1
        out = np.asarray(stat.correct_per_key(thetas, [0.5, 0.25, 1.0],
                                              key_axis=1))
        np.testing.assert_allclose(out[0, :, 0], [2.0, 4.0, 1.0])

    def test_correct_per_key_matches_masked_inner_oracle(self, keyed):
        """Key g's per-key-corrected thetas are bitwise equal to the
        masked-inner oracle corrected by p_g alone — correction is
        elementwise, so it preserves the base per-key contract."""
        vals, data, keycol = keyed
        stat = GroupedStatistic(Sum(), G)
        p_keys = [0.5, 0.25, 1.0, 0.8]
        thetas = jax.vmap(stat.finalize)(
            fused_resample_states(stat, SEED, vals, B))
        corrected = stat.correct_per_key(thetas, p_keys, key_axis=1)
        for g in range(G):
            mask = (keycol == g).astype(jnp.float32)
            ref = jax.vmap(Sum().finalize)(fused_resample_states(
                Sum(), SEED, data, B, valid_mask=mask))
            oracle = Sum().correct(ref, p_keys[g])
            _tree_bitwise(np.asarray(corrected)[:, g], oracle)

    def test_correct_per_key_validation(self):
        stat = GroupedStatistic(Sum(), 3)
        with pytest.raises(ValueError, match="p_keys"):
            stat.correct_per_key(jnp.ones((3, 1)), [0.5, 0.5])
        # p_g == 0 (stratum absent from the prefix) passes through
        out = stat.correct_per_key(jnp.ones((3, 1)), [0.5, 0.0, 1.0])
        np.testing.assert_allclose(np.asarray(out)[:, 0], [2.0, 1.0, 1.0])

    def test_poisson_delta_result_p_keys(self, keyed):
        from repro.core.delta import (poisson_delta_extend,
                                      poisson_delta_init,
                                      poisson_delta_result)
        vals, _, keycol = keyed
        stat = GroupedStatistic(Sum(), G)
        pd = poisson_delta_init(stat, B=B, dim=D + 1,
                                key=jax.random.PRNGKey(SEED),
                                backend="fused_rng")
        pd = poisson_delta_extend(pd, vals)
        p_keys = [0.5, 0.25, 1.0, 0.8]
        res = poisson_delta_result(pd, p_keys=p_keys)
        assert res.report.p_keys == tuple(p_keys)
        # key g's estimate is its raw sum scaled by 1/p_g
        raw = np.asarray(poisson_delta_result(pd).estimate)
        out = np.asarray(res.estimate)
        for g in range(G):
            np.testing.assert_allclose(out[g], raw[g] / p_keys[g],
                                       rtol=1e-6)

    def test_p_keys_requires_keyed_statistic(self):
        from repro.core.delta import (poisson_delta_extend,
                                      poisson_delta_init,
                                      poisson_delta_result)
        pd = poisson_delta_init(Sum(), B=8, dim=2,
                                key=jax.random.PRNGKey(0),
                                backend="fused_rng")
        pd = poisson_delta_extend(pd, jnp.ones((16, 2)))
        with pytest.raises(ValueError, match="keyed"):
            poisson_delta_result(pd, p_keys=[0.5])

    def test_stratified_session_corrects_sums_per_key(self):
        """End to end: a keyed SUM session over a StratifiedSampler with
        equal shares (rare keys heavily oversampled vs frequency) must
        recover every key's TRUE total — the whole-table p would inflate
        the rare key's sum by ~frequency/share."""
        from repro.core.session import EarlSession
        from repro.data import StratifiedSampler

        store = self._skewed_store()
        data = store.read_all()
        true = np.array([data[data[:, 1] == g, 0].sum() for g in range(3)])
        sampler = StratifiedSampler(store, num_groups=3, seed=1)
        sess = EarlSession(sampler, GroupedStatistic(Sum(), 3), sigma=0.05,
                           backend="fused_rng", max_pilot=512)
        res = sess.run(jax.random.PRNGKey(2))
        est = np.asarray(res.result)[:, 0]
        np.testing.assert_allclose(est, true, rtol=0.15)
        if res.n_used < store.N:
            # the naive whole-table correction is measurably wrong for
            # the rare key (sampled at ~1/3 share vs 5% frequency)
            n = res.n_used
            counts = sampler.stratum_counts(n)
            raw = est * (counts / np.maximum(sampler.stratum_sizes, 1))
            naive = raw * (store.N / n)
            assert abs(naive[2] - true[2]) > abs(est[2] - true[2])


# The hypothesis property suite for grouped segment-reduction lives in
# tests/test_grouped_properties.py (module-level importorskip, matching
# tests/test_properties.py) so this file runs even without hypothesis.
