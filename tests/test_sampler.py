"""Sampling over the sharded store (paper §3.3): uniformity, prefix
semantics, read accounting, pre- vs post-map."""
import numpy as np
import pytest
from scipy import stats as sps

from repro.data import (PostMapSampler, PreMapSampler, ShardedStore,
                        StratifiedSampler, synthetic_numeric)


def _store(n=50_000, nvals=20, interleave=True):
    # clustered layout: values sorted -> worst case for block sampling
    data = np.sort(np.repeat(np.arange(nvals), n // nvals)).astype(
        np.float32)[:, None]
    return ShardedStore.from_array(data, 1024, interleave=interleave)


class TestUniformity:
    def test_chi_square_uniform_sample(self):
        """Prefix samples from an adversarially clustered layout must be
        uniform (the paper's block-sampling hazard, §7)."""
        store = _store()
        sampler = PreMapSampler(store, seed=0)
        sample = np.asarray(sampler.take(0, 5000)).ravel()
        counts = np.bincount(sample.astype(int), minlength=20)
        chi2, p = sps.chisquare(counts)
        assert p > 0.001, f"sample not uniform: chi2={chi2}, p={p}"

    def test_prefixes_are_nested(self):
        store = _store()
        sampler = PreMapSampler(store, seed=1)
        a = np.asarray(sampler.take(0, 100))
        b = np.asarray(sampler.take(0, 500))
        np.testing.assert_array_equal(a, b[:100])

    def test_no_replacement_within_prefix(self):
        store = ShardedStore.from_array(
            np.arange(10_000, dtype=np.float32)[:, None], 512)
        sampler = PreMapSampler(store, seed=2)
        s = np.asarray(sampler.take(0, 10_000)).ravel()
        assert len(np.unique(s)) == 10_000


class TestReadAccounting:
    def test_pre_map_reads_only_sample(self):
        store = _store()
        sampler = PreMapSampler(store, seed=3)
        sampler.take(0, 1000)
        assert store.stats.rows_read == 1000
        assert store.stats.splits_opened <= len(store.splits)

    def test_post_map_reads_everything_once(self):
        store = _store()
        sampler = PostMapSampler(store, seed=3)
        sampler.take(0, 1000)
        assert store.stats.rows_read == store.N
        assert sampler.kv_count == store.N        # exact ⟨k,v⟩ accounting
        before = store.stats.rows_read
        sampler.take(1000, 2000)                  # cached: no re-read
        assert store.stats.rows_read == before

    def test_pre_and_post_same_rows(self):
        data = synthetic_numeric(20_000, 10, 2, seed=5)
        s1 = PreMapSampler(ShardedStore.from_array(data, 1024, seed=7),
                           seed=9)
        s2 = PostMapSampler(ShardedStore.from_array(data, 1024, seed=7),
                            seed=9)
        np.testing.assert_allclose(np.asarray(s1.take(0, 500)),
                                   np.asarray(s2.take(0, 500)))


def _keyed_store(sizes, seed=0, split_rows=1024):
    """One data column + integer key column; stratum g has sizes[g] rows."""
    rng = np.random.default_rng(seed)
    keys = np.repeat(np.arange(len(sizes)), sizes)
    rng.shuffle(keys)
    data = np.stack([rng.normal(size=len(keys)), keys], axis=1).astype(
        np.float32)
    return ShardedStore.from_array(data, split_rows)


class TestStratifiedSampler:
    SIZES = [9000, 600, 300, 100]           # heavy skew: 90:6:3:1

    def test_equal_shares_balance_prefixes(self):
        store = _keyed_store(self.SIZES)
        s = StratifiedSampler(store, num_groups=4, seed=3)
        counts = s.stratum_counts(360)
        # a uniform prefix would hold ~324:22:11:4 — stride scheduling
        # surfaces every key at the same rate instead
        np.testing.assert_array_equal(counts, [90, 90, 90, 90])
        np.testing.assert_array_equal(s.stratum_sizes, self.SIZES)

    def test_custom_shares_hit_proportions(self):
        store = _keyed_store(self.SIZES)
        s = StratifiedSampler(store, num_groups=4, seed=3,
                              shares=[1.0, 1.0, 2.0, 4.0])
        counts = s.stratum_counts(160)
        np.testing.assert_array_equal(counts, [20, 20, 40, 80])

    def test_exhausted_stratum_lets_others_fill(self):
        store = _keyed_store(self.SIZES)
        s = StratifiedSampler(store, num_groups=4, seed=3)
        counts = s.stratum_counts(2000)
        assert counts[3] == 100              # rare key fully drained
        assert counts.sum() == 2000          # prefix length unchanged
        assert s.stratum_counts(store.N).sum() == store.N

    def test_prefixes_nested_and_without_replacement(self):
        store = _keyed_store(self.SIZES)
        s = StratifiedSampler(store, num_groups=4, seed=5)
        a = np.asarray(s.take(0, 100))
        b = np.asarray(s.take(0, 800))
        np.testing.assert_array_equal(a, b[:100])
        assert len(np.unique(s.perm[:800])) == 800

    def test_within_key_order_matches_base_permutation(self):
        """Each stratum's slice of any prefix must be that stratum's rows
        in BASE permutation order — so per-key prefixes stay uniform
        without-replacement samples of that key."""
        store = _keyed_store(self.SIZES, seed=11)
        base = StratifiedSampler(store, num_groups=4, seed=7)
        ref = np.asarray(store.read_all())[:, 1].astype(np.int64)
        plain_perm = PreMapSampler(store, seed=7).perm
        for g in range(4):
            np.testing.assert_array_equal(
                base.perm[ref[base.perm] == g],
                plain_perm[ref[plain_perm] == g])

    def test_within_key_uniformity(self):
        # clustered values inside one key must come out uniform
        n, nvals = 20_000, 20
        vals = np.sort(np.repeat(np.arange(nvals), n // nvals))
        data = np.stack([vals, np.zeros(n)], axis=1).astype(np.float32)
        data = np.concatenate(
            [data, np.stack([np.zeros(n // 4), np.ones(n // 4)],
                            axis=1).astype(np.float32)])
        store = ShardedStore.from_array(data, 1024)
        s = StratifiedSampler(store, num_groups=2, seed=0)
        sample = np.asarray(s.take(0, 4000))
        key0 = sample[sample[:, 1] == 0.0, 0]
        counts = np.bincount(key0.astype(int), minlength=nvals)
        chi2, p = sps.chisquare(counts)
        assert p > 0.001, f"stratum sample not uniform: chi2={chi2}, p={p}"

    def test_validation_errors(self):
        store = _keyed_store([50, 50])
        with pytest.raises(ValueError, match="keyed rows"):
            StratifiedSampler(ShardedStore.from_array(
                np.zeros((64, 1), np.float32), 32), num_groups=2)
        with pytest.raises(ValueError, match=r"\[0, 1\)"):
            StratifiedSampler(store, num_groups=1)
        bad = ShardedStore.from_array(
            np.stack([np.zeros(64), np.full(64, 0.5)], axis=1).astype(
                np.float32), 32)
        with pytest.raises(ValueError, match="integers"):
            StratifiedSampler(bad, num_groups=2)
        with pytest.raises(ValueError, match="positive"):
            StratifiedSampler(store, num_groups=2, shares=[1.0, -1.0])
        with pytest.raises(ValueError, match="one per group"):
            StratifiedSampler(store, num_groups=2, shares=[1.0])


class TestStore:
    def test_locate_roundtrip(self):
        store = ShardedStore.from_array(
            np.arange(5000, dtype=np.float32)[:, None], 512,
            interleave=False)
        rows = np.array([0, 511, 512, 4999])
        split, local = store.locate(rows)
        for r, s, l in zip(rows, split, local):
            assert store.splits[s][l, 0] == float(r)
