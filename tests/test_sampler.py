"""Sampling over the sharded store (paper §3.3): uniformity, prefix
semantics, read accounting, pre- vs post-map."""
import numpy as np
import pytest
from scipy import stats as sps

from repro.data import (PostMapSampler, PreMapSampler, ShardedStore,
                        synthetic_numeric)


def _store(n=50_000, nvals=20, interleave=True):
    # clustered layout: values sorted -> worst case for block sampling
    data = np.sort(np.repeat(np.arange(nvals), n // nvals)).astype(
        np.float32)[:, None]
    return ShardedStore.from_array(data, 1024, interleave=interleave)


class TestUniformity:
    def test_chi_square_uniform_sample(self):
        """Prefix samples from an adversarially clustered layout must be
        uniform (the paper's block-sampling hazard, §7)."""
        store = _store()
        sampler = PreMapSampler(store, seed=0)
        sample = np.asarray(sampler.take(0, 5000)).ravel()
        counts = np.bincount(sample.astype(int), minlength=20)
        chi2, p = sps.chisquare(counts)
        assert p > 0.001, f"sample not uniform: chi2={chi2}, p={p}"

    def test_prefixes_are_nested(self):
        store = _store()
        sampler = PreMapSampler(store, seed=1)
        a = np.asarray(sampler.take(0, 100))
        b = np.asarray(sampler.take(0, 500))
        np.testing.assert_array_equal(a, b[:100])

    def test_no_replacement_within_prefix(self):
        store = ShardedStore.from_array(
            np.arange(10_000, dtype=np.float32)[:, None], 512)
        sampler = PreMapSampler(store, seed=2)
        s = np.asarray(sampler.take(0, 10_000)).ravel()
        assert len(np.unique(s)) == 10_000


class TestReadAccounting:
    def test_pre_map_reads_only_sample(self):
        store = _store()
        sampler = PreMapSampler(store, seed=3)
        sampler.take(0, 1000)
        assert store.stats.rows_read == 1000
        assert store.stats.splits_opened <= len(store.splits)

    def test_post_map_reads_everything_once(self):
        store = _store()
        sampler = PostMapSampler(store, seed=3)
        sampler.take(0, 1000)
        assert store.stats.rows_read == store.N
        assert sampler.kv_count == store.N        # exact ⟨k,v⟩ accounting
        before = store.stats.rows_read
        sampler.take(1000, 2000)                  # cached: no re-read
        assert store.stats.rows_read == before

    def test_pre_and_post_same_rows(self):
        data = synthetic_numeric(20_000, 10, 2, seed=5)
        s1 = PreMapSampler(ShardedStore.from_array(data, 1024, seed=7),
                           seed=9)
        s2 = PostMapSampler(ShardedStore.from_array(data, 1024, seed=7),
                            seed=9)
        np.testing.assert_allclose(np.asarray(s1.take(0, 500)),
                                   np.asarray(s2.take(0, 500)))


class TestStore:
    def test_locate_roundtrip(self):
        store = ShardedStore.from_array(
            np.arange(5000, dtype=np.float32)[:, None], 512,
            interleave=False)
        rows = np.array([0, 511, 512, 4999])
        split, local = store.locate(rows)
        for r, s, l in zip(rows, split, local):
            assert store.splits[s][l, 0] == float(r)
