"""Kill/resume bitwise contract for the crash-safe streaming bootstrap.

The contract under test: a ``bootstrap_streaming`` (or ``EarlSession``)
run that is KILLED mid-stream and resumed from its last checkpoint
produces a result BITWISE equal to the uninterrupted run.  This works
because chunk i's implicit Poisson weights are keyed
``offset_seed(base_seed, i)`` (position, not history), the fold is a
left-merge in chunk order, and the checkpoint cursor records exactly
(next chunk, rows consumed) — so the resumed suffix re-derives the same
per-chunk streams the dead run would have drawn.

Kills are simulated deterministically: a CheckpointManager subclass
raises AFTER its k-th successful save, which with ``checkpoint_every=1``
dies exactly at chunk boundary k — every boundary is exercised,
including "crash after the final chunk was already committed".
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core.reduce_api import (GroupedStatistic, KMeansStep, Mean,
                                   Quantile, StatisticGroup, Var)
from repro.core.session import EarlSession
from repro.core.streaming import bootstrap_streaming
from repro.data.sampler import PreMapSampler
from repro.data.store import ShardedStore

KEY = jax.random.PRNGKey(7)
CHUNK = 256                      # n=1000 → chunks [256, 256, 256, 232]
N_CHUNKS = 4


class _Kill(Exception):
    """The simulated mid-run death."""


class _DyingManager(CheckpointManager):
    """Commits its first ``die_after`` saves, then kills the run — the
    deterministic stand-in for SIGKILL at a chunk boundary."""

    def __init__(self, root, die_after, **kw):
        kw.setdefault("async_save", False)   # committed before the "crash"
        super().__init__(root, **kw)
        self.die_after = die_after
        self.saves = 0

    def save(self, *a, **kw):
        super().save(*a, **kw)
        self.saves += 1
        if self.saves >= self.die_after:
            raise _Kill(f"simulated crash after save #{self.saves}")


def _store_for(stat, n=1000, seed=0):
    rng = np.random.default_rng(seed)
    if getattr(stat, "num_groups", None) is not None:
        x = rng.normal(size=(n, 2)).astype(np.float32)
        k = rng.integers(0, stat.num_groups, size=(n, 1)).astype(np.float32)
        data = np.concatenate([x, k], axis=1)
    else:
        data = rng.normal(size=(n, 2)).astype(np.float32)
    return ShardedStore.from_array(data, 137, interleave=False)


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


STATS = [
    Mean(), Var(),
    Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
    KMeansStep(jnp.asarray(np.random.default_rng(2)
                           .normal(size=(3, 2)).astype(np.float32))),
    StatisticGroup([Mean(), Quantile(0.25, lo=-4.0, hi=4.0, nbins=32)]),
    GroupedStatistic(Mean(), 4),
]
_IDS = [("Grouped" if getattr(s, "num_groups", None) is not None
         else type(s).__name__) for s in STATS]


class TestStreamingKillResume:
    @pytest.mark.parametrize("die_after", range(1, N_CHUNKS + 1))
    @pytest.mark.parametrize("stat", STATS, ids=_IDS)
    def test_bitwise_at_every_chunk_boundary(self, stat, die_after,
                                             tmp_path):
        store = _store_for(stat)
        base = bootstrap_streaming(store, stat, B=16, key=KEY, chunk=CHUNK)
        assert base.stream.n_chunks == N_CHUNKS

        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, stat, B=16, key=KEY, chunk=CHUNK,
                                checkpoint=_DyingManager(root, die_after),
                                checkpoint_every=1)
        r = bootstrap_streaming(
            store, stat, B=16, key=KEY, chunk=CHUNK, resume=True,
            checkpoint=CheckpointManager(root, async_save=False))
        assert r.stream.resumed_from_chunk == die_after
        assert r.stream.n_chunks == N_CHUNKS - die_after
        _tree_bitwise(base.thetas, r.thetas)
        _tree_bitwise(base.estimate, r.estimate)
        assert base.n == r.n

    def test_resume_onto_different_queue_depth(self, tmp_path):
        """The cursor pins the math (chunk index, seed); the prefetch
        queue depth is pure mechanics and may differ across the restart."""
        store = _store_for(Mean())
        base = bootstrap_streaming(store, Mean(), B=16, key=KEY,
                                   chunk=CHUNK, queue_depth=2)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=16, key=KEY, chunk=CHUNK,
                                queue_depth=2,
                                checkpoint=_DyingManager(root, 2))
        r = bootstrap_streaming(
            store, Mean(), B=16, key=KEY, chunk=CHUNK, queue_depth=5,
            resume=True,
            checkpoint=CheckpointManager(root, async_save=False))
        _tree_bitwise(base.thetas, r.thetas)
        _tree_bitwise(base.estimate, r.estimate)

    def test_ragged_tail_boundary(self, tmp_path):
        """Crash right before the ragged final chunk: the resumed run's
        only work is the 232-row tail, and the cursor's start_row lands
        mid-split (splits are 137 rows, chunks 256)."""
        store = _store_for(Mean())
        base = bootstrap_streaming(store, Mean(), B=16, key=KEY,
                                   chunk=CHUNK)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=16, key=KEY, chunk=CHUNK,
                                checkpoint=_DyingManager(root, 3))
        store.stats.reset()
        r = bootstrap_streaming(
            store, Mean(), B=16, key=KEY, chunk=CHUNK, resume=True,
            checkpoint=CheckpointManager(root, async_save=False))
        _tree_bitwise(base.thetas, r.thetas)
        # the resumed pass must NOT re-read the 768 committed rows
        assert store.stats.rows_read < store.N

    def test_checkpoint_overhead_run_without_resume_matches(self, tmp_path):
        """Checkpointing must be an observer: a checkpointed (uninterrupted)
        run returns the same bits as a plain run."""
        store = _store_for(Var())
        base = bootstrap_streaming(store, Var(), B=16, key=KEY, chunk=CHUNK)
        r = bootstrap_streaming(
            store, Var(), B=16, key=KEY, chunk=CHUNK,
            checkpoint=str(tmp_path / "ckpt"), checkpoint_every=2)
        _tree_bitwise(base.thetas, r.thetas)
        _tree_bitwise(base.estimate, r.estimate)
        assert r.stream.n_checkpoints == 2


class TestResumeValidation:
    def test_resume_needs_checkpoint(self):
        with pytest.raises(ValueError, match="resume"):
            bootstrap_streaming(_store_for(Mean()), Mean(), B=8, key=KEY,
                                chunk=CHUNK, resume=True)

    def test_fingerprint_rejects_different_statistic(self, tmp_path):
        store = _store_for(Mean())
        root = str(tmp_path / "ckpt")
        bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                            checkpoint=CheckpointManager(root,
                                                         async_save=False))
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bootstrap_streaming(
                store, Var(), B=8, key=KEY, chunk=CHUNK, resume=True,
                checkpoint=CheckpointManager(root, async_save=False))

    @pytest.mark.parametrize("kw", [
        dict(key=jax.random.PRNGKey(8)),      # different weight streams
        dict(chunk=128),                      # different chunk geometry
        dict(B=16),                           # different resample count
    ], ids=["key", "chunk", "B"])
    def test_fingerprint_rejects_different_run_knobs(self, tmp_path, kw):
        store = _store_for(Mean())
        root = str(tmp_path / "ckpt")
        args = dict(B=8, key=KEY, chunk=CHUNK)
        bootstrap_streaming(store, Mean(), checkpoint=CheckpointManager(
            root, async_save=False), **args)
        args.update(kw)
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bootstrap_streaming(
                store, Mean(), resume=True,
                checkpoint=CheckpointManager(root, async_save=False),
                **args)

    def test_fingerprint_rejects_different_array_params(self, tmp_path):
        """Same spec, different TRACED params (KMeans centroids) — the
        fingerprint hashes param bytes, not just the structural key."""
        rng = np.random.default_rng(3)
        c1 = jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))
        c2 = jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))
        store = _store_for(Mean())
        root = str(tmp_path / "ckpt")
        bootstrap_streaming(store, KMeansStep(c1), B=8, key=KEY,
                            chunk=CHUNK, checkpoint=CheckpointManager(
                                root, async_save=False))
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bootstrap_streaming(
                store, KMeansStep(c2), B=8, key=KEY, chunk=CHUNK,
                resume=True,
                checkpoint=CheckpointManager(root, async_save=False))

    def test_fingerprint_content_rejects_changed_bytes(self, tmp_path):
        """By default the fingerprint binds the run SHAPE (stat, B, key,
        chunk, N, dim) but not the bytes — ``fingerprint_content=True``
        folds the store's split checksums in, so resuming onto a
        same-shape store whose data changed refuses loudly instead of
        silently mixing two datasets."""
        rng = np.random.default_rng(0)
        data = rng.normal(size=(1000, 2)).astype(np.float32)
        store = ShardedStore.from_array(data, 137, interleave=False)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                fingerprint_content=True,
                                checkpoint=_DyingManager(root, 2))
        changed = np.array(data)
        changed[500, 0] += 1.0                      # one element, same shape
        bad = ShardedStore.from_array(changed, 137, interleave=False)
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bootstrap_streaming(bad, Mean(), B=8, key=KEY, chunk=CHUNK,
                                resume=True, fingerprint_content=True,
                                checkpoint=CheckpointManager(
                                    root, async_save=False))
        # the SAME bytes resume cleanly, and bitwise so
        base = bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK)
        same = ShardedStore.from_array(data, 137, interleave=False)
        r = bootstrap_streaming(same, Mean(), B=8, key=KEY, chunk=CHUNK,
                                resume=True, fingerprint_content=True,
                                checkpoint=CheckpointManager(
                                    root, async_save=False))
        _tree_bitwise(base.thetas, r.thetas)

    def test_content_digest_sensitivity(self):
        """store_content_digest: stable across calls, identical for
        identical bytes, different for a one-element change."""
        from repro.core.streaming import store_content_digest
        rng = np.random.default_rng(1)
        data = rng.normal(size=(500, 2)).astype(np.float32)
        a = ShardedStore.from_array(data, 64, interleave=False)
        assert store_content_digest(a) == store_content_digest(a)
        b = ShardedStore.from_array(np.array(data), 64, interleave=False)
        assert store_content_digest(a) == store_content_digest(b)
        mut = np.array(data)
        mut[0, 0] = np.float32(mut[0, 0]) + 1.0
        c = ShardedStore.from_array(mut, 64, interleave=False)
        assert store_content_digest(a) != store_content_digest(c)

    def test_default_fingerprint_binds_shape_not_content(self, tmp_path):
        """The documented default: without ``fingerprint_content`` a
        same-shape different-bytes store is accepted on resume (cheap
        fingerprints; callers opt into the checksum pass)."""
        rng = np.random.default_rng(2)
        data = rng.normal(size=(1000, 2)).astype(np.float32)
        store = ShardedStore.from_array(data, 137, interleave=False)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                checkpoint=_DyingManager(root, 2))
        changed = np.array(data)
        changed[0, 0] += 1.0
        bad = ShardedStore.from_array(changed, 137, interleave=False)
        r = bootstrap_streaming(bad, Mean(), B=8, key=KEY, chunk=CHUNK,
                                resume=True,
                                checkpoint=CheckpointManager(
                                    root, async_save=False))
        assert r.stream.resumed_from_chunk == 2

    def test_foreign_checkpoint_rejected(self, tmp_path):
        """A checkpoint without a streaming cursor (e.g. an EarlSession or
        training snapshot) must be refused, not silently misread."""
        store = _store_for(Mean())
        root = str(tmp_path / "ckpt")
        mgr = CheckpointManager(root, async_save=False)
        stat = Mean()
        states = jax.vmap(lambda _: stat.init_state(2))(jnp.arange(8))
        mgr.save(0, (states, stat.init_state(2)), extra={"note": "foreign"})
        with pytest.raises(ValueError, match="cursor"):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                resume=True, checkpoint=mgr)


class TestSessionKillResume:
    """Same contract one layer up: an EarlSession killed between expansion
    rounds resumes from its checkpointed delta-maintained carry and ends
    with the identical early result."""

    SIGMA = 0.01

    def _session(self, store, checkpoint=None):
        return EarlSession(PreMapSampler(store, seed=4), Mean(),
                           sigma=self.SIGMA, backend="fused_rng",
                           checkpoint=checkpoint)

    @pytest.fixture(scope="class")
    def store(self):
        rng = np.random.default_rng(1)
        data = rng.normal(loc=3.0, scale=5.0,
                          size=(200_000, 2)).astype(np.float32)
        return ShardedStore.from_array(data, 8192)

    def test_kill_after_first_round_resumes_bitwise(self, store, tmp_path):
        key = jax.random.PRNGKey(11)
        base = self._session(store).run(key)
        assert base.iterations > 1          # the kill point must be mid-run

        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            self._session(store, _DyingManager(root, 1)).run(key)
        r = self._session(store, CheckpointManager(
            root, async_save=False)).run(key, resume=True)
        assert r.iterations == base.iterations
        assert r.n_used == base.n_used
        assert r.cv == base.cv
        _tree_bitwise(r.result, base.result)
        _tree_bitwise(r.ci_lo, base.ci_lo)
        assert len(r.history) == len(base.history)

    def test_resume_after_completed_run_rederives_result(self, store,
                                                         tmp_path):
        """Killed between the final save and the return: resume re-checks
        the sigma gate on the restored carry and returns without extending
        the sample any further."""
        key = jax.random.PRNGKey(11)
        root = str(tmp_path / "ckpt")
        full = self._session(store, CheckpointManager(
            root, async_save=False)).run(key)
        store.stats.reset()
        again = self._session(store, CheckpointManager(
            root, async_save=False)).run(key, resume=True)
        assert again.iterations == full.iterations
        assert again.n_used == full.n_used
        _tree_bitwise(again.result, full.result)
        # only the (capped) pilot is re-read; the main sample is not
        assert store.stats.rows_read < full.n_used

    def test_session_fingerprint_rejects_different_stat(self, store,
                                                        tmp_path):
        key = jax.random.PRNGKey(11)
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            self._session(store, _DyingManager(root, 1)).run(key)
        bad = EarlSession(PreMapSampler(store, seed=4), Var(),
                          sigma=self.SIGMA, backend="fused_rng",
                          checkpoint=CheckpointManager(root,
                                                       async_save=False))
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bad.run(key, resume=True)

    def test_session_resume_needs_checkpoint(self, store):
        with pytest.raises(ValueError, match="resume"):
            self._session(store).run(jax.random.PRNGKey(0), resume=True)


class TestPinnedResumeOverGrowingStore:
    """``n_rows=`` + ``fingerprint_content=True`` is the durable-ingest
    resume contract: a pinned run checkpointed mid-stream must resume
    BITWISE even after the log grew underneath it — the fingerprint binds
    the pinned prefix (extent + prefix bytes), not the whole store."""

    def test_resume_after_growth_is_bitwise(self, tmp_path):
        rng = np.random.default_rng(9)
        splits = [rng.normal(size=(250, 2)).astype(np.float32)
                  for _ in range(6)]
        n_rows = 250 * 4                        # pin to the first 4 batches

        base_store = ShardedStore([s.copy() for s in splits[:4]])
        base = bootstrap_streaming(base_store, Mean(), B=8, key=KEY,
                                   chunk=CHUNK)

        store = ShardedStore([s.copy() for s in splits[:4]])
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                n_rows=n_rows, fingerprint_content=True,
                                checkpoint=_DyingManager(root, 2))
        for s in splits[4:]:                    # the log grows meanwhile
            store.append_split(s.copy())
        r = bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                n_rows=n_rows, resume=True,
                                fingerprint_content=True,
                                checkpoint=CheckpointManager(
                                    root, async_save=False))
        assert r.stream.resumed_from_chunk == 2
        _tree_bitwise(base.thetas, r.thetas)
        _tree_bitwise(base.estimate, r.estimate)
        assert base.n == r.n == n_rows

    def test_fingerprint_binds_the_extent(self, tmp_path):
        """Resuming with a DIFFERENT n_rows is a different run and must
        refuse loudly, not silently re-scale the correction."""
        store = _store_for(Mean())
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                n_rows=750,
                                checkpoint=_DyingManager(root, 2))
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            bootstrap_streaming(store, Mean(), B=8, key=KEY, chunk=CHUNK,
                                n_rows=500, resume=True,
                                checkpoint=CheckpointManager(
                                    root, async_save=False))
