"""Hypothesis property suite for grouped segment-reduction (ISSUE-7
satellite): random keys, masks, shapes and dtypes must preserve the two
load-bearing invariants of the GROUP BY kernels —

* grouped ≡ per-key oracle BITWISE: slot g of a grouped fused call equals
  the ungrouped call under ``valid_mask = (key == g)`` (common random
  numbers — one shared implicit Poisson(1) stream, exact 0/1 key masks);
* scan ≡ Pallas(interpret) bitwise under no mask, prefix masks, and
  interior-hole masks (both lowerings share the tile weight math).

Deterministic fixed-case coverage of the same contracts lives in
tests/test_grouped.py; this module extends it across the input space and
is skipped wholesale when hypothesis is not installed (the pattern of
tests/test_properties.py).
"""
import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.weighted_hist.ops import fused_poisson_hist  # noqa: E402
from repro.kernels.weighted_stats.ops import \
    fused_poisson_moments  # noqa: E402

_settings = settings(max_examples=30, deadline=None)


def _tree_bitwise(a, b):
    import jax
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


class TestGroupedSegmentReductionProperties:
    @given(n=st.integers(2, 257), g=st.integers(1, 5),
           b=st.integers(1, 9), seed=st.integers(0, 2**20),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    @_settings
    def test_grouped_equals_per_key_oracle_bitwise(self, n, g, b, seed,
                                                   dtype):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        gid = jnp.asarray(rng.integers(0, g, size=n).astype(np.float32))
        dt = jnp.dtype(dtype)
        wt, s1, s2 = fused_poisson_moments(seed, x, b, group_ids=gid,
                                           num_groups=g, dtype=dt)
        for gg in range(g):
            ref = fused_poisson_moments(
                seed, x, b, valid_mask=(gid == gg).astype(jnp.float32),
                dtype=dt)
            _tree_bitwise((wt[:, gg], s1[:, gg], s2[:, gg]), ref)

    @given(n=st.integers(2, 257), g=st.integers(1, 4),
           b=st.integers(1, 9), seed=st.integers(0, 2**20),
           mode=st.sampled_from(["none", "prefix", "holes"]))
    @_settings
    def test_scan_equals_pallas_under_masks(self, n, g, b, seed, mode):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        gid = jnp.asarray(rng.integers(0, g, size=n).astype(np.float32))
        if mode == "none":
            mask = None
        elif mode == "prefix":
            mask = jnp.asarray(
                (np.arange(n) < rng.integers(0, n + 1)).astype(np.float32))
        else:
            mask = jnp.asarray((rng.random(n) > 0.5).astype(np.float32))
        s = fused_poisson_moments(seed, x, b, backend="scan",
                                  valid_mask=mask, group_ids=gid,
                                  num_groups=g)
        k = fused_poisson_moments(seed, x, b, backend="pallas_interpret",
                                  valid_mask=mask, group_ids=gid,
                                  num_groups=g)
        _tree_bitwise(s, k)

    @given(n=st.integers(2, 200), g=st.integers(1, 4),
           seed=st.integers(0, 2**20))
    @_settings
    def test_grouped_hist_equals_per_key_oracle(self, n, g, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, 1)).astype(np.float32))
        gid = jnp.asarray(rng.integers(0, g, size=n).astype(np.float32))
        counts = fused_poisson_hist(seed, x, -4.0, 4.0, 16, 4,
                                    group_ids=gid, num_groups=g)
        for gg in range(g):
            ref = fused_poisson_hist(
                seed, x, -4.0, 4.0, 16, 4,
                valid_mask=(gid == gg).astype(jnp.float32))
            _tree_bitwise(counts[:, gg], ref)
