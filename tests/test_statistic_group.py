"""StatisticGroup: single-pass multi-statistic bootstrap (ISSUE-5).

Covers the acceptance criteria:
  * jaxpr shape/stream capture at n=2^20, B=256: the group pipeline
    materializes NO (B, n) weight matrix and draws ONE threefry stream per
    tile (same eqn count as a single-statistic run — not one per member);
  * statistical equivalence vs per-member oracles: shared weights make the
    group's member thetas BITWISE equal to each member's dedicated fused
    run under the same key (joint CIs from common random numbers), on both
    the fused and the materialized backends;
  * a 1-member group is bitwise equal to the existing fused path;
  * slot dedup: Mean+Var+Std share one moment accumulator, same-range
    quantiles share one sketch;
  * the Pallas multi-kernel (interpret mode) matches the scan lowering;
  * KMeansStep and custom statistics consume the same cached weight tiles
    via the per-tile callback fallback;
  * group flows end-to-end through chunked / delta / SSABE / EarlSession
    (per-member reports, stop when ALL members meet sigma) and the sharded
    single-device oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EarlSession, GroupAccuracyReport, KMeansStep, Mean,
                        Quantile, Statistic, StatisticGroup, Std, Var,
                        bootstrap, bootstrap_chunked, sharded_fused_states)
from repro.core.bootstrap import fused_resample_states, seed_from_key
from repro.core.delta import (poisson_delta_extend, poisson_delta_init,
                              poisson_delta_result)
from repro.core.reduce_api import (_ArrayParam, bind_params, split_params)
from repro.core.ssabe import ssabe
from repro.kernels.fused_multi import ops as fm_ops
from test_matrix_free import _max_intermediate_size, _walk_shapes  # noqa


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(u), np.asarray(v))
        for u, v in zip(la, lb))


def _members():
    return (Mean(), Var(), Quantile(0.5, nbins=512, lo=0.0, hi=16.0))


def _group():
    return StatisticGroup(_members())


# ----------------------------------------------------------------------------
# jaxpr capture: one shared stream, no (B, n) intermediate
# ----------------------------------------------------------------------------
def _count_eqns(fn, *args, name="random_bits"):
    """Count PRNG draw eqns (``random_bits`` is the threefry draw under
    jax's typed-key API — one per weight-tile stream)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return _walk_count(jaxpr.jaxpr, name)


def _walk_count(jaxpr, name):
    c = 0
    for eqn in jaxpr.eqns:
        if name in eqn.primitive.name:
            c += 1
        for p in eqn.params.values():
            for q in (p if isinstance(p, (tuple, list)) else (p,)):
                if hasattr(q, "jaxpr") and hasattr(q.jaxpr, "eqns"):
                    c += _walk_count(q.jaxpr, name)
                elif hasattr(q, "eqns"):
                    c += _walk_count(q, name)
    return c


class TestSharedStreamCapture:
    B, N = 256, 1 << 20

    def test_group_pipeline_never_builds_Bn(self, key):
        """n=2^20, B=256: every intermediate of the traced 3-statistic
        group pipeline is far smaller than the (B, n) weight matrix."""
        from repro.core.bootstrap import _fused_thetas
        x = jnp.zeros((self.N,), jnp.float32)
        biggest = _max_intermediate_size(
            lambda v, k: _fused_thetas(v, _group(), self.B, k), x, key)
        assert biggest < self.B * self.N / 100, (
            f"largest intermediate has {biggest} elements — "
            f"(B, n) would be {self.B * self.N}")

    def test_one_threefry_stream_per_tile_not_per_member(self, key):
        """The traced group pipeline contains exactly as many threefry
        eqns as a SINGLE-statistic run — the weight tile is drawn once and
        shared, not regenerated per member."""
        from repro.core.bootstrap import _fused_thetas
        x = jnp.zeros((self.N,), jnp.float32)
        n_group = _count_eqns(
            lambda v, k: _fused_thetas(v, _group(), self.B, k), x, key)
        n_single = _count_eqns(
            lambda v, k: _fused_thetas(v, Mean(), self.B, k), x, key)
        assert n_single > 0          # harness sanity: stream is visible
        assert n_group == n_single, (
            f"group traces {n_group} threefry eqns vs {n_single} for one "
            f"statistic — members are regenerating the stream")

    def test_harness_detects_sequential_duplication(self, key):
        """Sanity: the same counter DOES flag k sequential runs."""
        from repro.core.bootstrap import _fused_thetas

        def seq(v, k):
            return [_fused_thetas(v, m, self.B, k) for m in _members()]

        x = jnp.zeros((self.N,), jnp.float32)
        n_seq = _count_eqns(seq, x, key)
        n_single = _count_eqns(
            lambda v, k: _fused_thetas(v, Mean(), self.B, k), x, key)
        assert n_seq >= 3 * n_single


# ----------------------------------------------------------------------------
# slot dedup + construction
# ----------------------------------------------------------------------------
class TestGroupStructure:
    def test_moment_members_share_one_slot(self):
        g = StatisticGroup((Mean(), Var(), Std(),
                            Quantile(0.5, nbins=64, lo=0.0, hi=1.0)))
        assert len(g.slots) == 2
        assert g.member_slot == (0, 0, 0, 1)

    def test_same_range_quantiles_share_one_sketch(self, key):
        g = StatisticGroup((Quantile(0.25, nbins=128, lo=0.0, hi=10.0),
                            Quantile(0.75, nbins=128, lo=0.0, hi=10.0)))
        assert len(g.slots) == 1
        x = jax.random.uniform(key, (500,)) * 10
        q25, q75 = g(x)
        assert float(q25) < float(q75)

    def test_different_range_quantiles_get_own_slots(self):
        g = StatisticGroup((Quantile(0.5, nbins=128, lo=0.0, hi=10.0),
                            Quantile(0.5, nbins=256, lo=0.0, hi=10.0)))
        assert len(g.slots) == 2

    def test_kmeans_and_custom_never_shared(self):
        cent = jnp.zeros((2, 1))
        g = StatisticGroup((KMeansStep(cent), KMeansStep(cent), Mean()))
        assert len(g.slots) == 3

    def test_constructor_errors(self):
        with pytest.raises(ValueError, match="at least one"):
            StatisticGroup(())
        with pytest.raises(TypeError, match="flatten"):
            StatisticGroup((StatisticGroup((Mean(),)),))
        with pytest.raises(TypeError, match="not a Statistic"):
            StatisticGroup((Mean(), 3.0))
        with pytest.raises(ValueError, match="backend"):
            StatisticGroup((Mean(),), backend="tpu")

    def test_kernel_backend_rejects_kmeans_groups(self, key):
        g = StatisticGroup((Mean(), KMeansStep(jnp.zeros((2, 1)))))
        x = jax.random.normal(key, (256, 1))
        with pytest.raises(ValueError, match="scan"):
            fm_ops.fused_poisson_multi(g, 7, x, 8,
                                       backend="pallas_interpret")

    def test_split_bind_params_thread_member_arrays(self):
        cent = jnp.array([[1.0], [2.0]])
        g = StatisticGroup((Mean(), KMeansStep(cent)))
        spec, params = split_params(g)
        assert isinstance(spec.members[1].centroids, _ArrayParam)
        g2 = bind_params(spec, params)
        np.testing.assert_array_equal(np.asarray(g2.members[1].centroids),
                                      np.asarray(cent))
        # same-shaped fresh group -> SAME spec (one jit cache entry)
        g3 = StatisticGroup((Mean(), KMeansStep(cent + 1.0)))
        assert split_params(g3)[0] == spec


# ----------------------------------------------------------------------------
# equivalence vs per-member oracles (shared weights => bitwise)
# ----------------------------------------------------------------------------
class TestGroupEquivalence:
    def test_fused_member_thetas_bitwise_equal_dedicated_runs(self, key):
        x = jax.random.normal(key, (1000,)) * 2 + 8
        r_g = bootstrap(x, _group(), B=32, key=key, backend="fused_rng")
        for i, m in enumerate(_members()):
            r_m = bootstrap(x, m, B=32, key=key, backend="fused_rng")
            np.testing.assert_array_equal(np.asarray(r_g.thetas[i]),
                                          np.asarray(r_m.thetas))
            np.testing.assert_array_equal(np.ravel(r_g.estimate[i]),
                                          np.ravel(r_m.estimate))

    def test_materialized_backend_shares_weights_too(self, key):
        """backend=None draws ONE (B, n) poisson matrix for the whole
        group — member thetas equal dedicated materialized runs."""
        x = jax.random.normal(key, (700,)) + 5
        r_g = bootstrap(x, _group(), B=16, key=key)
        for i, m in enumerate(_members()):
            r_m = bootstrap(x, m, B=16, key=key)
            np.testing.assert_allclose(np.asarray(r_g.thetas[i]),
                                       np.asarray(r_m.thetas),
                                       rtol=1e-6)

    def test_one_member_group_bitwise_equals_fused_path(self, key):
        x = jax.random.normal(key, (900, 2))
        for m in (Mean(), Quantile(0.5, nbins=256, lo=-8.0, hi=8.0),
                  KMeansStep(jnp.array([[0.0, 0.0], [1.0, 1.0]]))):
            sg = fused_resample_states(StatisticGroup((m,)), jnp.int32(7),
                                       x, 16)
            sm = fused_resample_states(m, jnp.int32(7), x, 16)
            assert _leaves_equal(sg, sm), type(m).__name__

    def test_kernel_matches_scan_lowering(self, key):
        x = jax.random.normal(key, (700, 2)) + 4
        g = StatisticGroup((Mean(), Var(),
                            Quantile(0.5, nbins=200, lo=0.0, hi=8.0),
                            Quantile(0.9, nbins=128, lo=-1.0, hi=9.0)))
        a = fm_ops.fused_poisson_multi(g, 11, x, 24, backend="scan")
        b = fm_ops.fused_poisson_multi(g, 11, x, 24,
                                       backend="pallas_interpret")
        for u, v in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-5, atol=1e-4)

    def test_kmeans_member_consumes_shared_tiles(self, key):
        x = jax.random.normal(key, (800, 2))
        cent = jnp.array([[-1.0, -1.0], [1.0, 1.0]])
        g = StatisticGroup((Mean(), KMeansStep(cent)))
        s_g = fused_resample_states(g, jnp.int32(5), x, 16)
        s_k = fused_resample_states(KMeansStep(cent), jnp.int32(5), x, 16)
        assert _leaves_equal(s_g[1], s_k)

    def test_custom_statistic_tile_callback_fallback(self, key):
        """A statistic with NO tile_update override rides the same cached
        weight tiles through the default vmapped-update callback."""

        class NoTileMean(Mean):
            def accumulator_key(self):
                return None              # own slot

            def tile_update(self, states, x_tile, w_tile):
                return Statistic.tile_update(self, states, x_tile, w_tile)

        x = jax.random.normal(key, (900,)) + 3
        g = StatisticGroup((Mean(), NoTileMean()))
        r = bootstrap(x, g, B=16, key=key, backend="fused_rng")
        np.testing.assert_allclose(np.asarray(r.thetas[0]),
                                   np.asarray(r.thetas[1]), rtol=1e-5)

    def test_n_valid_masks_padding(self, key):
        n, pad = 700, 1024 - 700
        x = jax.random.uniform(key, (n, 1)) * 10
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        g = _group()
        a = fused_resample_states(g, jnp.int32(3), x, 16)
        b = g.fused_poisson_states(jnp.int32(3), xp, 16, n_valid=n)
        for u, v in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-6)


# ----------------------------------------------------------------------------
# drivers: chunked / sharded / delta / ssabe / session
# ----------------------------------------------------------------------------
class TestGroupDrivers:
    def test_chunked_matches_unchunked(self, key):
        x = jax.random.normal(key, (3000,)) * 2 + 8
        r_p = bootstrap(x, _group(), B=64, key=key, backend="fused_rng")
        r_c = bootstrap_chunked(x, _group(), B=64, key=key, chunk=512,
                                backend="fused_rng")
        for tp, tc in zip(r_p.thetas, r_c.thetas):
            assert np.isfinite(np.asarray(tc)).all()
        assert abs(r_p.cv - r_c.cv) / (r_p.cv + 1e-12) < 1.0

    def test_sharded_oracle_composes_memberwise(self, key):
        """nshards=1 == unsharded; nshards=4 psums slot-wise (Quantile
        lo/hi untouched)."""
        x = jax.random.normal(key, (1000, 1)) * 2 + 8
        g = _group()
        s1 = sharded_fused_states(g, 7, x, 16, nshards=1)
        s0 = fused_resample_states(g, jnp.int32(7), x, 16)
        assert _leaves_equal(s1, s0)
        s4 = sharded_fused_states(g, 7, x, 16, nshards=4)
        t0 = jax.vmap(g.finalize)(s0)
        t4 = jax.vmap(g.finalize)(s4)
        for a, b in zip(t0, t4):
            assert np.isfinite(np.asarray(b)).all()
        # lo/hi config leaves survive the shard merge un-scaled
        np.testing.assert_array_equal(np.asarray(s4[1].lo),
                                      np.asarray(s0[1].lo))

    def test_delta_extend_matches_per_member_delta(self, key):
        x = jax.random.normal(key, (900, 1)) + 5
        pieces = (x[:400], x[400:])
        pd = poisson_delta_init(_group(), 16, 1, key, backend="fused_rng")
        for piece in pieces:
            pd = poisson_delta_extend(pd, piece)
        res = poisson_delta_result(pd)
        assert isinstance(res.report, GroupAccuracyReport)
        for i, m in enumerate(_members()):
            pm = poisson_delta_init(m, 16, 1, key, backend="fused_rng")
            for piece in pieces:
                pm = poisson_delta_extend(pm, piece)
            np.testing.assert_array_equal(
                np.asarray(res.thetas[i]),
                np.asarray(poisson_delta_result(pm).thetas))

    def test_ssabe_group_stops_on_worst_member(self, key):
        x = jax.random.normal(key, (1000,)) * 2 + 10
        r = ssabe(x, _group(), sigma=0.05, tau=0.01, key=key,
                  backend="fused_rng")
        assert r.B >= 2 and r.n >= 1
        assert len(r.cv_history_n) == 5

    def test_session_end_to_end_per_member_reports(self, key):
        class Perm:
            def __init__(self, data):
                self.data = np.asarray(data)
                self.N = len(data)

            def take(self, a, b):
                return jnp.asarray(self.data[a:b])

        data = np.random.default_rng(3).normal(10, 2, 200_000).astype(
            np.float32)
        g = StatisticGroup((Mean(), Quantile(0.5, lo=0.0, hi=25.0), Std()))
        sess = EarlSession(Perm(data), g, sigma=0.03, backend="fused_rng")
        out = sess.run(key)
        assert not out.fell_back
        assert len(out.reports) == 3
        # every member met sigma (the group gate is the WORST member)
        assert all(r.cv <= 0.03 for r in out.reports)
        assert out.cv == max(r.cv for r in out.reports)
        assert "member_cvs" in out.history[-1]
        est = [float(np.ravel(v)[0]) for v in out.result]
        assert abs(est[0] - 10.0) < 0.3        # mean
        assert abs(est[1] - 10.0) < 0.3        # median
        assert abs(est[2] - 2.0) < 0.3         # std


class TestGroupAccuracyReport:
    def test_worst_member_gates(self, key):
        x = jax.random.normal(key, (500,)) + 6
        r = bootstrap(x, StatisticGroup((Mean(), Var())), B=32, key=key,
                      backend="fused_rng")
        rep = r.report
        assert isinstance(rep, GroupAccuracyReport)
        assert rep.cv == max(m.cv for m in rep.members)
        assert rep.se == max(m.se for m in rep.members)
        assert len(rep.ci_lo) == 2 and len(rep.cvs) == 2
