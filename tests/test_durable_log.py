"""Durable segment log robustness (live/segment.py + live/durable_log.py).

The contract under test, bitwise where the promise is bitwise:

* a clean round trip through the on-disk segment format recovers a store
  bitwise equal to the in-memory ``IngestLog`` fed the same batches,
  under every fsync policy (which must not change the bytes);
* producer kill-at-any-byte: truncating the tail segment at EVERY byte
  offset recovers the surviving prefix bitwise, counts exactly one torn
  read, and a ``LiveSession`` over the recovered log reproduces the
  uninterrupted session's reports bitwise;
* random mid-file bit flips are caught by the per-record CRC framing:
  recovery truncates at the damaged segment with exact
  ``FaultCounters`` accounting;
* ENOSPC mid-append raises loudly, never corrupts the sealed prefix,
  and the producer resumes after space frees up;
* one writer per log (pid lock, stale locks reclaimed);
* a tailing consumer in another process sees every sealed batch exactly
  once through ``LiveSession``;
* an unreadable segment under ``FailurePolicy(on_exhausted="degrade")``
  becomes invalid rows (``p_eff`` drops, the CI widens) instead of
  killing the session — and ``reload()`` after out-of-band repair swaps
  the real bytes back in with a FRESH split checksum (the stale-crc
  cache regression).
"""
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core.reduce_api import Mean
from repro.core.streaming import bootstrap_streaming
from repro.data.store import ShardedStore
from repro.ft import (FailurePolicy, LagPolicy, bit_flip, enospc_after,
                      torn_write)
from repro.live import (CorruptSegmentError, DurableIngestLog, IngestLog,
                        LiveSession, LogLockedError, SegmentError,
                        TornSegmentError)
from repro.live import segment as seg

KEY = jax.random.PRNGKey(29)
B = 4
ROWS = 8
DIM = 2
N_BATCHES = 4


def _batches(n=N_BATCHES, rows=ROWS, dim=DIM, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((rows, dim)).astype(np.float32)
            for _ in range(n)]


def _mem_log(batches):
    log = IngestLog()
    for b in batches:
        log.append(b)
    return log


def _write_log(root, batches, fsync="never"):
    with DurableIngestLog(root, fsync=fsync) as log:
        for b in batches:
            log.append(b)
        log.flush()


def _assert_store_bitwise(a, b):
    assert len(a.splits) == len(b.splits)
    for i in range(len(a.splits)):
        assert np.array_equal(np.asarray(a.splits[i]),
                              np.asarray(b.splits[i])), f"split {i} differs"
        assert a.split_checksum(i) == b.split_checksum(i)


def _session_reports(log):
    sess = LiveSession(log, Mean(), B=B, key=KEY)
    return sess.poll()


def _assert_reports_bitwise(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.seq == w.seq and g.p_eff == w.p_eff
        assert np.array_equal(np.asarray(g.thetas), np.asarray(w.thetas))
        assert np.array_equal(np.asarray(g.estimate),
                              np.asarray(w.estimate))


# -- format ------------------------------------------------------------


def test_segment_round_trip(tmp_path):
    data = _batches(1)[0]
    path = seg.write_segment(str(tmp_path), 7, data, sync=True)
    assert os.path.basename(path) == "seg_00000007.seg"
    first_seq, dim, recs = seg.read_segment(path, expect_seq=7,
                                            expect_dim=DIM)
    assert (first_seq, dim) == (7, DIM) and len(recs) == 1
    assert recs[0][0] == 7
    assert np.array_equal(recs[0][1], data)
    probe = seg.probe_segment(path)
    assert probe.ok and probe.rows == ROWS and probe.dim == DIM


def test_segment_name_parse():
    assert seg.parse_segment_name("seg_00000042.seg") == 42
    for bad in ("seg_.seg", "seg_0001.tmp", "ckpt_0001", "seg_x1.seg"):
        assert seg.parse_segment_name(bad) is None


def test_segment_validation_rejects_wrong_identity(tmp_path):
    path = seg.write_segment(str(tmp_path), 3, _batches(1)[0])
    with pytest.raises(CorruptSegmentError):
        seg.read_segment(path, expect_seq=4)
    with pytest.raises(CorruptSegmentError):
        seg.read_segment(path, expect_dim=DIM + 1)


# -- clean round trip --------------------------------------------------


@pytest.mark.parametrize("fsync", ["never", "batch", "always"])
def test_durable_append_recover_bitwise(tmp_path, fsync):
    batches = _batches()
    root = str(tmp_path / fsync)
    _write_log(root, batches, fsync=fsync)

    mem = _mem_log(batches)
    log = DurableIngestLog(root)
    assert log.recovery.batches == N_BATCHES
    assert log.recovery.truncated_at is None
    assert log.next_seq == N_BATCHES
    assert log.total_rows == mem.total_rows
    _assert_store_bitwise(log.store, mem.store)
    # ... and recovery is append-ready: the resumed producer continues
    # the same log the in-memory oracle would have
    extra = _batches(1, seed=99)[0]
    assert log.append(extra) == N_BATCHES
    log.close()
    mem.append(extra)
    log2 = DurableIngestLog(root)
    _assert_store_bitwise(log2.store, mem.store)
    log2.close()


def test_fsync_policy_does_not_change_bytes(tmp_path):
    batches = _batches()
    blobs = {}
    for fsync in ("never", "batch", "always"):
        root = str(tmp_path / fsync)
        _write_log(root, batches, fsync=fsync)
        blobs[fsync] = [open(os.path.join(root, seg.segment_name(i)),
                             "rb").read() for i in range(N_BATCHES)]
    assert blobs["never"] == blobs["batch"] == blobs["always"]


def test_read_paths_work_unchanged_over_durable_log(tmp_path):
    """The recovered log IS a ShardedStore: bootstrap_streaming over it
    equals the same run over a plain store of the same rows."""
    batches = _batches()
    _write_log(str(tmp_path), batches)
    log = DurableIngestLog(str(tmp_path))
    r_log = bootstrap_streaming(log.store, Mean(), 16, KEY, chunk=8)
    r_ref = bootstrap_streaming(ShardedStore([np.array(b) for b in batches]),
                                Mean(), 16, KEY, chunk=8)
    assert np.array_equal(np.asarray(r_log.thetas), np.asarray(r_ref.thetas))
    log.close()


def test_append_copies_callers_buffer():
    """Seal = defensive copy: a producer reusing its staging buffer must
    not mutate sealed history (or stale its cached checksum)."""
    buf = np.ones((4, 2), np.float32)
    mem = IngestLog()
    mem.append(buf)
    crc0 = mem.store.split_checksum(0)
    buf[:] = 7.0
    assert np.array_equal(mem.store.splits[0], np.ones((4, 2), np.float32))
    assert mem.store.split_checksum(0) == crc0


# -- single writer -----------------------------------------------------


def test_writer_lock_exclusive(tmp_path):
    log = DurableIngestLog(str(tmp_path))
    with pytest.raises(LogLockedError):
        DurableIngestLog(str(tmp_path))
    log.close()
    DurableIngestLog(str(tmp_path)).close()     # released on close


def test_writer_lock_stale_pid_reclaimed(tmp_path):
    proc = subprocess.Popen(["sleep", "0"])
    proc.wait()                                  # a pid that is now dead
    (tmp_path / "writer.lock").write_text(f"{proc.pid}\n")
    DurableIngestLog(str(tmp_path)).close()
    (tmp_path / "writer.lock").write_text("not-a-pid\n")
    DurableIngestLog(str(tmp_path)).close()


# -- torn writes: kill at any byte ------------------------------------


def test_torn_write_recovery_at_every_byte_offset(tmp_path):
    """Truncate the tail segment at EVERY byte offset: recovery always
    truncates to the surviving prefix (bitwise equal to the in-memory
    log fed the surviving batches, exactly one short_read counted), and
    a LiveSession over the recovered log reproduces the uninterrupted
    session's reports bitwise."""
    batches = _batches()
    pristine = str(tmp_path / "pristine")
    _write_log(pristine, batches)

    mem = _mem_log(batches[:-1])                 # the surviving prefix
    want_reports = _session_reports(mem)
    tail = seg.segment_name(N_BATCHES - 1)
    size = os.path.getsize(os.path.join(pristine, tail))
    assert size == (seg.HEADER_SIZE + seg.REC_HEADER_SIZE
                    + ROWS * DIM * 4 + 4 + seg.FOOTER_SIZE)

    work = str(tmp_path / "work")
    for cut in range(size):
        shutil.rmtree(work, ignore_errors=True)
        shutil.copytree(pristine, work)
        torn_write(os.path.join(work, tail), cut)
        log = DurableIngestLog(work)
        r = log.recovery
        assert r.batches == N_BATCHES - 1, f"cut at byte {cut}: {r}"
        assert r.truncated_at == N_BATCHES - 1 and r.files_dropped == 1
        assert log.counters.short_reads == 1, f"cut at {cut}: {log.counters}"
        assert log.counters.checksum_failures == 0
        _assert_store_bitwise(log.store, mem.store)
        _assert_reports_bitwise(_session_reports(log), want_reports)
        # appending resumes at the truncation point, bitwise
        assert log.append(batches[-1]) == N_BATCHES - 1
        log.close()
        full = DurableIngestLog(work)
        _assert_store_bitwise(full.store, _mem_log(batches).store)
        full.close()


def test_bit_flip_recovery(tmp_path):
    """Random mid-file bit flips anywhere in the log: recovery truncates
    at the damaged segment with one checksum_failure counted (a flip
    never shortens the file, so it must never read as torn)."""
    batches = _batches()
    pristine = str(tmp_path / "pristine")
    _write_log(pristine, batches)
    sizes = [os.path.getsize(os.path.join(pristine, seg.segment_name(i)))
             for i in range(N_BATCHES)]

    rng = np.random.default_rng(17)
    work = str(tmp_path / "work")
    for _ in range(40):
        s = int(rng.integers(0, N_BATCHES))
        off = int(rng.integers(0, sizes[s]))
        mask = 1 << int(rng.integers(0, 8))
        shutil.rmtree(work, ignore_errors=True)
        shutil.copytree(pristine, work)
        bit_flip(os.path.join(work, seg.segment_name(s)), off, mask)
        log = DurableIngestLog(work)
        where = f"seg {s} byte {off} mask {mask:#x}"
        assert log.recovery.batches == s, where
        assert log.recovery.truncated_at == s, where
        assert log.recovery.files_dropped == N_BATCHES - s
        assert log.counters.checksum_failures == 1, where
        assert log.counters.short_reads == 0, where
        _assert_store_bitwise(log.store, _mem_log(batches[:s]).store)
        log.close()


def test_hole_in_sequence_truncates(tmp_path):
    batches = _batches()
    _write_log(str(tmp_path), batches)
    os.unlink(str(tmp_path / seg.segment_name(1)))
    log = DurableIngestLog(str(tmp_path))
    assert log.recovery.batches == 1
    assert log.recovery.truncated_at == 2       # first file dropped
    assert log.recovery.files_dropped == 2      # seqs 2, 3 unreachable
    assert "hole at seq 1" in log.recovery.reason
    _assert_store_bitwise(log.store, _mem_log(batches[:1]).store)
    log.close()


# -- ENOSPC ------------------------------------------------------------


def test_enospc_mid_append_is_loud_and_leaves_log_readable(tmp_path):
    batches = _batches()
    root = str(tmp_path)
    log = DurableIngestLog(root, fsync="never")
    log.append(batches[0])
    log.flush()
    with enospc_after(30):                      # dies mid-record
        log.append(batches[1])
        with pytest.raises(OSError):
            log.flush()
    assert log.counters.io_errors == 1
    with pytest.raises(OSError):
        log.close()                             # still loud, but releases
    # no staging debris, sealed prefix intact and readable
    assert [n for n in os.listdir(root) if n.startswith(".tmp_seg_")] == []
    log2 = DurableIngestLog(root)
    assert log2.recovery.batches == 1
    _assert_store_bitwise(log2.store, _mem_log(batches[:1]).store)
    # space freed: the producer resumes where the disk image ends
    for b in batches[1:]:
        log2.append(b)
    log2.close()
    log3 = DurableIngestLog(root)
    _assert_store_bitwise(log3.store, _mem_log(batches).store)
    log3.close()


def test_enospc_with_always_policy_raises_from_append(tmp_path):
    log = DurableIngestLog(str(tmp_path), fsync="always")
    log.append(_batches(1)[0])
    with enospc_after(0):
        with pytest.raises(OSError):
            log.append(_batches(1, seed=6)[0])
    with pytest.raises(OSError):
        log.close()
    log2 = DurableIngestLog(str(tmp_path))
    assert log2.recovery.batches == 1
    log2.close()


# -- tailing consumers -------------------------------------------------


def test_tail_same_process(tmp_path):
    """A tail-mode log sees sealed batches as the producer flushes them —
    and the session over it is bitwise equal to the in-memory one."""
    batches = _batches(6)
    root = str(tmp_path)
    prod = DurableIngestLog(root, fsync="batch", group=2)
    tail = DurableIngestLog(root, mode="tail")
    sess = LiveSession(tail, Mean(), B=B, key=KEY)
    got = []
    for b in batches:
        prod.append(b)
        prod.flush()
        got.extend(sess.poll())
    prod.close()
    assert [r.seq for r in got] == list(range(6))
    assert sess.counters.folded == 6 and sess.counters.duplicates == 0
    _assert_reports_bitwise(got, _session_reports(_mem_log(batches)))


def test_tail_mode_cannot_append(tmp_path):
    _write_log(str(tmp_path), _batches())
    tail = DurableIngestLog(str(tmp_path), mode="tail")
    with pytest.raises(RuntimeError, match="tail"):
        tail.append(_batches(1)[0])
    tail.close()                                 # no-op, no lock held


def test_tail_degrade_then_reload(tmp_path):
    """An unreadable segment under degrade policy becomes invalid rows —
    p_eff drops by exactly its extent, the session lives — and reload()
    after repair swaps the real bytes back with a FRESH checksum (the
    corrupt-then-recover round trip of the split_checksum cache fix)."""
    batches = _batches(6)
    root = str(tmp_path)
    _write_log(root, batches)
    bad = os.path.join(root, seg.segment_name(2))
    pristine_bytes = open(bad, "rb").read()
    bit_flip(bad, seg.HEADER_SIZE + seg.REC_HEADER_SIZE + 5, 0x20)

    tail = DurableIngestLog(root, mode="tail",
                            policy=FailurePolicy(on_exhausted="degrade"))
    sess = LiveSession(tail, Mean(), B=B, key=KEY,
                       policy=LagPolicy(max_lag_batches=1))
    reports = sess.poll()
    assert [r.seq for r in reports] == [0, 1, 3, 4, 5]
    assert tail.lost_seqs == {2}
    assert tail.counters.checksum_failures == 1
    assert tail.counters.splits_lost == 1
    last = reports[-1]
    assert last.counters.gap_rows == ROWS
    assert last.p_eff == pytest.approx(5 * ROWS / (6 * ROWS))
    # the placeholder split is zeros with its own (valid) checksum
    assert not np.any(tail.store.splits[2])
    crc_zero = tail.store.split_checksum(2)

    # out-of-band repair: restore the pristine file, reload the batch
    with open(bad, "wb") as f:
        f.write(pristine_bytes)
    tail.reload(2)
    assert tail.lost_seqs == set()
    assert np.array_equal(tail.store.splits[2], batches[2])
    crc_repaired = tail.store.split_checksum(2)
    assert crc_repaired != crc_zero              # stale-cache regression
    assert crc_repaired == _mem_log(batches).store.split_checksum(2)


def test_tail_raise_policy_is_loud(tmp_path):
    _write_log(str(tmp_path), _batches())
    bit_flip(str(tmp_path / seg.segment_name(1)), seg.HEADER_SIZE + 3, 0x01)
    tail = DurableIngestLog(str(tmp_path), mode="tail")
    with pytest.raises(SegmentError):
        tail.next_seq


def test_tail_degrade_unknown_extent_stalls(tmp_path):
    """Damage that destroys the record header leaves the extent unknown:
    the consumer stops at the batch (no guessing) instead of misplacing
    every later row."""
    _write_log(str(tmp_path), _batches())
    torn_write(str(tmp_path / seg.segment_name(1)), 10)   # header gone
    tail = DurableIngestLog(str(tmp_path), mode="tail",
                            policy=FailurePolicy(on_exhausted="degrade"))
    assert tail.next_seq == 1
    assert tail.counters.short_reads == 1
    assert tail.lost_seqs == set()


# -- split_checksum identity cache (store-level regression) ------------


def test_split_checksum_cache_keyed_by_identity():
    store = ShardedStore([np.ones((4, 2), np.float32)])
    crc_a = store.split_checksum(0)
    assert store.split_checksum(0) == crc_a      # cached
    store.replace_split(0, np.full((4, 2), 2.0, np.float32))
    crc_b = store.split_checksum(0)
    assert crc_b != crc_a                        # the pre-fix stale value
    import zlib
    assert crc_b == zlib.crc32(
        np.ascontiguousarray(np.full((4, 2), 2.0, np.float32)).tobytes())


def test_replace_split_preserves_geometry():
    store = ShardedStore([np.ones((4, 2), np.float32)])
    with pytest.raises(ValueError, match="shape"):
        store.replace_split(0, np.ones((5, 2), np.float32))
    with pytest.raises(ValueError, match="dtype"):
        store.replace_split(0, np.ones((4, 2), np.float64))


# -- cross-process -----------------------------------------------------

_PRODUCER = """
import sys, time
import numpy as np
from repro.live import DurableIngestLog

root, n = sys.argv[1], int(sys.argv[2])
rng = np.random.default_rng(23)
with DurableIngestLog(root, fsync="never") as log:
    for _ in range(n):
        log.append(rng.standard_normal((16, 2)).astype(np.float32))
        log.flush()                      # seal before the next sleep
        time.sleep(0.05)
print("producer done", log.next_seq)
"""

_CONSUMER = """
import sys, time
import numpy as np
import jax
from repro.core.reduce_api import Mean
from repro.live import DurableIngestLog, LiveSession

root, n, out = sys.argv[1], int(sys.argv[2]), sys.argv[3]
tail = DurableIngestLog(root, mode="tail")
sess = LiveSession(tail, Mean(), B=4, key=jax.random.PRNGKey(29))
seqs = []
deadline = time.monotonic() + 120.0
last = None
while len(seqs) < n:
    for r in sess.poll():
        seqs.append(r.seq)
        last = r
    if time.monotonic() > deadline:
        raise SystemExit(f"timed out with {len(seqs)}/{n} batches")
    time.sleep(0.01)
np.savez(out, thetas=np.asarray(last.thetas),
         estimate=np.asarray(last.estimate), seqs=np.asarray(seqs),
         folded=sess.counters.folded, duplicates=sess.counters.duplicates)
print("consumer done", seqs)
"""


def test_cross_process_producer_consumer(tmp_path):
    """A producer process appends while a consumer process tails sealed
    segments through LiveSession: the consumer folds every sealed batch
    exactly once, and its final report is bitwise equal to an in-process
    session over the same batches."""
    n = 6
    root = str(tmp_path / "log")
    out = str(tmp_path / "consumer.npz")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    procs = [
        subprocess.Popen([sys.executable, "-c", _PRODUCER, root, str(n)],
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT),
        subprocess.Popen([sys.executable, "-c", _CONSUMER, root, str(n),
                          out], env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT),
    ]
    logs = []
    for p in procs:
        stdout, _ = p.communicate(timeout=180)
        logs.append(stdout.decode())
        assert p.returncode == 0, "\n".join(logs)

    got = np.load(out)
    assert int(got["folded"]) == n
    assert int(got["duplicates"]) == 0
    assert list(got["seqs"]) == list(range(n))   # exactly once, in order

    rng = np.random.default_rng(23)              # the producer's stream
    mem = IngestLog()
    for _ in range(n):
        mem.append(rng.standard_normal((16, 2)).astype(np.float32))
    want = _session_reports_b4(mem)
    assert np.array_equal(got["thetas"], np.asarray(want[-1].thetas))
    assert np.array_equal(got["estimate"], np.asarray(want[-1].estimate))


def _session_reports_b4(log):
    sess = LiveSession(log, Mean(), B=4, key=jax.random.PRNGKey(29))
    return sess.poll()
