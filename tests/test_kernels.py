"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.poisson_counts import ops as pc_ops
from repro.kernels.poisson_counts.kernel import _poisson_from_bits
from repro.kernels.poisson_counts.ref import (expected_moments,
                                              poisson_from_bits_ref,
                                              poisson_pmf)
from repro.kernels.weighted_stats import ops as ws_ops
from repro.kernels.weighted_stats.ref import weighted_moments_ref


class TestWeightedStats:
    @pytest.mark.parametrize("B,n,d", [
        (1, 8, 1), (7, 130, 5), (32, 1000, 1), (64, 2048, 256),
        (128, 512, 128), (3, 4096, 17),
    ])
    def test_sweep_shapes(self, key, B, n, d):
        w = jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
        wt_k, s1_k, s2_k = ws_ops.weighted_moments(
            w, x, backend="pallas_interpret")
        wt_r, s1_r, s2_r = weighted_moments_ref(w, x)
        np.testing.assert_allclose(wt_k, wt_r[:, 0], rtol=1e-5)
        np.testing.assert_allclose(s1_k, s1_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s2_k, s2_r, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, key, dtype):
        w = jax.random.poisson(key, 1.0, (16, 256)).astype(dtype)
        x = (jax.random.normal(jax.random.fold_in(key, 1), (256, 8))
             .astype(dtype))
        wt_k, s1_k, s2_k = ws_ops.weighted_moments(
            w, x, backend="pallas_interpret")
        wt_r, s1_r, s2_r = weighted_moments_ref(w, x)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(s1_k, s1_r, rtol=tol, atol=tol)

    def test_1d_values(self, key):
        w = jnp.ones((4, 100))
        x = jax.random.normal(key, (100,))
        wt, s1, s2 = ws_ops.weighted_moments(w, x,
                                             backend="pallas_interpret")
        assert s1.shape == (4, 1)
        np.testing.assert_allclose(s1[:, 0], jnp.sum(x), rtol=1e-4)


class TestPoissonCounts:
    def test_deterministic(self):
        a = pc_ops.poisson_counts(42, 64, 512, backend="pallas_interpret")
        b = pc_ops.poisson_counts(42, 64, 512, backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_seed_sensitivity(self):
        a = pc_ops.poisson_counts(1, 64, 512, backend="pallas_interpret")
        b = pc_ops.poisson_counts(2, 64, 512, backend="pallas_interpret")
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_moments(self):
        c = pc_ops.poisson_counts(7, 256, 4096, backend="pallas_interpret")
        mean_e, var_e = expected_moments()
        assert abs(float(c.mean()) - mean_e) < 0.01
        assert abs(float(c.var()) - var_e) < 0.02

    def test_pmf(self):
        c = np.asarray(pc_ops.poisson_counts(11, 512, 2048,
                                             backend="pallas_interpret"))
        for k in range(4):
            frac = float((c == k).mean())
            assert abs(frac - poisson_pmf(k)) < 0.01, f"P(K={k})"

    def test_ladder_bit_exact_vs_ref(self, key):
        bits = jax.random.bits(key, (64, 128), dtype=jnp.uint32)
        np.testing.assert_array_equal(
            np.asarray(_poisson_from_bits(bits)),
            np.asarray(poisson_from_bits_ref(bits)))

    @pytest.mark.parametrize("B,n", [(5, 100), (129, 1000)])
    def test_unaligned_shapes(self, B, n):
        c = pc_ops.poisson_counts(3, B, n, backend="pallas_interpret")
        assert c.shape == (B, n)


class TestFlashAttention:
    def _mk(self, key, b, hq, hkv, sq, skv, d, dtype=jnp.float32):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
        k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
        v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
        return q, k, v

    @pytest.mark.parametrize("cfg,kwargs", [
        ((2, 4, 2, 64, 64, 32), dict(causal=True)),
        ((1, 4, 4, 128, 128, 32), dict(causal=True, window=32)),
        ((2, 8, 2, 96, 96, 16), dict(causal=False)),
        ((1, 2, 1, 64, 192, 32), dict(causal=True, kv_offset=128)),
        ((1, 8, 1, 80, 80, 64), dict(causal=True)),
    ])
    @pytest.mark.parametrize("backend", ["blockwise", "pallas_interpret"])
    def test_sweep_vs_oracle(self, key, cfg, kwargs, backend):
        q, k, v = self._mk(key, *cfg)
        ref = mha_reference(q, k, v, **kwargs)
        out = fa_ops.flash_attention(q, k, v, backend=backend,
                                     block_q=32, block_k=32, **kwargs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

    def test_windowed_backend(self, key):
        q, k, v = self._mk(key, 2, 4, 2, 128, 128, 32)
        ref = mha_reference(q, k, v, causal=True, window=48)
        out = fa_ops.flash_attention(q, k, v, backend="windowed",
                                     causal=True, window=48, block_q=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

    def test_bf16(self, key):
        q, k, v = self._mk(key, 1, 2, 2, 64, 64, 32, jnp.bfloat16)
        ref = mha_reference(q, k, v, causal=True)
        out = fa_ops.flash_attention(q, k, v, backend="pallas_interpret",
                                     causal=True, block_q=32, block_k=32)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=3e-2, rtol=3e-2)

    def test_unaligned_seq(self, key):
        q, k, v = self._mk(key, 1, 2, 1, 67, 67, 16)
        ref = mha_reference(q, k, v, causal=True)
        out = fa_ops.flash_attention(q, k, v, backend="blockwise",
                                     causal=True, block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)
