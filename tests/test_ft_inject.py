"""Deterministic fault injection + the resilient read/degrade/elastic paths.

Three layers under test:

* ``FaultyStore``/``ResilientStore`` in isolation — each injected fault
  kind (transient IOError, latency spike, short read, corrupted batch) is
  detected, counted, retried under the ``RetryPolicy`` budget, and either
  raised or degraded to a masked LOST split when the budget runs out.
* The streaming driver end to end — under injected faults within the
  retry budget the run completes WITHOUT manual intervention and its
  result is BITWISE equal to the fault-free run; observed counters in
  ``StreamReport.faults`` match what the injector says it injected; a
  split lost mid-run degrades to masked zeros and the result matches a
  hand-rolled dedicated ``valid_mask`` oracle fold bit for bit.
* The unified ``FailurePolicy``/``elastic_estimate`` reduce path — lost
  and deadline-late shards fold into one mask, matching the
  ``estimate_with_loss_mask`` oracle bitwise, and ``meets_bound`` drives
  the continue-approximate vs checkpoint-restart decision.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import DistributedEarl, Mean
from repro.core.bootstrap import seed_from_key
from repro.core.reduce_api import Quantile, Var, bind_params, split_params
from repro.core.streaming import _stream_chunk_jit, bootstrap_streaming
from repro.data import synthetic_numeric
from repro.data.store import ShardedStore
from repro.ft import (CONTINUE, RESTART, FailurePolicy, Fault,
                      FaultCounters, FaultExhaustedError, FaultyStore,
                      ResilientStore, RetryPolicy, ShardEvents,
                      elastic_estimate, failure_mask)

KEY = jax.random.PRNGKey(3)
CHUNK = 256


def _store(n=1000, d=2, split_size=137, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, d)).astype(np.float32)
    return ShardedStore.from_array(data, split_size, interleave=False)


def _tree_bitwise(a, b):
    ok = jax.tree_util.tree_map(
        lambda u, v: bool(np.array_equal(np.asarray(u), np.asarray(v))),
        a, b)
    assert all(jax.tree_util.tree_leaves(ok)), ok


# ----------------------------------------------------------------------------
# the injector
# ----------------------------------------------------------------------------
class TestFaultyStore:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            Fault(split=0, kind="gremlin")

    def test_fault_on_missing_split_rejected(self):
        with pytest.raises(ValueError, match="split"):
            FaultyStore(_store(), [Fault(split=99, kind="io")])

    def test_transient_io_clears_after_declared_attempts(self):
        fs = FaultyStore(_store(), [Fault(split=1, kind="io", attempts=2)])
        for _ in range(2):
            with pytest.raises(IOError):
                fs.read_split(1)
        np.testing.assert_array_equal(fs.read_split(1), fs.splits[1])
        assert fs.injected.io_errors == 2

    def test_permanent_fault_never_clears(self):
        fs = FaultyStore(_store(), [Fault(split=0, kind="io",
                                          permanent=True)])
        for _ in range(5):
            with pytest.raises(IOError):
                fs.read_split(0)

    def test_short_and_corrupt_are_detectable(self):
        store = _store()
        fs = FaultyStore(store, [Fault(split=0, kind="short"),
                                 Fault(split=1, kind="corrupt")])
        short = fs.read_split(0)
        assert len(short) < store.split_sizes[0]
        bad = fs.read_split(1)
        assert len(bad) == store.split_sizes[1]
        import zlib
        assert (zlib.crc32(np.ascontiguousarray(bad).tobytes())
                != fs.split_checksum(1)), \
            "corruption must not forge the pristine checksum"
        # the injector never mutates the underlying store
        np.testing.assert_array_equal(store.splits[1], fs.inner.splits[1])

    def test_seeded_plan_is_reproducible(self):
        store = _store()
        a = FaultyStore.seeded(store, seed=5, p_io=0.3, p_corrupt=0.2)
        b = FaultyStore.seeded(store, seed=5, p_io=0.3, p_corrupt=0.2)
        assert a.faults == b.faults
        c = FaultyStore.seeded(store, seed=6, p_io=0.3, p_corrupt=0.2)
        assert a.faults != c.faults       # a different seed, different plan
        assert FaultyStore.seeded(store, seed=5).faults == ()


class TestDeliveryPlan:
    """Seeded duplicate / out-of-order delivery (feeds live-ingest tests:
    the consumer must dedup and reorder back to the clean bits)."""

    def test_identity_without_faults(self):
        fs = FaultyStore(_store())
        assert fs.delivery_plan(seed=1) == list(range(len(fs.splits)))
        assert fs.injected.duplicates == 0
        assert fs.injected.reordered == 0

    def test_seeded_plan_is_reproducible(self):
        a = FaultyStore(_store()).delivery_plan(seed=4, p_duplicate=0.4,
                                                max_reorder=3)
        b = FaultyStore(_store()).delivery_plan(seed=4, p_duplicate=0.4,
                                                max_reorder=3)
        assert a == b
        c = FaultyStore(_store()).delivery_plan(seed=5, p_duplicate=0.4,
                                                max_reorder=3)
        assert a != c

    def test_every_split_delivered_at_least_once(self):
        fs = FaultyStore(_store())
        plan = fs.delivery_plan(seed=2, p_duplicate=0.5, max_reorder=4)
        assert set(plan) == set(range(len(fs.splits)))
        assert len(plan) == len(fs.splits) + fs.injected.duplicates
        assert fs.injected.duplicates > 0

    def test_reorder_displacement_is_bounded(self):
        """Without duplication, no split lands more than ``max_reorder``
        positions from its in-order slot."""
        for mr in (1, 2, 5):
            fs = FaultyStore(_store())
            plan = fs.delivery_plan(seed=3, max_reorder=mr)
            assert sorted(plan) == list(range(len(fs.splits)))
            for pos, s in enumerate(plan):
                assert abs(pos - s) <= mr, (mr, pos, s)
            assert fs.injected.reordered == sum(
                1 for pos, s in enumerate(plan) if pos != s)

    def test_duplicate_echo_arrives_after_original(self):
        fs = FaultyStore(_store())
        plan = fs.delivery_plan(seed=6, p_duplicate=0.6)
        for s in set(plan):
            first = plan.index(s)
            assert all(p > first for p in range(len(plan))
                       if plan[p] == s and p != first)

    def test_iter_delivery_yields_split_rows(self):
        store = _store()
        fs = FaultyStore(store)
        got = list(fs.iter_delivery(seed=7, p_duplicate=0.3, max_reorder=2))
        assert [s for s, _ in got] == fs.delivery_plan(
            seed=7, p_duplicate=0.3, max_reorder=2)
        for s, rows in got:
            np.testing.assert_array_equal(rows, store.splits[s])

    def test_validation(self):
        fs = FaultyStore(_store())
        with pytest.raises(ValueError, match="p_duplicate"):
            fs.delivery_plan(seed=0, p_duplicate=1.5)
        with pytest.raises(ValueError, match="max_reorder"):
            fs.delivery_plan(seed=0, max_reorder=-1)


# ----------------------------------------------------------------------------
# the resilient read path
# ----------------------------------------------------------------------------
class TestResilientStore:
    def test_transient_io_retried_to_success(self):
        fs = FaultyStore(_store(), [Fault(split=1, kind="io", attempts=2)])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=3, base_delay=0.0))
        np.testing.assert_array_equal(rs.read_split(1), fs.inner.splits[1])
        assert rs.counters.io_errors == 2
        assert rs.counters.retries == 2

    def test_corrupt_read_caught_and_retried(self):
        fs = FaultyStore(_store(), [Fault(split=2, kind="corrupt")])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=2, base_delay=0.0))
        np.testing.assert_array_equal(rs.read_split(2), fs.inner.splits[2])
        assert rs.counters.checksum_failures == 1

    def test_short_read_caught_and_retried(self):
        fs = FaultyStore(_store(), [Fault(split=0, kind="short")])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=2, base_delay=0.0))
        np.testing.assert_array_equal(rs.read_split(0), fs.inner.splits[0])
        assert rs.counters.short_reads == 1

    def test_latency_spike_counts_deadline_miss(self):
        fs = FaultyStore(_store(), [Fault(split=1, kind="latency",
                                          latency_s=0.2)])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=3, base_delay=0.0,
                                            timeout=0.05))
        np.testing.assert_array_equal(rs.read_split(1), fs.inner.splits[1])
        assert rs.counters.deadline_misses >= 1

    def test_late_data_accepted_on_final_attempt(self):
        """Every attempt is slow: slow beats lost — the final attempt's
        valid-but-late data is returned rather than discarded."""
        fs = FaultyStore(_store(), [Fault(split=1, kind="latency",
                                          latency_s=0.05, permanent=True)])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=2, base_delay=0.0,
                                            timeout=0.001))
        np.testing.assert_array_equal(rs.read_split(1), fs.inner.splits[1])
        assert rs.counters.deadline_misses == 2

    def test_backoff_delays_are_exponential(self):
        p = RetryPolicy(max_attempts=4, base_delay=0.01)
        assert [p.delay(k) for k in (1, 2, 3)] == [0.01, 0.02, 0.04]

    def test_exhausted_budget_raises(self):
        fs = FaultyStore(_store(), [Fault(split=1, kind="io",
                                          permanent=True)])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=3, base_delay=0.0))
        with pytest.raises(FaultExhaustedError, match="split 1"):
            rs.read_split(1)

    def test_exhausted_budget_degrades_to_lost_split(self):
        store = _store()
        fs = FaultyStore(store, [Fault(split=2, kind="io", permanent=True)])
        rs = ResilientStore(fs, RetryPolicy(max_attempts=2, base_delay=0.0),
                            on_exhausted="degrade")
        out = rs.read_split(2)
        assert out.shape == store.splits[2].shape
        assert not out.any()
        assert rs.lost_splits == [2]
        assert rs.counters.splits_lost == 1
        lo, hi = rs.invalid_row_ranges()[0]
        assert (lo, hi) == (int(store.offsets[2]), int(store.offsets[3]))

    def test_bad_on_exhausted_rejected(self):
        with pytest.raises(ValueError, match="on_exhausted"):
            ResilientStore(_store(), RetryPolicy(), on_exhausted="panic")


# ----------------------------------------------------------------------------
# streaming end to end under injected faults
# ----------------------------------------------------------------------------
class TestStreamingUnderFaults:
    def test_transient_faults_within_budget_bitwise_clean(self):
        store = _store()
        base = bootstrap_streaming(store, Mean(), B=16, key=KEY,
                                   chunk=CHUNK)
        fs = FaultyStore(store, [Fault(split=1, kind="io", attempts=2),
                                 Fault(split=3, kind="corrupt"),
                                 Fault(split=5, kind="short")])
        r = bootstrap_streaming(fs, Mean(), B=16, key=KEY, chunk=CHUNK,
                                retry=RetryPolicy(max_attempts=4,
                                                  base_delay=0.0))
        _tree_bitwise(base.thetas, r.thetas)
        _tree_bitwise(base.estimate, r.estimate)
        # observed == injected, surfaced in the report
        f: FaultCounters = r.stream.faults
        assert f.io_errors == fs.injected.io_errors == 2
        assert f.checksum_failures == fs.injected.checksum_failures == 1
        assert f.short_reads == fs.injected.short_reads == 1
        assert f.retries == 4
        assert r.stream.lost_splits == ()

    def test_straggler_past_deadline_completes_bitwise(self):
        store = _store()
        base = bootstrap_streaming(store, Mean(), B=16, key=KEY,
                                   chunk=CHUNK)
        fs = FaultyStore(store, [Fault(split=2, kind="latency",
                                       latency_s=0.1)])
        r = bootstrap_streaming(
            fs, Mean(), B=16, key=KEY, chunk=CHUNK,
            retry=RetryPolicy(max_attempts=2, base_delay=0.0,
                              timeout=0.02))
        _tree_bitwise(base.thetas, r.thetas)
        assert r.stream.faults.deadline_misses >= 1

    def test_exhausted_raise_propagates(self):
        fs = FaultyStore(_store(), [Fault(split=1, kind="io",
                                          permanent=True)])
        with pytest.raises(FaultExhaustedError):
            bootstrap_streaming(fs, Mean(), B=16, key=KEY, chunk=CHUNK,
                                retry=RetryPolicy(max_attempts=2,
                                                  base_delay=0.0))

    @staticmethod
    def _oracle_masked_fold(data, lost_ranges, stat, B, key, chunk):
        """Dedicated valid_mask oracle: fold the (zeroed) rows chunk by
        chunk with masks built DIRECTLY from the known lost ranges —
        independent of the ResilientStore degradation machinery."""
        data = np.array(data, copy=True)
        for lo, hi in lost_ranges:
            data[lo:hi] = 0.0
        n, d = data.shape
        spec, params = split_params(stat)
        fresh = jax.tree_util.tree_map(
            lambda a: jax.device_put(np.asarray(a)),
            (jax.vmap(lambda _: stat.init_state(d))(jnp.arange(B)),
             stat.init_state(d)))
        states, est = fresh
        base = seed_from_key(key)
        valid = 0
        for i in range(-(-n // chunk)):
            xb = data[i * chunk:(i + 1) * chunk]
            nb = len(xb)
            mask = np.zeros((chunk,), np.float32)
            mask[:nb] = 1.0
            for lo, hi in lost_ranges:
                a, b = max(lo, i * chunk) - i * chunk, \
                    min(hi, i * chunk + nb) - i * chunk
                if a < b:
                    mask[a:b] = 0.0
            if nb < chunk:
                xb = np.concatenate(
                    [xb, np.zeros((chunk - nb, d), xb.dtype)])
            valid += int(mask.sum())
            states, est = _stream_chunk_jit(
                states, est, jax.device_put(xb), jax.device_put(mask),
                base, jnp.asarray(i, jnp.int32), params, spec, B)
        p_eff = valid / n
        s = bind_params(spec, params)
        return (s.correct(jax.vmap(s.finalize)(states), p_eff),
                s.correct(s.finalize(est), p_eff))

    @pytest.mark.parametrize("stat", [
        Mean(), Var(), Quantile(0.5, lo=-4.0, hi=4.0, nbins=64),
    ], ids=lambda s: type(s).__name__)
    def test_mid_run_shard_loss_matches_valid_mask_oracle(self, stat):
        store = _store()
        fs = FaultyStore(store, [Fault(split=2, kind="io",
                                       permanent=True)])
        r = bootstrap_streaming(
            fs, stat, B=16, key=KEY, chunk=CHUNK,
            policy=FailurePolicy(retry=RetryPolicy(max_attempts=2,
                                                   base_delay=0.0),
                                 on_exhausted="degrade"))
        assert r.stream.lost_splits == (2,)
        assert r.stream.faults.splits_lost == 1
        lost = [(int(store.offsets[2]), int(store.offsets[3]))]
        assert r.stream.valid_rows == store.N - 137
        assert r.n == store.N - 137
        thetas, estimate = self._oracle_masked_fold(
            store.read_all(), lost, stat, 16, KEY, CHUNK)
        _tree_bitwise(thetas, r.thetas)
        _tree_bitwise(estimate, r.estimate)

    def test_degraded_run_is_resumable(self, tmp_path):
        """Degradation and checkpointing compose: kill a degraded run,
        resume it, and the lost split stays lost (carried in the cursor)
        with the same final bits."""
        from repro.checkpoint.manager import CheckpointManager
        store = _store()

        def faulty():
            return FaultyStore(store, [Fault(split=0, kind="io",
                                             permanent=True)])

        pol = FailurePolicy(retry=RetryPolicy(max_attempts=2,
                                              base_delay=0.0),
                            on_exhausted="degrade")
        base = bootstrap_streaming(faulty(), Mean(), B=16, key=KEY,
                                   chunk=CHUNK, policy=pol)

        from test_ft_resume import _DyingManager, _Kill
        root = str(tmp_path / "ckpt")
        with pytest.raises(_Kill):
            bootstrap_streaming(faulty(), Mean(), B=16, key=KEY,
                                chunk=CHUNK, policy=pol,
                                checkpoint=_DyingManager(root, 2))
        r = bootstrap_streaming(
            faulty(), Mean(), B=16, key=KEY, chunk=CHUNK, policy=pol,
            resume=True,
            checkpoint=CheckpointManager(root, async_save=False))
        _tree_bitwise(base.thetas, r.thetas)
        assert r.stream.lost_splits == (0,)
        assert r.n == base.n


# ----------------------------------------------------------------------------
# the unified reduce-side policy
# ----------------------------------------------------------------------------
def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


class TestElasticPolicy:
    def _earl(self, B=64):
        return DistributedEarl(_one_device_mesh(), Mean(), B=B,
                               data_axes=("data",))

    def test_lost_and_late_fold_into_one_mask_oracle_bitwise(self, key):
        data = jnp.asarray(synthetic_numeric(16_384, 10, 2, seed=6))
        earl = self._earl()
        events = ShardEvents(n_shards=8, lost=(1,),
                             completion_s=(0.1,) * 7 + (9.9,))
        er = elastic_estimate(earl, data, key, events,
                              FailurePolicy(sigma=0.05, deadline_s=1.0))
        assert er.lost == (1,) and er.late == (7,)
        assert er.report.shards_lost == 2
        # the dedicated valid_mask oracle: same mask, direct call
        mask = failure_mask(data.shape[0], 8, [1, 7])
        oracle = earl.estimate_with_loss_mask(data, mask, key,
                                              p=float(mask.mean()))
        _tree_bitwise(er.report.result, oracle.estimate)
        _tree_bitwise(er.report.ci_lo, oracle.report.ci_lo)
        _tree_bitwise(er.report.ci_hi, oracle.report.ci_hi)
        assert er.report.cv == oracle.cv

    def test_meets_bound_drives_decision(self, key):
        easy = jnp.asarray(synthetic_numeric(16_384, 10, 2, seed=7))
        er = elastic_estimate(self._earl(), easy, key,
                              ShardEvents(n_shards=8, lost=(0,)),
                              FailurePolicy(sigma=0.05))
        assert er.decision == CONTINUE and er.report.meets_bound
        hard = jnp.asarray(synthetic_numeric(4096, 10, 200, seed=8))
        er2 = elastic_estimate(self._earl(), hard, key,
                               ShardEvents(n_shards=16,
                                           lost=tuple(range(15))),
                               FailurePolicy(sigma=0.001))
        assert er2.decision == RESTART and not er2.report.meets_bound
        assert not er2.can_restart       # no CheckpointManager configured

    def test_estimate_elastic_method_matches_function(self, key, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        data = jnp.asarray(synthetic_numeric(8192, 10, 2, seed=9))
        earl = self._earl()
        events = ShardEvents(n_shards=8, lost=(3,))
        pol = FailurePolicy(sigma=0.05,
                            checkpoint=CheckpointManager(str(tmp_path)))
        a = earl.estimate_elastic(data, key, events, pol)
        b = elastic_estimate(earl, data, key, events, pol)
        _tree_bitwise(a.report.result, b.report.result)
        assert a.decision == b.decision
        assert a.can_restart and b.can_restart

    def test_late_requires_full_completion_vector(self):
        with pytest.raises(ValueError, match="completion_s"):
            ShardEvents(n_shards=8, completion_s=(0.1, 0.2)).late(1.0)
