import os
import sys

# Smoke tests and benches must see ONE device; only launch/dryrun.py (run
# as its own process) sets the 512-device flag.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not inherit the dry-run's forced device count"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
