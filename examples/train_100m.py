"""End-to-end driver: train a ~100M-parameter dense LM with the full
production stack — data pipeline, AdamW, async checkpointing with restart,
EARL-adaptive gradient accumulation, and early-accurate eval.

This is the assignment's "train ~100M model for a few hundred steps"
example.  On this CPU container a full-size step takes ~20 s, so the
default is a short run; pass --steps 300 for the full few-hundred-step
run (the code path is identical).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_driver

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=20)
ap.add_argument("--batch", type=int, default=4)
args = ap.parse_args()

# ~99M params: granite family at d=640, 12 layers, d_ff=2560, 32k vocab
OVERRIDE = ('{"n_layers": 12, "d_model": 640, "n_heads": 8, '
            '"n_kv_heads": 4, "head_dim": 80, "d_ff": 2560, '
            '"vocab": 32000, "vocab_pad_multiple": 128, '
            '"loss_chunk": 128, "attn_block_q": 64, "attn_block_k": 64, '
            '"compute_dtype": "float32"}')

train_driver.main([
    "--arch", "granite-3-2b",
    "--override", OVERRIDE,
    "--steps", str(args.steps),
    "--batch", str(args.batch),
    "--seq", "256",
    "--ckpt-dir", "/tmp/repro_100m_ckpt",
    "--ckpt-every", "10",
    "--eval-every", str(max(args.steps // 2, 10)),
    "--adaptive-accum",
    "--microbatches", "4",
])
