"""K-Means with EARL (paper §6.3): fit on an early-accurate sample and
certify centroid stability with a bootstrap CV bound, vs full-data Lloyd.

The Lloyd loops run through ``kmeans_fit`` (one jitted scan — centroids
are carried state, so iterations share a single compilation) and the
bootstrap certificate runs matrix-free (``backend="fused_rng"`` routes
``KMeansStep`` through the fused assignment kernel: no (B, n) weight
matrix, no (n, k) one-hot — peak O(B·k·d)).

Run:  PYTHONPATH=src python examples/analytics_kmeans.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KMeansStep, bootstrap, kmeans_fit
from repro.data import PreMapSampler, ShardedStore, synthetic_clusters

N, K, ITERS = 400_000, 5, 8
x_np, true_centers = synthetic_clusters(N, k=K, dim=2, seed=5)
sampler = PreMapSampler(ShardedStore.from_array(x_np, 65_536), seed=6)


def inertia(x, cents):
    d2 = ((x[:, None, :] - np.asarray(cents)[None]) ** 2).sum(-1)
    return float(d2.min(axis=1).mean())


x_full = jnp.asarray(x_np)
n = N // 50                                    # 2% uniform sample
xs = sampler.take(0, n)
init = xs[:K]

# warm: compile both Lloyd scans + the fused bootstrap once, so the timed
# walls below compare steady-state compute (the compilations are shared by
# every later call with same-shaped inputs — centroids are traced params)
jax.block_until_ready(kmeans_fit(x_full, K, ITERS, jax.random.PRNGKey(9),
                                 init=init)[0])
jax.block_until_ready(kmeans_fit(xs, K, ITERS, jax.random.PRNGKey(9),
                                 init=init)[0])
jax.block_until_ready(bootstrap(xs, KMeansStep(init), B=24,
                                key=jax.random.PRNGKey(9),
                                backend="fused_rng").thetas)

t0 = time.perf_counter()
cents_full, _ = kmeans_fit(x_full, K, ITERS, jax.random.PRNGKey(0),
                           init=init)
jax.block_until_ready(cents_full)
t_full = time.perf_counter() - t0

t0 = time.perf_counter()
cents_earl, _ = kmeans_fit(xs, K, ITERS, jax.random.PRNGKey(0), init=init)
boot = bootstrap(xs, KMeansStep(cents_earl), B=24,
                 key=jax.random.PRNGKey(0), backend="fused_rng")
jax.block_until_ready(boot.thetas)
t_earl = time.perf_counter() - t0

i_full, i_earl = inertia(x_np, cents_full), inertia(x_np, cents_earl)
print(f"full-data Lloyd : inertia={i_full:.4f}  wall={t_full:.2f}s")
print(f"EARL 2% sample  : inertia={i_earl:.4f}  wall={t_earl:.2f}s  "
      f"centroid_cv={boot.cv:.4f}  (matrix-free bootstrap)")
print(f"inertia gap     : {(i_earl - i_full) / i_full:+.3%} "
      f"(paper validates <5%)")
print(f"rows touched    : {n}/{N} ({n / N:.1%}); speedup "
      f"{t_full / t_earl:.1f}x wall, {N / n:.0f}x data")
