"""Serving example: batched prefill + token-by-token decode with the ring
KV cache, on a reduced SWA config (the long_500k-capable family).

Run:  PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill

cfg = get_config("h2o-danube-3-4b", smoke=True)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)

BATCH, PROMPT, GEN = 4, 48, 16
prompt = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab)

t0 = time.perf_counter()
logits, cache = prefill(cfg, params, prompt, cache_len=PROMPT + GEN)
print(f"prefill: {BATCH}x{PROMPT} tokens in "
      f"{time.perf_counter() - t0:.2f}s; SWA ring cache len = "
      f"{cache['groups']['0']['attn']['k'].shape[3]} (window={cfg.window})")

decode = jax.jit(lambda c, tok, pos: decode_step(cfg, params, c, tok, pos))
tokens = jnp.argmax(logits, -1)[:, None]
out = [tokens]
t0 = time.perf_counter()
for t in range(GEN):
    logits, cache = decode(cache, tokens, jnp.int32(PROMPT + t))
    tokens = jnp.argmax(logits, -1)[:, None]
    out.append(tokens)
dt = time.perf_counter() - t0
seqs = jnp.concatenate(out, axis=1)
print(f"decode: {GEN} steps x {BATCH} seqs in {dt:.2f}s "
      f"({GEN * BATCH / dt:.1f} tok/s on CPU)")
print("greedy continuations (token ids):")
for row in seqs.tolist():
    print("  ", row)
