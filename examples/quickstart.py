"""Quickstart: early accurate results for analytics (the paper's core demo).

Computes mean / median / stddev over a 2M-row sharded store with a 5%
error bound — as ONE ``StatisticGroup`` session: EARL pilots a tiny
sample, SSABE picks (B, n) for the WORST member, and all three answers
ship together after a single matrix-free pass per iteration.  The group
shares one in-kernel Poisson(1) weight stream across its members (mean
and stddev additionally share one moment accumulator), so the 3-statistic
session costs ~1× the RNG and data traffic of a 1-statistic session — and
because every member sees the SAME resamples, the three confidence
intervals are jointly consistent.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import EarlSession, Mean, Quantile, StatisticGroup, Std
from repro.data import PreMapSampler, ShardedStore, synthetic_numeric

N = 2_000_000
data = synthetic_numeric(N, mean=10.0, std=2.0, seed=0)
exact = dict(mean=float(data.mean()), median=float(np.median(data)),
             std=float(data.std()))

names = ("mean", "median", "std")
group = StatisticGroup((Mean(), Quantile(0.5, lo=0.0, hi=25.0), Std()))

store = ShardedStore.from_array(data, split_size=65_536)
session = EarlSession(PreMapSampler(store, seed=1), group, sigma=0.05,
                     backend="fused_rng")
out = session.run(jax.random.PRNGKey(0))

print(f"one shared-sample session: data_used={out.fraction:6.2%}  "
      f"rows_read={store.stats.rows_read}/{N}  B={out.B}  "
      f"iters={out.iterations}  worst_cv={out.cv:.4f}")
for name, res, report in zip(names, out.result, out.reports):
    est = float(np.ravel(res)[0])
    lo = float(np.ravel(report.ci_lo)[0])
    hi = float(np.ravel(report.ci_hi)[0])
    print(f"{name:7s} EARL={est:8.4f}  exact={exact[name]:8.4f}  "
          f"rel_err={abs(est - exact[name]) / abs(exact[name]):6.4f}  "
          f"cv={report.cv:.4f}  ci95=[{lo:7.4f}, {hi:7.4f}]")
