"""Quickstart: early accurate results for analytics (the paper's core demo).

Computes mean / median / stddev over a 2M-row sharded store with a 5%
error bound: EARL pilots a tiny sample, SSABE picks (B, n), and the answer
ships with a bootstrap confidence interval after touching ~1% of the data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import EarlSession, Mean, Quantile, Std
from repro.data import PreMapSampler, ShardedStore, synthetic_numeric

N = 2_000_000
data = synthetic_numeric(N, mean=10.0, std=2.0, seed=0)
exact = dict(mean=float(data.mean()), median=float(np.median(data)),
             std=float(data.std()))

key = jax.random.PRNGKey(0)
for name, stat in [("mean", Mean()),
                   ("median", Quantile(0.5, lo=0.0, hi=25.0)),
                   ("std", Std())]:
    store = ShardedStore.from_array(data, split_size=65_536)
    session = EarlSession(PreMapSampler(store, seed=1), stat, sigma=0.05)
    out = session.run(key)
    est = float(np.ravel(out.result)[0])
    print(f"{name:7s} EARL={est:8.4f}  exact={exact[name]:8.4f}  "
          f"rel_err={abs(est - exact[name]) / abs(exact[name]):6.4f}  "
          f"cv={out.cv:.4f}  data_used={out.fraction:6.2%}  "
          f"rows_read={store.stats.rows_read}/{N}  "
          f"B={out.B}  iters={out.iterations}")
