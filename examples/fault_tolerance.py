"""Fault tolerance via approximation (paper §3.4) + classical substrate.

1. Shard loss: kill 3 of 16 data shards mid-job; EARL re-weights the
   survivors and reports the answer WITH a bootstrap bound — no restart.
2. Straggler: one shard misses the reduce deadline; same machinery.
3. Catastrophic loss: bound exceeded -> recommendation flips to restart,
   which the checkpoint manager serves (restore + elastic remesh).

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.core import DistributedEarl, Mean
from repro.data import synthetic_numeric
from repro.ft import DeadlineReducer, estimate_with_failures, mesh_for_devices

mesh = mesh_for_devices(len(jax.devices()))
earl = DistributedEarl(mesh, Mean(), B=64, data_axes=("data",))
data = jnp.asarray(synthetic_numeric(262_144, 10.0, 2.0, seed=1))
key = jax.random.PRNGKey(0)

print("=== 1. node failure: 3/16 shards lost ===")
rep = estimate_with_failures(earl, data, lost_shards=[2, 7, 11],
                             n_shards=16, sigma=0.05, key=key)
print(f"  survivors' estimate: {float(np.ravel(rep.result)[0]):.4f} "
      f"(true {float(data.mean()):.4f}), cv={rep.cv:.4f}, "
      f"p={rep.p_surviving:.2f}")
print(f"  -> {rep.recommendation}")

print("=== 2. straggler at the reduce deadline ===")
red = DeadlineReducer(earl, n_shards=16, sigma=0.05)
times = [0.1] * 15 + [30.0]
srep = red.reduce(data, times, deadline_s=1.0, key=key)
print(f"  {srep.on_time}/16 on time; estimate "
      f"{float(np.ravel(srep.report.result)[0]):.4f} cv={srep.report.cv:.4f}")
print(f"  -> {srep.report.recommendation}")

print("=== 3. catastrophic loss -> checkpoint restart path ===")
noisy = jnp.asarray(synthetic_numeric(4096, 10.0, 200.0, seed=2))
rep = estimate_with_failures(earl, noisy, lost_shards=list(range(15)),
                             n_shards=16, sigma=0.001, key=key)
print(f"  cv={rep.cv:.4f} > sigma -> {rep.recommendation}")
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_save=False)
    state = {"params": {"w": jnp.arange(8.0)}, "step": jnp.int32(123)}
    mgr.save(123, state, extra={"note": "pre-failure snapshot"})
    restored, extra = mgr.restore(jax.eval_shape(lambda: state))
    print(f"  restored step {int(restored['step'])} "
          f"({extra['note']}) onto mesh {dict(mesh.shape)}")
