"""Crash-safe EARL end to end: kill -> resume -> mid-run shard loss.

1. Checkpointed streaming: a streamed bootstrap snapshots its carry every
   k chunks; we kill it mid-run at a chunk boundary and resume — the
   resumed result is BITWISE equal to the uninterrupted run (the chunk
   streams are position-keyed, so chunk i's resamples never depend on the
   process history).
2. Injected faults: a FaultyStore deals transient IOErrors, a corrupted
   batch (caught by the per-split checksum) and a latency spike; the
   bounded RetryPolicy absorbs all of it — the run completes hands-off
   and the StreamReport itemizes what happened.
3. Mid-run shard loss: a split dies permanently; the run degrades instead
   of dying — the lost rows feed a masked partial (never recomputed) and
   the final correct(p_eff) widens the CI honestly.
4. The FailurePolicy verdict: meets_bound drives continue-approximate vs
   checkpoint-restart, and the checkpoint manager serves the restart.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DistributedEarl, Mean
from repro.core.streaming import bootstrap_streaming
from repro.data import ShardedStore, synthetic_numeric
from repro.ft import (FailurePolicy, Fault, FaultyStore, RetryPolicy,
                      ShardEvents, elastic_estimate, mesh_for_devices)

key = jax.random.PRNGKey(0)
rng = np.random.default_rng(1)
store = ShardedStore.from_array(rng.normal(10.0, 2.0, size=(100_000, 4)),
                                split_size=4096, interleave=False)
B, chunk = 64, 8192

print("=== 1. kill mid-run, resume, bitwise-equal result ===")
reference = bootstrap_streaming(store, Mean(), B, key, chunk=chunk)

tmp = tempfile.TemporaryDirectory()
ckpt_root = f"{tmp.name}/stream"


class _Die(Exception):
    pass


class _DyingManager(CheckpointManager):
    """Simulated crash: dies right after its 6th durable snapshot."""

    def save(self, *a, **kw):
        super().save(*a, **kw)
        self.saves = getattr(self, "saves", 0) + 1
        if self.saves >= 6:
            raise _Die


try:
    bootstrap_streaming(store, Mean(), B, key, chunk=chunk,
                        checkpoint=_DyingManager(ckpt_root,
                                                 async_save=False),
                        checkpoint_every=1)
except _Die:
    print("  run killed after checkpoint 6 (chunks 0-5 durable)")

resumed = bootstrap_streaming(store, Mean(), B, key, chunk=chunk,
                              resume=True,
                              checkpoint=CheckpointManager(ckpt_root))
bitwise = bool(np.array_equal(np.asarray(reference.thetas),
                              np.asarray(resumed.thetas)))
print(f"  resumed from chunk {resumed.stream.resumed_from_chunk}, "
      f"estimate {float(np.ravel(resumed.estimate)[0]):.4f}, "
      f"bitwise equal to uninterrupted run: {bitwise}")

print("=== 2. transient faults absorbed by bounded retry ===")
flaky = FaultyStore(store, [
    Fault(split=1, kind="io", attempts=2),        # two IOErrors, then fine
    Fault(split=4, kind="corrupt", attempts=1),   # checksum catches it
    Fault(split=7, kind="latency", attempts=1, latency_s=0.2),
])
r = bootstrap_streaming(flaky, Mean(), B, key, chunk=chunk,
                        retry=RetryPolicy(max_attempts=4, base_delay=0.01,
                                          timeout=0.05))
f = r.stream.faults
print(f"  completed hands-off: io_errors={f.io_errors} "
      f"checksum_failures={f.checksum_failures} "
      f"deadline_misses={f.deadline_misses} retries={f.retries}")
bitwise = bool(np.array_equal(np.asarray(reference.thetas),
                              np.asarray(r.thetas)))
print(f"  result bitwise equal to the fault-free run: {bitwise}")

print("=== 3. permanent shard loss -> degrade, CI widens via p_eff ===")
dead = FaultyStore(store, [Fault(split=3, kind="io", permanent=True)])
r = bootstrap_streaming(
    dead, Mean(), B, key, chunk=chunk,
    policy=FailurePolicy(retry=RetryPolicy(max_attempts=3, base_delay=0.0),
                         on_exhausted="degrade"))
print(f"  lost splits {r.stream.lost_splits}: "
      f"{r.stream.valid_rows}/{store.N} rows survive")
print(f"  estimate {float(np.ravel(r.estimate)[0]):.4f} "
      f"ci=[{float(np.ravel(r.report.ci_lo)[0]):.4f}, "
      f"{float(np.ravel(r.report.ci_hi)[0]):.4f}] cv={r.report.cv:.4f}")

print("=== 4. FailurePolicy verdict: continue vs checkpoint-restart ===")
mesh = mesh_for_devices(len(jax.devices()))
earl = DistributedEarl(mesh, Mean(), B=64, data_axes=("data",))
data = jnp.asarray(synthetic_numeric(262_144, 10.0, 2.0, seed=1))
policy = FailurePolicy(sigma=0.05, deadline_s=1.0,
                       checkpoint=CheckpointManager(f"{tmp.name}/mesh"))
events = ShardEvents(n_shards=16, lost=(2, 11),
                     completion_s=[0.1] * 15 + [30.0])   # one straggler
rep = elastic_estimate(earl, data, key, events, policy)
print(f"  lost={rep.lost} late={rep.late} -> {rep.decision} "
      f"(cv={rep.report.cv:.4f} <= sigma)")

noisy = jnp.asarray(synthetic_numeric(4096, 10.0, 200.0, seed=2))
rep = elastic_estimate(earl, noisy, key,
                       ShardEvents(n_shards=16, lost=tuple(range(15))),
                       FailurePolicy(sigma=0.001,
                                     checkpoint=policy.checkpoint))
print(f"  catastrophic loss: cv={rep.report.cv:.4f} > sigma -> "
      f"{rep.decision} (can_restart={rep.can_restart})")
tmp.cleanup()
