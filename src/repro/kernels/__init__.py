"""Pallas TPU kernels for EARL's compute hot spots.

The paper's §4 optimizes the resampling loop — on TPU that loop is a dense
(B, n) weight matrix contracted against the sample (DESIGN.md §2), so the
hot spots are:

  weighted_stats/   fused (w_tot, Σw·x, Σw·x²) for all B resamples in one
                    MXU pass over VMEM tiles; ``fused_poisson_moments`` is
                    the matrix-free bootstrap hot path — Poisson(1) weights
                    are generated *inside* the contraction from a
                    counter-based PRNG, so the (B, n) weight matrix never
                    exists anywhere (peak live memory O(B·d)).
  poisson_counts/   in-kernel PRNG → Poisson(1) bootstrap weights (no HBM
                    round-trip for the (B, n) weight matrix); also the
                    tile/seeding machinery the fused path reuses and the
                    materialization oracle for its tests.
  kmeans_assign/    fused k-means assignment+accumulate for KMeansStep:
                    distances, argmin and the weighted (sums, counts,
                    inertia) per x tile with the centroid block resident in
                    VMEM — neither the (n, k) distance matrix nor the one-
                    hot ever exists in HBM; ``fused_poisson_kmeans`` adds
                    the in-kernel Poisson(1) weight generation (same tile
                    discipline as weighted_stats), the matrix-free
                    bootstrap-over-k-means hot path (peak O(B·k·d)).
  weighted_hist/    fused weighted-histogram sketch for Quantile/Median:
                    per-tile one-hot in VMEM + MXU bin accumulate, so the
                    (n, d, nbins) one-hot tensor never materializes.
                    Histograms are mergeable synopses (psum across shards).
  flash_attention/  blockwise causal/sliding-window attention used by the
                    serving/eval path of the model zoo (keeps the early-
                    accurate eval statistic's forward pass roofline-bound).

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper w/ padding + platform dispatch), ref.py (pure-jnp oracle).
Kernels are validated on CPU with interpret=True against ref.py.
"""
