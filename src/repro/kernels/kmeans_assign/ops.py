"""jit'd public wrappers for kmeans_assign: padding + platform dispatch.

On TPU the Pallas kernels run compiled; everywhere else they run in
interpret mode (tests) or through the jnp scan lowerings — the same tile
decomposition, assignment math (`kernel._assign_tile`) and k-sequential f32
accumulation expressed as a ``lax.scan``, so XLA:CPU runs the identical
algorithm at full speed with the identical per-tile working set.

Two entry points:

* ``kmeans_assign``        — one weighted Lloyd assignment pass to a single
  (sums, counts, inertia) state; the (n, k) distance/one-hot matrices only
  ever exist one (block_n, k) tile at a time.
* ``fused_poisson_kmeans`` — matrix-free bootstrap-over-k-means: B
  per-resample states under implicit Poisson(1) weights generated inside
  the pass from the counter-based PRNG (same (seed, b-tile, n-tile)
  discipline as weighted_stats.fused_poisson_moments, so the implicit
  matrix equals ``implicit_weights(seed, B, n)``); neither the (B, n)
  weight matrix nor any (n, k) intermediate materializes — peak live state
  is O(B·k·d) plus one (B, block_n) weight tile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kernel import (_assign_tile,
                                                fused_poisson_kmeans_kernel,
                                                kmeans_assign_kernel)
from repro.kernels.weighted_stats.ops import (_pad_to, implicit_weight_tile,
                                              weight_tile_blocks)


# ============================================================================
# single-state weighted assignment pass
# ============================================================================
@functools.partial(jax.jit, static_argnames=("block_n",))
def _assign_scan(xp: jax.Array, wp: jax.Array, cent: jax.Array,
                 block_n: int):
    """CPU lowering of the single-state kernel: scan over n-tiles with the
    shared `_assign_tile` math; peak live intermediate is (block_n, k)."""
    n, d = xp.shape
    k = cent.shape[0]
    nt = n // block_n
    xc = xp.reshape(nt, block_n, d)
    wc = wp.reshape(nt, block_n)

    def body(carry, inp):
        sums, counts, inertia = carry
        x, w = inp
        assign, min_d2 = _assign_tile(x, cent, k)
        wx = x * w[:, None]
        return (sums + jax.lax.dot_general(
                    assign, wx, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32),
                counts + assign.T @ w,
                inertia + jnp.sum(w * min_d2)), None

    init = (jnp.zeros((k, d), jnp.float32), jnp.zeros((k,), jnp.float32),
            jnp.zeros((), jnp.float32))
    (sums, counts, inertia), _ = jax.lax.scan(body, init, (xc, wc))
    return sums, counts, inertia


def kmeans_assign(values: jax.Array, weights: Optional[jax.Array],
                  centroids: jax.Array, backend: str | None = None,
                  block_n: int = 512):
    """values (n, d) × centroids (k, d) [× weights (n,)] ->
    (sums (k, d), counts (k,), inertia ()).

    backend: None = auto (pallas on TPU, scan elsewhere), "pallas",
    "pallas_interpret", "scan", "jnp" (materialized (n, k) oracle).
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "scan"

    if backend == "jnp":
        from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
        return kmeans_assign_ref(values, weights, centroids)

    bn = weight_tile_blocks(8, n, 8, block_n)[1]   # shared n-tile clamp
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    wp = _pad_to(weights.astype(jnp.float32), bn, 0)   # zero weight = no-op

    if backend == "scan":
        return _assign_scan(xp, wp, jnp.asarray(centroids, jnp.float32), bn)

    k = centroids.shape[0]
    cp = _pad_to(_pad_to(jnp.asarray(centroids, jnp.float32), 8, 0), 128, 1)
    xpp = _pad_to(xp, 128, 1)
    sums, counts, inertia = kmeans_assign_kernel(
        xpp, wp[:, None], cp, k_valid=k, block_n=bn,
        interpret=(backend != "pallas"))
    return sums[:k, :d], counts[:k, 0], inertia[0, 0]


# ============================================================================
# matrix-free bootstrap path
# ============================================================================
@functools.partial(jax.jit, static_argnames=("B", "block_b", "block_n"))
def _fused_kmeans_scan(seed, n_valid, xp, cent, B, block_b, block_n,
                       maskp=None):
    """CPU lowering of the fused kernel: weights come from the SHARED
    ``weighted_stats.ops.implicit_weight_tile`` (same per-tile threefry
    bits and CDF ladder as every fused path), assignment from the shared
    ``_assign_tile`` — peak live state per step is the (B, block_n) weight
    tile plus the (block_n, k·d) per-cluster moment tile."""
    n, d = xp.shape
    k = cent.shape[0]
    nb_n = n // block_n
    xc = xp.reshape(nb_n, block_n, d)
    maskc = None if maskp is None else maskp.reshape(nb_n, block_n)

    def body(carry, t):
        sums, counts, inertia = carry
        w = implicit_weight_tile(seed, n_valid, t, B,
                                 block_b, block_n,
                                 valid=None if maskc is None
                                 else maskc[t])          # (B, bn)
        xt = xc[t]
        assign, min_d2 = _assign_tile(xt, cent, k)       # (bn, k)
        # cluster-masked moments as ONE (B, bn) @ (bn, k·d) contraction
        y = (assign[:, :, None] * xt[:, None, :]).reshape(block_n, k * d)
        return (sums + (w @ y).reshape(B, k, d),
                counts + w @ assign,
                inertia + w @ min_d2), None

    init = (jnp.zeros((B, k, d), jnp.float32),
            jnp.zeros((B, k), jnp.float32),
            jnp.zeros((B,), jnp.float32))
    (sums, counts, inertia), _ = jax.lax.scan(
        body, init, jnp.arange(nb_n, dtype=jnp.int32))
    return sums, counts, inertia


@functools.partial(jax.jit, static_argnames=("B", "num_groups", "block_b",
                                             "block_n"))
def _grouped_fused_kmeans_scan(seed, n_valid, xp, gp, cent, B, block_b,
                               block_n, num_groups, maskp=None):
    """GROUP BY k-means lowering: the assignment tile (key-independent —
    every key shares the centroids) is computed ONCE per n-tile, the
    implicit weight tile is drawn ONCE, and each key's (sums, counts,
    inertia) slot accumulates the SAME contractions as
    ``_fused_kmeans_scan`` under ``w * (gid == g)`` — so slot g is bitwise
    the ungrouped scan under ``maskp = (gid == g)`` (exact 0/1 mask
    composition)."""
    n, d = xp.shape
    k = cent.shape[0]
    nb_n = n // block_n
    xc = xp.reshape(nb_n, block_n, d)
    gc = gp.reshape(nb_n, block_n)
    maskc = None if maskp is None else maskp.reshape(nb_n, block_n)

    def body(carry, t):
        sums, counts, inertia = carry
        w = implicit_weight_tile(seed, n_valid, t, B,
                                 block_b, block_n,
                                 valid=None if maskc is None
                                 else maskc[t])          # (B, bn)
        xt = xc[t]
        gid = gc[t]
        assign, min_d2 = _assign_tile(xt, cent, k)       # (bn, k)
        y = (assign[:, :, None] * xt[:, None, :]).reshape(block_n, k * d)
        s_new, c_new, i_new = [], [], []
        for g in range(num_groups):
            wg = w * (gid == g).astype(jnp.float32)[None, :]
            s_new.append(sums[:, g] + (wg @ y).reshape(B, k, d))
            c_new.append(counts[:, g] + wg @ assign)
            i_new.append(inertia[:, g] + wg @ min_d2)
        return (jnp.stack(s_new, axis=1), jnp.stack(c_new, axis=1),
                jnp.stack(i_new, axis=1)), None

    init = (jnp.zeros((B, num_groups, k, d), jnp.float32),
            jnp.zeros((B, num_groups, k), jnp.float32),
            jnp.zeros((B, num_groups), jnp.float32))
    (sums, counts, inertia), _ = jax.lax.scan(
        body, init, jnp.arange(nb_n, dtype=jnp.int32))
    return sums, counts, inertia


def fused_poisson_kmeans(seed, values: jax.Array, centroids: jax.Array,
                         B: int, backend: str | None = None,
                         block_b: int = 128, block_n: int = 512,
                         n_valid=None, valid_mask=None,
                         group_ids=None,
                         num_groups: int | None = None
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Matrix-free bootstrap-over-k-means from an int32 seed.

    values (n, d) or (n,) × centroids (k, d) ->
    (sums (B, k, d), counts (B, k), inertia (B,)) where the implicit
    weights are Poisson(1), keyed per (block_b, block_n) tile by
    (seed, b-tile, n-tile) — the same matrix as
    ``weighted_stats.ops.implicit_weights(seed, B, n)``.

    ``n_valid`` (traced scalar, default n) masks weight columns >= n_valid
    to zero, so pre-padded callers (the chunked bootstrap's ragged tail)
    contribute nothing for padding rows.  ``valid_mask`` (traced (n,) f32
    of exact 0.0/1.0) multiplies the weight tiles — arbitrary interior
    validity holes; a prefix-shaped mask reproduces the ``n_valid`` result
    bit for bit (see ``implicit_weight_tile``).

    ``group_ids`` (traced (n,) integer keys 0..num_groups-1) switches on
    the GROUP BY path: every key shares the centroid assignment (computed
    once per tile) and the SAME implicit weight stream, segment-reduced
    into per-key states — outputs gain a G axis ((B, G, k, d), (B, G, k),
    (B, G)) and slot g is BITWISE the ungrouped call under
    ``valid_mask = (group_ids == g)``.  Scan-lowered only (the grouped
    Pallas kernel would keep G·k·d accumulators VMEM-resident; see ROADMAP
    Known modeling limits) — auto resolves to "scan", explicit Pallas
    backends raise.

    backend: None = auto (pallas on TPU, scan elsewhere), "pallas",
    "pallas_interpret", "scan".
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    k = centroids.shape[0]
    if backend is None:
        backend = ("scan" if group_ids is not None
                   else "pallas" if jax.default_backend() == "tpu"
                   else "scan")
    if group_ids is not None and backend != "scan":
        raise ValueError(
            "fused_poisson_kmeans(group_ids=...) is scan-only: the grouped "
            "kernel's G·k·d accumulators do not fit the Pallas VMEM "
            f"residency model (use backend='scan', got backend={backend!r})")
    if n_valid is None:
        n_valid = n

    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    seed = jnp.asarray(seed, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    cent = jnp.asarray(centroids, jnp.float32)
    mp = None
    if valid_mask is not None:
        mp = _pad_to(jnp.asarray(valid_mask, jnp.float32).reshape(n), bn, 0)

    if group_ids is not None:
        if num_groups is None or int(num_groups) < 1:
            raise ValueError("group_ids requires num_groups >= 1, got "
                             f"{num_groups!r}")
        # padding columns keep key 0 — zero weight via n_valid/valid_mask.
        gp = _pad_to(jnp.asarray(group_ids, jnp.float32).reshape(n), bn, 0)
        sums, counts, inertia = _grouped_fused_kmeans_scan(
            seed, n_valid, xp, gp, cent, Bp, bb, bn, int(num_groups),
            maskp=mp)
        return sums[:B], counts[:B], inertia[:B]

    if backend == "scan":
        sums, counts, inertia = _fused_kmeans_scan(seed, n_valid, xp, cent,
                                                   Bp, bb, bn, maskp=mp)
        return sums[:B], counts[:B], inertia[:B]

    cp = _pad_to(_pad_to(cent, 8, 0), 128, 1)
    kp, dp = cp.shape
    xpp = _pad_to(xp, 128, 1)
    sums, counts, inertia = fused_poisson_kmeans_kernel(
        seed, n_valid, xpp, cp, Bp, k_valid=k,
        block_b=bb, block_n=bn,
        interpret=(backend != "pallas"),
        use_tpu_prng=(backend == "pallas"),
        mask=None if mp is None else mp[None, :])
    sums = sums.reshape(Bp, kp, dp)
    return sums[:B, :k, :d], counts[:B, :k], inertia[:B, 0]
