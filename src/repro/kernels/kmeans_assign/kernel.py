"""Fused k-means assignment+accumulate Pallas kernels.

``kmeans_assign_kernel`` performs one weighted Lloyd assignment pass: each
(bn, d) tile of x is read into VMEM once, distances to the resident (k, d)
centroid block are computed on the MXU, the argmin/one-hot assignment lives
only tile-locally, and the weighted per-cluster (sums, counts, inertia) are
accumulated in place — so neither the (n, k) distance matrix nor the (n, k)
one-hot ever exists in HBM.

``fused_poisson_kmeans_kernel`` is the matrix-free bootstrap-over-k-means
hot path: the Poisson(1) resample weight tile is generated *inside* the
kernel from the same counter-based PRNG tile discipline as
kernels/weighted_stats.fused_poisson_moments (keyed by (seed, b-tile,
n-tile), so the implicit weight matrix is bit-identical to
``poisson_counts(seed, B, n)`` under matching blocks) and contracted against
the tile-local assignment — the (B, n) weight matrix never exists either,
and peak live state is the O(B·k·d) per-resample accumulators.

Grids: ``(n/bn,)`` for the single-state pass; ``(B/bB, n/bn)`` for the
fused bootstrap pass with the contraction axis n LAST so output tiles are
revisited sequentially and accumulated in place (same discipline as
weighted_stats).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.weighted_stats.kernel import _poisson_tile

_F32_MAX = float(jnp.finfo(jnp.float32).max)


def _assign_tile(x: jax.Array, cent: jax.Array, k_valid: int):
    """Tile-local assignment: x (bn, d) against cent (k, d).

    Returns (one-hot A (bn, k) f32, min-d² (bn,) f32).  d² is clamped at 0
    (f32 cancellation in the expanded form can go slightly negative for
    points at/near a centroid); centroid rows >= ``k_valid`` (sublane
    padding) are masked to +inf so they never win the argmin.

    Shared verbatim by the Pallas kernels and the jnp scan lowering so the
    two lowerings accumulate identical tile values in identical order.
    """
    x = x.astype(jnp.float32)
    cent = cent.astype(jnp.float32)
    xx = jnp.sum(x * x, -1, keepdims=True)                   # (bn, 1)
    cc = jnp.sum(cent * cent, -1)                            # (k,)
    xc = jax.lax.dot_general(x, cent, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx - 2.0 * xc + cc[None, :], 0.0)       # (bn, k)
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    if k_valid < cent.shape[0]:
        d2 = jnp.where(col < k_valid, d2, _F32_MAX)
    a = jnp.argmin(d2, -1)
    assign = (col == a[:, None]).astype(jnp.float32)         # (bn, k)
    return assign, jnp.min(d2, -1)


# ============================================================================
# single-state weighted assignment pass
# ============================================================================
def _ka_kernel(x_ref, w_ref, c_ref, sums_ref, counts_ref, inertia_ref, *,
               k_valid: int):
    t = pl.program_id(0)        # n-tile index (contraction)

    x = x_ref[...].astype(jnp.float32)       # (bn, dp)
    w = w_ref[...].astype(jnp.float32)       # (bn, 1); padded rows are 0
    assign, min_d2 = _assign_tile(x, c_ref[...], k_valid)

    @pl.when(t == 0)
    def _init():
        sums_ref[...] = jnp.zeros(sums_ref.shape, sums_ref.dtype)
        counts_ref[...] = jnp.zeros(counts_ref.shape, counts_ref.dtype)
        inertia_ref[...] = jnp.zeros(inertia_ref.shape, inertia_ref.dtype)

    wx = x * w                                               # (bn, dp)
    sums_ref[...] += jax.lax.dot_general(
        assign, wx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (kp, dp)
    counts_ref[...] += jax.lax.dot_general(
        assign, w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (kp, 1)
    inertia_ref[...] += jnp.sum(w[:, 0] * min_d2)


@functools.partial(jax.jit,
                   static_argnames=("k_valid", "block_n", "interpret"))
def kmeans_assign_kernel(values: jax.Array, weights: jax.Array,
                         centroids: jax.Array, k_valid: int,
                         block_n: int = 512, interpret: bool = True):
    """Raw kernel entry: shapes must already be padded to block multiples.

    values (n, dp) f32; weights (n, 1) f32 (padded rows zeroed); centroids
    (kp, dp) f32 with real rows < ``k_valid``.  Returns
    (sums (kp, dp), counts (kp, 1), inertia (1, 1)) — all f32.
    """
    n, dp = values.shape
    kp = centroids.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert centroids.shape[1] == dp, (centroids.shape, values.shape)

    kern = functools.partial(_ka_kernel, k_valid=k_valid)
    return pl.pallas_call(
        kern,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dp), lambda t: (t, 0)),
            pl.BlockSpec((block_n, 1), lambda t: (t, 0)),
            pl.BlockSpec((kp, dp), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((kp, dp), lambda t: (0, 0)),
            pl.BlockSpec((kp, 1), lambda t: (0, 0)),
            pl.BlockSpec((1, 1), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values, weights, centroids)


# ============================================================================
# matrix-free bootstrap path: in-kernel weight generation + assignment
# ============================================================================
def _fpk_kernel(scal_ref, x_ref, c_ref, *refs,
                k_valid: int, block_b: int, block_n: int, dp: int,
                use_tpu_prng: bool, has_mask: bool = False):
    if has_mask:
        m_ref, (sums_ref, counts_ref, inertia_ref) = refs[0], refs[1:]
    else:
        m_ref, (sums_ref, counts_ref, inertia_ref) = None, refs
    i = pl.program_id(0)        # B-tile index
    t = pl.program_id(1)        # n-tile index (contraction)

    w = _poisson_tile(scal_ref[0], i, t, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])  # (bB, bn)
    x = x_ref[...].astype(jnp.float32)                       # (bn, dp)
    assign, min_d2 = _assign_tile(x, c_ref[...], k_valid)    # (bn, kp)

    @pl.when(t == 0)
    def _init():
        sums_ref[...] = jnp.zeros(sums_ref.shape, sums_ref.dtype)
        counts_ref[...] = jnp.zeros(counts_ref.shape, counts_ref.dtype)
        inertia_ref[...] = jnp.zeros(inertia_ref.shape, inertia_ref.dtype)

    counts_ref[...] += jax.lax.dot(w, assign,
                                   preferred_element_type=jnp.float32)
    inertia_ref[...] += jax.lax.dot(w, min_d2[:, None],
                                    preferred_element_type=jnp.float32)
    # per-cluster masked moment: sums[:, j·dp:(j+1)·dp] is cluster j's (B, d)
    # weighted point sum — kp lane-aligned dots instead of a (bn, kp·dp)
    # VMEM blowup (k is small; the (B, n) weight tile is reused for all kp).
    for j in range(assign.shape[1]):
        sums_ref[:, j * dp:(j + 1) * dp] += jax.lax.dot(
            w, assign[:, j:j + 1] * x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("B", "k_valid", "block_b", "block_n",
                                    "interpret", "use_tpu_prng"))
def fused_poisson_kmeans_kernel(seed: jax.Array, n_valid: jax.Array,
                                values: jax.Array, centroids: jax.Array,
                                B: int, k_valid: int,
                                block_b: int = 128, block_n: int = 512,
                                interpret: bool = True,
                                use_tpu_prng: bool = False,
                                mask=None):
    """Matrix-free bootstrap-over-k-means: B per-resample (sums, counts,
    inertia) states under implicit in-kernel Poisson(1) weights.

    values (n, dp) f32 pre-padded (ops.py handles it); ``n_valid`` masks
    weight columns >= the unpadded row count (padded x rows are zero, so the
    assignment of masked rows contributes nothing once their weight is 0).
    Returns (sums (B, kp·dp), counts (B, kp), inertia (B, 1)) — all f32;
    ``B`` must be a ``block_b`` multiple.
    """
    n, dp = values.shape
    kp = centroids.shape[0]
    assert B % block_b == 0 and n % block_n == 0, ((B, n), (block_b, block_n))
    assert centroids.shape[1] == dp, (centroids.shape, values.shape)

    kern = functools.partial(_fpk_kernel, k_valid=k_valid, block_b=block_b,
                             block_n=block_n, dp=dp,
                             use_tpu_prng=use_tpu_prng,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    grid = (B // block_b, n // block_n)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((block_n, dp), lambda i, t: (t, 0)),
        pl.BlockSpec((kp, dp), lambda i, t: (0, 0)),
    ]
    operands = [scal, values, centroids]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, t: (0, t)))
        operands.append(mask)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, kp * dp), lambda i, t: (i, 0)),
            pl.BlockSpec((block_b, kp), lambda i, t: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, kp * dp), jnp.float32),
            jax.ShapeDtypeStruct((B, kp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
