"""Pure-jnp oracle for the kmeans_assign kernel.

Materializes exactly what the kernel avoids: the (n, k) distance matrix and
the (n, k) one-hot assignment.  Kept as the correctness oracle (and as the
memory-hog baseline the shape-capture tests flag).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(values: jax.Array, weights: jax.Array,
                      centroids: jax.Array):
    """One weighted Lloyd assignment pass, materialized.

    values (n, d), weights (n,), centroids (k, d) ->
    (sums (k, d), counts (k,), inertia ()).

    d² uses the expanded form ‖x‖² − 2x·c + ‖c‖², clamped at 0: f32
    cancellation can push it slightly negative for points at/near a
    centroid, which would leak a negative inertia.
    """
    x = jnp.asarray(values, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    c = jnp.asarray(centroids, jnp.float32)
    d2 = (jnp.sum(x * x, -1, keepdims=True)
          - 2.0 * x @ c.T
          + jnp.sum(c * c, -1))                              # (n, k)
    d2 = jnp.maximum(d2, 0.0)
    assign = jax.nn.one_hot(jnp.argmin(d2, -1), c.shape[0],
                            dtype=jnp.float32)               # (n, k)
    wa = assign * w[:, None]
    return (wa.T @ x,
            jnp.sum(wa, 0),
            jnp.sum(w * jnp.min(d2, -1)))
