"""Oracles for poisson_counts.

Two kinds of reference:
  * ``poisson_from_bits_ref`` — bit-exact oracle for the CDF-inversion
    ladder given the same uniform bits (tests feed both the kernel path
    and this oracle the identical bit tiles).
  * ``poisson_weights_ref``   — distribution oracle (jax.random.poisson);
    kernel output is compared statistically (mean≈1, var≈1, P(K=k)).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def poisson_from_bits_ref(bits: jax.Array) -> jax.Array:
    """Identical ladder to kernel.py, in plain jnp."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    acc = 0.0
    counts = jnp.zeros(bits.shape, jnp.float32)
    for k in range(10):
        acc += math.exp(-1.0) / math.factorial(k)
        counts += (u > jnp.float32(acc)).astype(jnp.float32)
    return counts


def poisson_weights_ref(key: jax.Array, B: int, n: int) -> jax.Array:
    return jax.random.poisson(key, 1.0, (B, n)).astype(jnp.float32)


def poisson_pmf(k: int) -> float:
    return math.exp(-1.0) / math.factorial(k)


def expected_moments() -> tuple[float, float]:
    """Poisson(1): mean 1, var 1 (truncation at 9 shifts both by <2e-7)."""
    mean = sum(k * poisson_pmf(k) for k in range(10))
    ex2 = sum(k * k * poisson_pmf(k) for k in range(10))
    return mean, ex2 - mean * mean
