"""jit'd public wrapper for poisson_counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.poisson_counts.kernel import poisson_counts_kernel
from repro.kernels.poisson_counts.ref import poisson_weights_ref
from repro.kernels.weighted_stats.ops import weight_tile_blocks


def poisson_counts(seed, B: int, n: int, backend: str | None = None,
                   block_b: int = 128, block_n: int = 512) -> jax.Array:
    """(B, n) Poisson(1) bootstrap weights.

    backend: None = auto (pallas+TPU hardware PRNG on TPU, jnp elsewhere),
    "pallas", "pallas_interpret", "jnp".
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"

    if backend == "jnp":
        key = jax.random.PRNGKey(jnp.asarray(seed, jnp.int32))
        return poisson_weights_ref(key, B, n)

    interpret = backend != "pallas"
    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    np_ = n + (-n) % bn
    out = poisson_counts_kernel(jnp.asarray(seed, jnp.int32), Bp, np_,
                                block_b=bb, block_n=bn,
                                interpret=interpret,
                                use_tpu_prng=not interpret)
    return out[:B, :n]
