"""In-kernel PRNG → Poisson(1) bootstrap-weight Pallas kernel.

The (B, n) Poisson weight matrix of the distributed bootstrap never has to
round-trip through HBM: each VMEM tile seeds the TPU PRNG with
(seed, tile_i, tile_j), draws uniform bits, and converts them to Poisson(1)
counts by CDF inversion (P(K > 9) < 1.1e-7, so a 10-term ladder is exact to
float32 resolution).  Paired with weighted_stats this makes resampling a
pure compute kernel — generate weights in VMEM, contract, discard.

Seeding is per-tile: (seed, tile_i, tile_j) fully determines a tile, so a
fixed (seed, block config) reproduces the same matrix call-to-call, and
different shards/steps decorrelate by folding their id into ``seed``
before the call (as core/distributed.py does at the jax.random level).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Poisson(1) CDF ladder: counts = #{thresholds < u}.
_CDF = []
_acc = 0.0
for _k in range(10):
    _acc += math.exp(-1.0) / math.factorial(_k)
    _CDF.append(_acc)


def _threefry_bits(seed, i, j, shape):
    """Tile-local counter-based bits for interpret/CPU fallback semantics."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), i), j)
    return jax.random.bits(key, shape, dtype=jnp.uint32)


def _poisson_from_bits(bits: jax.Array) -> jax.Array:
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    counts = jnp.zeros(bits.shape, jnp.float32)
    for c in _CDF:
        counts += (u > jnp.float32(c)).astype(jnp.float32)
    return counts


def _pc_kernel(seed_ref, out_ref, *, use_tpu_prng: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    if use_tpu_prng:
        pltpu.prng_seed(seed_ref[0], i, j)
        bits = pltpu.prng_random_bits(out_ref.shape)
        bits = pltpu.bitcast(bits, jnp.uint32)
    else:
        bits = _threefry_bits(seed_ref[0], i, j, out_ref.shape)
    out_ref[...] = _poisson_from_bits(bits)


@functools.partial(jax.jit,
                   static_argnames=("B", "n", "block_b", "block_n",
                                    "interpret", "use_tpu_prng"))
def poisson_counts_kernel(seed: jax.Array, B: int, n: int,
                          block_b: int = 128, block_n: int = 512,
                          interpret: bool = True,
                          use_tpu_prng: bool = False) -> jax.Array:
    """(B, n) Poisson(1) weights from a scalar int32 seed.

    Shapes must be pre-padded to block multiples (ops.py handles this).
    """
    assert B % block_b == 0 and n % block_n == 0
    grid = (B // block_b, n // block_n)
    kern = functools.partial(_pc_kernel, use_tpu_prng=use_tpu_prng)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.float32),
        interpret=interpret,
    )(seed.reshape((1,)).astype(jnp.int32))
