"""Fused multi-statistic Pallas kernel (the StatisticGroup hot path).

One kernel pass = one shared implicit Poisson(1) weight tile per
(b, n)-block feeding EVERY slot accumulator of a ``StatisticGroup``: the
moment dot-accumulators of kernels/weighted_stats and the histogram
one-hot contractions of kernels/weighted_hist, fused behind a single
``_poisson_tile`` draw and a single VMEM-resident x tile — k statistics
cost ~1× the PRNG work and x traffic of one, and every member sees the
SAME resamples (joint CIs from common random numbers).

Slot layout is static (``kinds``): at most one ``"moments"`` slot
(Mean/Var/Std/… share one accumulator by construction — see
``Statistic.accumulator_key``) and any number of ``"hist"`` slots, each
with its own (nbins, lo, hi).  KMeansStep / custom slots have no kernel
lowering — ops.py routes groups containing them through the scan lowering,
where they consume the same cached weight tile via
``Statistic.tile_update``.

The per-tile weight draw and the per-slot tile math are imported from the
single-statistic kernels (``_poisson_tile``, ``_bin_indices``,
``finite_mass_mask``), so the implicit weight matrix stays bit-identical
to ``implicit_weights(seed, B, n)`` and the fused group is bit-identical
to running each member's dedicated fused kernel with the same seed.

Grid: (B/bB, n/bn) with the contraction axis n LAST, so every output tile
is revisited sequentially and accumulated in place (same discipline as
weighted_stats / weighted_hist / kmeans_assign).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.weighted_hist.ref import _bin_indices, finite_mass_mask
from repro.kernels.weighted_stats.kernel import _poisson_tile


def _fm_kernel(scal_ref, x_ref, *refs, kinds, hist_nbins, hist_out_bins,
               d: int, block_b: int, block_n: int, use_tpu_prng: bool,
               has_mask: bool = False):
    i = pl.program_id(0)        # B-tile index
    t = pl.program_id(1)        # n-tile index (contraction)

    n_hist = sum(1 for k in kinds if k == "hist")
    in_refs = refs[:2 * n_hist]             # (lo, hi) per hist slot
    m_ref = refs[2 * n_hist] if has_mask else None
    out_refs = refs[2 * n_hist + (1 if has_mask else 0):]

    # ONE weight tile for every slot below — the whole point of the kernel.
    w = _poisson_tile(scal_ref[0], i, t, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])  # (bB, bn)
    x = x_ref[...].astype(jnp.float32)                        # (bn, dp)
    bn = x.shape[0]

    oi = 0      # output-ref cursor
    hidx = 0    # hist-slot cursor
    for kind in kinds:
        if kind == "moments":
            wtot_ref, s1_ref, s2_ref = out_refs[oi:oi + 3]
            oi += 3

            @pl.when(t == 0)
            def _init_m(wtot_ref=wtot_ref, s1_ref=s1_ref, s2_ref=s2_ref):
                wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)
                s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
                s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

            wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)
            s1_ref[...] += jax.lax.dot(w, x,
                                       preferred_element_type=jnp.float32)
            s2_ref[...] += jax.lax.dot(w, x * x,
                                       preferred_element_type=jnp.float32)
        else:
            nbins = hist_nbins[hidx]
            out_bins = hist_out_bins[hidx]
            lo_ref, hi_ref = in_refs[2 * hidx:2 * hidx + 2]
            out_ref = out_refs[oi]
            oi += 1
            hidx += 1

            @pl.when(t == 0)
            def _init_h(out_ref=out_ref):
                out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

            idx = _bin_indices(x, lo_ref[...], hi_ref[...], nbins)
            mass = finite_mass_mask(x)
            bins = jax.lax.broadcasted_iota(jnp.int32, (bn, out_bins), 1)
            # d lane-aligned dots reusing the one weight tile (same layout
            # discipline as weighted_hist's fused kernel); only the d REAL
            # columns are contracted — lane padding of x is never read.
            for c in range(d):
                onehot = ((idx[:, c:c + 1] == bins).astype(jnp.float32)
                          * mass[:, c:c + 1])                 # (bn, ob)
                out_ref[:, c * out_bins:(c + 1) * out_bins] += jax.lax.dot(
                    w, onehot, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("B", "kinds", "hist_nbins", "d_valid",
                                    "block_b", "block_n", "interpret",
                                    "use_tpu_prng"))
def fused_poisson_multi_kernel(seed: jax.Array, n_valid: jax.Array,
                               values: jax.Array, hist_lo, hist_hi, B: int,
                               kinds, hist_nbins, d_valid: int,
                               block_b: int = 128, block_n: int = 512,
                               interpret: bool = True,
                               use_tpu_prng: bool = False,
                               mask=None):
    """Raw kernel entry: shapes must already be padded (ops.py does this).

    values (n, dp) f32 with dp the 128-lane-padded dimension; ``hist_lo``/
    ``hist_hi`` are tuples of (1, dp) f32 arrays, one per ``"hist"`` entry
    of ``kinds`` (padding spans must be nonzero).  ``kinds`` is the static
    slot layout, e.g. ``("moments", "hist", "hist")``; ``hist_nbins`` the
    matching true bin counts.  ``B`` must be a ``block_b`` multiple,
    ``n_valid`` masks weight columns past the unpadded row count.

    Returns the flat output tuple in slot order: a "moments" slot yields
    (w_tot (B, 1), s1 (B, dp), s2 (B, dp)); a "hist" slot yields
    (B, d_valid·out_bins) with out_bins = nbins lane-padded to 128 —
    callers reshape and slice [..., :nbins].
    """
    n, dp = values.shape
    assert B % block_b == 0 and n % block_n == 0, ((B, n), (block_b, block_n))
    assert d_valid <= dp, (d_valid, dp)
    assert sum(1 for k in kinds if k == "hist") == len(hist_nbins) == \
        len(hist_lo) == len(hist_hi), (kinds, hist_nbins)
    assert kinds.count("moments") <= 1, kinds
    hist_out_bins = tuple(nb + (-nb) % 128 for nb in hist_nbins)

    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((block_n, dp), lambda i, t: (t, 0))]
    operands = [jnp.stack([jnp.asarray(seed, jnp.int32),
                           jnp.asarray(n_valid, jnp.int32)]), values]
    for lo, hi in zip(hist_lo, hist_hi):
        in_specs.append(pl.BlockSpec((1, dp), lambda i, t: (0, 0)))
        in_specs.append(pl.BlockSpec((1, dp), lambda i, t: (0, 0)))
        operands.extend([lo, hi])
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, t: (0, t)))
        operands.append(mask)

    out_specs, out_shape = [], []
    hidx = 0
    for kind in kinds:
        if kind == "moments":
            out_specs += [
                pl.BlockSpec((block_b, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((block_b, dp), lambda i, t: (i, 0)),
                pl.BlockSpec((block_b, dp), lambda i, t: (i, 0)),
            ]
            out_shape += [
                jax.ShapeDtypeStruct((B, 1), jnp.float32),
                jax.ShapeDtypeStruct((B, dp), jnp.float32),
                jax.ShapeDtypeStruct((B, dp), jnp.float32),
            ]
        else:
            ob = hist_out_bins[hidx]
            hidx += 1
            out_specs.append(pl.BlockSpec((block_b, d_valid * ob),
                                          lambda i, t: (i, 0)))
            out_shape.append(
                jax.ShapeDtypeStruct((B, d_valid * ob), jnp.float32))

    kern = functools.partial(_fm_kernel, kinds=tuple(kinds),
                             hist_nbins=tuple(hist_nbins),
                             hist_out_bins=hist_out_bins, d=d_valid,
                             block_b=block_b, block_n=block_n,
                             use_tpu_prng=use_tpu_prng,
                             has_mask=mask is not None)
    outs = pl.pallas_call(
        kern,
        grid=(B // block_b, n // block_n),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return tuple(outs)
