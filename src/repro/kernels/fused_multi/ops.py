"""Public wrapper for the fused multi-statistic bootstrap pass.

``fused_poisson_multi`` computes, for every slot accumulator of a
``StatisticGroup``, the B per-resample states under ONE shared implicit
Poisson(1) weight stream and ONE pass over x: each (block_b, block_n)
weight tile is generated once — same ``weight_tile_blocks`` clamp and
``(seed, b-tile, n-tile)`` threefry keying as every other fused path, so
the implicit matrix is bit-identical to
``weighted_stats.ops.implicit_weights(seed, B, n)`` — and handed to every
slot's per-tile accumulator in turn.

Lowerings (``backend``):

* ``"scan"`` (CPU default) — a single ``lax.scan`` over n-tiles whose body
  draws the weight tile via the shared ``implicit_weight_tile`` and calls
  each slot's ``Statistic.tile_update``: moment slots run the
  weighted_stats dot math, histogram slots the weighted_hist scatter math,
  KMeansStep the kmeans_assign tile math, and custom statistics fall back
  to a vmapped ``update`` over the SAME cached tile — nothing ever
  regenerates or re-reads.
* ``"pallas"`` / ``"pallas_interpret"`` — kernels/fused_multi/kernel.py,
  available when every slot is a moment or histogram accumulator (the MXU
  shapes); groups with KMeansStep/custom slots use the scan lowering.
* ``None`` — auto: pallas on TPU when kernel-eligible, scan elsewhere.

NOT internally jitted: the callers (``_bootstrap_jit``, ``_pd_extend_jit``,
the chunked/sharded scan bodies) already trace it inside their jits, and a
StatisticGroup carrying traced member parameters (KMeansStep centroids)
must not be captured as a jit-static argument.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_multi.kernel import fused_poisson_multi_kernel
from repro.kernels.weighted_stats.ops import (_pad_to, implicit_weight_tile,
                                              weight_tile_blocks)


def _kernel_slots(group) -> Tuple[bool, tuple]:
    """(eligible, hist slot list) — the Pallas kernel handles at most one
    moment slot plus histogram slots."""
    from repro.core.reduce_api import Quantile, _MomentStatistic
    hists = tuple(s for s in group.slots if isinstance(s, Quantile))
    ok = all(isinstance(s, (Quantile, _MomentStatistic))
             for s in group.slots)
    return ok, hists


def _multi_scan(slots, seed, n_valid, xp, B: int, block_b: int,
                block_n: int, maskp=None):
    """CPU lowering: one scan, one weight tile per step, every slot fed."""
    n, d = xp.shape
    nt = n // block_n
    xc = xp.reshape(nt, block_n, d)
    maskc = None if maskp is None else maskp.reshape(nt, block_n)
    init = tuple(jax.vmap(lambda _, s=s: s.init_state(d))(jnp.arange(B))
                 for s in slots)

    def body(states, t):
        w = implicit_weight_tile(seed, n_valid, t, B, block_b, block_n,
                                 valid=None if maskc is None else maskc[t])
        xt = xc[t]
        return tuple(s.tile_update(st, xt, w)
                     for s, st in zip(slots, states)), None

    states, _ = jax.lax.scan(body, init, jnp.arange(nt, dtype=jnp.int32))
    return states


def fused_poisson_tiled(stat, seed, values: jax.Array, B: int,
                        n_valid=None, valid_mask=None,
                        block_b: int = 128, block_n: int = 512):
    """Generic matrix-free tile scan for ONE statistic: draw each implicit
    Poisson(1) weight tile once (shared ``weight_tile_blocks`` /
    ``(seed, b-tile, n-tile)`` keying) and feed it to
    ``stat.tile_update``.  This is the ``_multi_scan`` machinery without
    the slot tuple — the fused path for statistics that segment or
    transform the tile themselves, e.g. ``GroupedStatistic`` over a custom
    inner (its ``tile_update`` splits the key column off ``x_tile`` and
    key-masks the shared weight tile), so even custom keyed statistics
    never materialize the (B, n) weight matrix."""
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    if n_valid is None:
        n_valid = n
    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    seed = jnp.asarray(seed, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    mp = None
    if valid_mask is not None:
        mp = _pad_to(jnp.asarray(valid_mask, jnp.float32).reshape(n), bn, 0)
    states = _multi_scan((stat,), seed, n_valid, xp, Bp, bb, bn, maskp=mp)[0]
    return jax.tree_util.tree_map(lambda a: a[:B], states)


def fused_poisson_multi(group, seed, values: jax.Array, B: int,
                        n_valid=None, valid_mask=None,
                        backend: str | None = None,
                        block_b: int = 128, block_n: int = 512) -> Tuple:
    """Slot-ordered tuple of B-leading per-resample states for ``group``
    under one shared in-kernel Poisson(1) weight stream.

    ``n_valid`` (traced scalar, default n) masks weight columns >= n_valid
    to zero, exactly as in every other fused path.  ``valid_mask`` (traced
    (n,) f32 of exact 0.0/1.0) multiplies the shared weight tiles —
    arbitrary interior validity holes; a prefix-shaped mask reproduces the
    ``n_valid`` result bit for bit (see ``implicit_weight_tile``).  The
    result is what ``StatisticGroup.fused_poisson_states`` returns — its
    state pytree.
    """
    from repro.core.reduce_api import HistogramState, _MomentStatistic
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    eligible, hist_slots = _kernel_slots(group)
    if backend is None:
        backend = ("pallas" if jax.default_backend() == "tpu" and eligible
                   else "scan")
    if backend not in ("scan", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown fused_poisson_multi backend: {backend!r}")
    if backend != "scan" and not eligible:
        raise ValueError(
            "the fused_multi Pallas kernel covers moment/histogram slots "
            "only; groups with KMeansStep or custom statistics use "
            "backend='scan' (same shared weight tiles, via tile_update)")
    if n_valid is None:
        n_valid = n

    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    seed = jnp.asarray(seed, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    mp = None
    if valid_mask is not None:
        mp = _pad_to(jnp.asarray(valid_mask, jnp.float32).reshape(n), bn, 0)

    if backend == "scan":
        states = _multi_scan(group.slots, seed, n_valid, xp, Bp, bb, bn,
                             maskp=mp)
        return jax.tree_util.tree_map(lambda a: a[:B], states)

    # ---- Pallas kernel path: moments + hist slots only ------------------
    kinds = tuple("moments" if isinstance(s, _MomentStatistic) else "hist"
                  for s in group.slots)
    xpp = _pad_to(xp, 128, 1)
    los = tuple(_pad_to(jnp.full((1, d), s.lo, jnp.float32), 128, 1)
                for s in hist_slots)
    his = tuple(_pad_to(jnp.full((1, d), s.hi, jnp.float32), 128, 1,
                        value=1.0)              # nonzero padding span
                for s in hist_slots)
    outs = fused_poisson_multi_kernel(
        seed, n_valid, xpp, los, his, Bp, kinds=kinds,
        hist_nbins=tuple(s.nbins for s in hist_slots), d_valid=d,
        block_b=bb, block_n=bn, interpret=(backend != "pallas"),
        use_tpu_prng=(backend == "pallas"),
        mask=None if mp is None else mp[None, :])

    states, oi = [], 0
    for slot, kind in zip(group.slots, kinds):
        if kind == "moments":
            wt, s1, s2 = outs[oi:oi + 3]
            oi += 3
            states.append(jax.vmap(slot.from_moments)(
                wt[:B, 0], s1[:B, :d], s2[:B, :d]))
        else:
            ob = slot.nbins + (-slot.nbins) % 128
            counts = outs[oi].reshape(Bp, d, ob)[:B, :, :slot.nbins]
            oi += 1
            states.append(HistogramState(
                counts=counts,
                lo=jnp.full((B, d), slot.lo, jnp.float32),
                hi=jnp.full((B, d), slot.hi, jnp.float32)))
    return tuple(states)
