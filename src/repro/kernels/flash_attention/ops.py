"""Public attention op: padding, backend dispatch, pure-JAX blockwise paths.

Backends
  pallas            compiled Pallas kernel (TPU target)
  pallas_interpret  same kernel, interpret mode (CPU validation)
  blockwise         pure-JAX flash recurrence (lax.scan over q and kv
                    blocks) — used for dry-run lowering and CPU smoke runs;
                    peak temp is (B, H, bq, bk) instead of (B, H, S, S)
  windowed          exact-shape sliding-window path: each q block gathers
                    only the ceil(W/bk)+1 KV blocks it can see, so HLO
                    FLOPs match the true SWA cost (no masked-block waste)
  direct            materialized softmax oracle (small shapes only)

Auto selection: TPU → pallas; window set and small → windowed; else
blockwise.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import mha_reference

_NEG_INF = -1e30


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# pure-JAX blockwise flash (generic causal/full)
# ---------------------------------------------------------------------------
def _blockwise(q, k, v, *, causal, window, scale, block_q, block_k,
               kv_offset):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, max(sq, 1))
    bk = min(block_k, max(skv, 1))

    qp = _pad_axis(q, bq, 2)
    kp = _pad_axis(k, bk, 2)
    vp = _pad_axis(v, bk, 2)
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk

    # (b, hkv, group, nq, bq, d) query blocks grouped per kv head
    qb = qp.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32)
    kb = kp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vb = vp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)

    def per_q_block(qi):
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, axis=3, keepdims=False)
        # qblk: (b, hkv, group, bq, d)
        rows = qi * bq + jnp.arange(bq)[:, None] + kv_offset

        def inner(carry, kj):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kb, kj, 2, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, kj, 2, keepdims=False)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk) * scale
            cols = kj * bk + jnp.arange(bk)[None, :]
            mask = (cols < skv) & (rows < sq + kv_offset)
            if causal:
                mask = mask & (cols <= rows)
            if window is not None:
                mask = mask & (cols > rows - window)
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, group, bq), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(per_q_block, jnp.arange(nq))     # (nq, b, hkv, g, bq, d)
    out = jnp.moveaxis(out, 0, 3)                      # (b, hkv, g, nq, bq, d)
    out = out.reshape(b, hq, nq * bq, d)[:, :, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# exact-shape sliding-window path
# ---------------------------------------------------------------------------
def _windowed(q, k, v, *, window, scale, block_q, kv_offset):
    """Causal SWA: q block i sees only KV rows (i·bq − W, (i+1)·bq]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, max(sq, 1))
    nrel = -(-window // bq) + 1          # ceil(W/bq)+1 KV blocks per q block

    qp = _pad_axis(q, bq, 2)
    kp = _pad_axis(k, bq, 2)
    vp = _pad_axis(v, bq, 2)
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bq

    qb = qp.reshape(b, hkv, group, nq, bq, d).astype(jnp.float32)
    kb = kp.reshape(b, hkv, nk, bq, d).astype(jnp.float32)
    vb = vp.reshape(b, hkv, nk, bq, d).astype(jnp.float32)

    def per_q_block(qi):
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 3, keepdims=False)
        rel = qi - jnp.arange(nrel)[::-1]            # (nrel,) block ids
        relc = jnp.clip(rel, 0, nk - 1)
        kctx = jnp.take(kb, relc, axis=2)            # (b, hkv, nrel, bq, d)
        vctx = jnp.take(vb, relc, axis=2)
        kctx = kctx.reshape(b, hkv, nrel * bq, d)
        vctx = vctx.reshape(b, hkv, nrel * bq, d)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kctx) * scale
        rows = qi * bq + jnp.arange(bq)[:, None] + kv_offset
        cols = (jnp.repeat(rel, bq) * bq
                + jnp.tile(jnp.arange(bq), nrel))[None, :]
        mask = (jnp.repeat(rel >= 0, bq)[None, :]
                & (cols <= rows) & (cols > rows - window)
                & (cols < skv) & (rows < sq + kv_offset))
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask[None, None, None], p, 0.0)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p, vctx)

    out = jax.lax.map(per_q_block, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 3).reshape(b, hq, nq * bq, d)[:, :, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, kv_offset: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    backend: Optional[str] = None) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); GQA by head grouping."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if scale is None:
        scale = d ** -0.5

    if backend is None:
        if jax.default_backend() == "tpu":
            backend = "pallas"
        elif window is not None and window <= 4 * block_q:
            backend = "windowed"
        else:
            backend = "blockwise"

    if backend == "direct":
        return mha_reference(q, k, v, causal=causal, window=window,
                             scale=scale, kv_offset=kv_offset)
    if backend == "windowed":
        assert causal and window is not None
        return _windowed(q, k, v, window=window, scale=scale,
                         block_q=block_q, kv_offset=kv_offset)
    if backend == "blockwise":
        return _blockwise(q, k, v, causal=causal, window=window, scale=scale,
                          block_q=block_q, block_k=block_k,
                          kv_offset=kv_offset)

    interpret = backend != "pallas"
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(128, skv))
    qp = _pad_axis(q, bq, 2).reshape(b * hq, -1, d)
    kp = _pad_axis(k, bk, 2).reshape(b * hkv, -1, d)
    vp = _pad_axis(v, bk, 2).reshape(b * hkv, -1, d)
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, window=window, scale=float(scale),
        block_q=bq, block_k=bk, q_heads=hq, kv_heads=hkv,
        seq_q=sq, seq_k=skv, kv_offset=kv_offset, interpret=interpret)
    return out.reshape(b, hq, -1, d)[:, :, :sq]
