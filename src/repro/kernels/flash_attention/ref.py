"""Pure-jnp oracle for flash_attention: direct masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_mask(seq_q: int, seq_k: int, causal: bool,
                   window: int | None, kv_offset: int = 0) -> jax.Array:
    """(Sq, Sk) boolean mask; True = attend.  Query row r sits at absolute
    position r + kv_offset (cached decode)."""
    rows = jnp.arange(seq_q)[:, None] + kv_offset
    cols = jnp.arange(seq_k)[None, :]
    mask = jnp.ones((seq_q, seq_k), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None, kv_offset: int = 0
                  ) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  GQA via head repeat."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = attention_mask(sq, k.shape[2], causal, window, kv_offset)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
