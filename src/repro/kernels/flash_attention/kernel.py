"""Blockwise (flash) attention Pallas kernel with causal + sliding-window
masking and GQA-aware index maps.

TPU adaptation notes (DESIGN.md §2): the FlashAttention recurrence is
implemented as a *sequential grid axis* (the KV-block axis is the last grid
dimension, which Pallas TPU iterates in order) with the running softmax
state (m, l, acc) held in VMEM scratch — the TPU analogue of the GPU
shared-memory tile loop.  Tiles are MXU-aligned: head_dim and block sizes
are multiples of 128 where the inputs allow.

Layout: q is (B·Hq, Sq, D), kv is (B·Hkv, Skv, D); the k/v BlockSpec index
map folds the GQA group arithmetic so KV tiles are fetched once per group
instead of materializing repeated heads in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int, seq_q: int, seq_k: int,
               kv_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions; kv_offset shifts query rows for cached decode
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + kv_offset
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < seq_k                               # kv padding
    mask &= (rows < seq_q + kv_offset)                # q padding
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q",
                              "block_k", "q_heads", "kv_heads", "seq_q",
                              "seq_k", "kv_offset", "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, window: int | None, scale: float,
                           block_q: int, block_k: int,
                           q_heads: int, kv_heads: int,
                           seq_q: int, seq_k: int, kv_offset: int = 0,
                           interpret: bool = True) -> jax.Array:
    """q: (B·Hq, Sq_pad, D); k, v: (B·Hkv, Skv_pad, D) — pre-padded.

    seq_q/seq_k are the unpadded logical lengths (mask beyond them).
    """
    bhq, sq, d = q.shape
    bhk, sk, _ = k.shape
    group = q_heads // kv_heads
    grid = (bhq, sq // block_q, sk // block_k)

    def q_map(b, i, j):
        return (b, i, 0)

    def kv_map(b, i, j):
        batch = b // q_heads
        kvh = (b % q_heads) // group
        return (batch * kv_heads + kvh, j, 0)

    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=seq_q, seq_k=seq_k,
        kv_offset=kv_offset)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
