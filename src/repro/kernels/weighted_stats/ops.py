"""jit'd public wrappers for weighted_stats: padding + platform dispatch.

On TPU the Pallas kernels run compiled; everywhere else they run in
interpret mode (tests) or fall back to jnp paths (fast CPU path for the
benchmarks — interpret mode is a correctness tool, not a perf tool).

Two entry points:

* ``weighted_moments``       — contract an explicit (B, n) weight matrix.
* ``fused_poisson_moments``  — matrix-free: Poisson(1) weights are generated
  from a counter-based PRNG *inside* the contraction (Pallas kernel on TPU,
  a tile-by-tile ``lax.scan`` on CPU) so the (B, n) matrix never
  materializes; peak live memory is O(B·block_n + B·d).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.poisson_counts.kernel import (_poisson_from_bits,
                                                 _threefry_bits)
from repro.kernels.weighted_stats.kernel import (
    fused_poisson_moments_grouped_kernel, fused_poisson_moments_kernel,
    fused_poisson_moments_stream_kernel, weighted_moments_kernel)
from repro.kernels.weighted_stats.ref import weighted_moments_ref


def _pad_to(x: jax.Array, mult: int, axis: int,
            value: float = 0.0) -> jax.Array:
    """Zero-pad (or ``value``-pad) ``axis`` up to a multiple of ``mult``.
    Shared by the kernel ops wrappers (weighted_hist imports it too)."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def weight_tile_blocks(B: int, n: int, block_b: int = 128,
                       block_n: int = 512) -> Tuple[int, int]:
    """Clamped (block_b, block_n) for the (implicit) weight-matrix tiling —
    hardware-aligned defaults that also stay small for tiny test shapes.

    EVERY fused path (fused_poisson_moments, fused_poisson_kmeans,
    fused_poisson_hist, poisson_counts, implicit_weights) must pick its
    weight-tile blocks through THIS helper: the PRNG is keyed per
    (seed, b-tile, n-tile), so two paths agree bit-for-bit on the implicit
    weight matrix — the common-random-numbers / delta-maintenance
    contract — only if they agree on this clamp.
    """
    return min(block_b, max(8, B)), min(block_n, max(128, n))


def _pick_blocks(B: int, n: int, d: int) -> Tuple[int, int, int]:
    """Tiles for the explicit-W kernel (same clamp + fixed lane width).

    VMEM budget (f32): bB·bn (W) + bn·bd (X, X²) + 2·bB·bd (acc) — with the
    defaults 128·512 + 512·128 + 2·128·128 floats ≈ 0.7 MB, far under the
    ~16 MB/core VMEM of v5e, leaving room for double buffering.
    """
    bb, bn = weight_tile_blocks(B, n)
    bd = 128                    # lane width: fixed regardless of d
    return bb, bn, bd


def weighted_moments(weights: jax.Array, values: jax.Array,
                     backend: str | None = None):
    """weights (B, n) × values (n, d) -> (w_tot (B,), s1 (B,d), s2 (B,d)).

    backend: None = auto (pallas on TPU, jnp elsewhere), "pallas",
    "pallas_interpret", "jnp".
    """
    if values.ndim == 1:
        values = values[:, None]
    B, n = weights.shape
    d = values.shape[1]

    if backend is None:
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")

    if backend == "jnp":
        w_tot, s1, s2 = weighted_moments_ref(weights, values)
        return w_tot[:, 0], s1, s2

    interpret = backend != "pallas"
    bb, bn, bd = _pick_blocks(B, n, d)
    wp = _pad_to(_pad_to(weights.astype(jnp.float32), bb, 0), bn, 1)
    xp = _pad_to(_pad_to(values.astype(jnp.float32), bn, 0), bd, 1)
    w_tot, s1, s2 = weighted_moments_kernel(
        wp, xp, block_b=bb, block_n=bn, block_d=bd, interpret=interpret)
    return w_tot[:B, 0], s1[:B, :d], s2[:B, :d]


# ============================================================================
# matrix-free path
# ============================================================================
def implicit_weight_tile(seed, n_valid, t, B: int, block_b: int,
                         block_n: int, valid=None) -> jax.Array:
    """The (B, block_n) implicit Poisson(1) weight tile at n-tile ``t``:
    the scan-lowering analogue of the kernels' in-VMEM per-tile draw (same
    threefry fold-in order, same CDF ladder, columns >= ``n_valid`` masked
    to 0).

    ``valid`` (optional (block_n,) f32 of exact 0.0/1.0) is this tile's
    slice of an arbitrary validity mask — interior holes from failed
    shards, not just a prefix.  The tile is multiplied by it AFTER the
    prefix mask; since w·1.0 == w and w·0.0 == 0.0 exactly in f32, a
    prefix-shaped ``valid`` reproduces the ``n_valid`` masking bit for bit.

    EVERY matrix-free scan lowering (fused moments here,
    kernels/kmeans_assign's fused bootstrap) must draw its weights through
    this helper — it is what keeps the implicit matrix bit-identical to
    ``implicit_weights(seed, B, n)`` across statistics, which the delta-
    maintenance / common-random-numbers discipline relies on."""
    def one(i):
        bits = _threefry_bits(seed, i, t, (block_b, block_n))
        return _poisson_from_bits(bits)
    w = jax.vmap(one)(jnp.arange(B // block_b)).reshape(B, block_n)
    cols = jnp.arange(block_n, dtype=jnp.int32)
    mask = (t * block_n + cols) < n_valid
    w = jnp.where(mask[None, :], w, 0.0)
    if valid is not None:
        w = w * valid[None, :]
    return w


@functools.partial(jax.jit, static_argnames=("B", "block_b", "block_n",
                                             "dtype"))
def _fused_scan(seed, n_valid, xp, B, block_b, block_n,
                dtype=jnp.float32, maskp=None):
    """CPU/matrix-free oracle of the fused kernel: same tile decomposition,
    same per-tile threefry bits and CDF ladder, same k-sequential f32
    accumulation — but expressed as a jnp scan so XLA:CPU runs it at full
    speed.  Peak live memory per step is (B, block_n).

    ``dtype=bfloat16`` is the reduced-precision input study: the weight
    tile (small Poisson(1) integers, exactly representable in bf16) and x
    enter the contraction in bf16 while the s1/s2 accumulators stay f32 —
    i.e. the MXU bf16-multiply/f32-accumulate mode.  x² is squared in f32
    FIRST and then rounded once to bf16 (squaring an already-rounded bf16
    x would double the relative error of the second moment)."""
    n, d = xp.shape
    nb_n = n // block_n
    xc = xp.reshape(nb_n, block_n, d)
    # ``maskp=None`` keeps the pre-mask jaxpr byte-identical (None is a
    # valid empty-pytree jit operand, so one jitted function serves both).
    maskc = None if maskp is None else maskp.reshape(nb_n, block_n)

    def body(carry, k):
        w_tot, s1, s2 = carry
        w = implicit_weight_tile(seed, n_valid, k, B, block_b, block_n,
                                 valid=None if maskc is None else maskc[k])
        xk = xc[k]
        return (w_tot + jnp.sum(w, axis=1, keepdims=True),
                s1 + jax.lax.dot(w.astype(dtype), xk.astype(dtype),
                                 preferred_element_type=jnp.float32),
                s2 + jax.lax.dot(w.astype(dtype),
                                 (xk * xk).astype(dtype),
                                 preferred_element_type=jnp.float32)), None

    init = (jnp.zeros((B, 1), jnp.float32),
            jnp.zeros((B, d), jnp.float32),
            jnp.zeros((B, d), jnp.float32))
    (w_tot, s1, s2), _ = jax.lax.scan(body, init,
                                      jnp.arange(nb_n, dtype=jnp.int32))
    return w_tot, s1, s2


@functools.partial(jax.jit, static_argnames=("B", "block_b", "block_n",
                                             "num_groups", "dtype"))
def _grouped_fused_scan(seed, n_valid, xp, gp, B, block_b, block_n,
                        num_groups, dtype=jnp.float32, maskp=None):
    """GROUP BY scan lowering: one implicit weight tile per step, keyed
    into ``num_groups`` accumulator slots by an exact 0/1 key-mask
    multiply.  A static per-key loop applies the SAME dot / row-sum ops as
    ``_fused_scan`` to ``w * (gid == g)``, so slot g is bitwise what
    ``_fused_scan`` produces under ``maskp = (gid == g)`` (0/1 mask
    multiplies compose exactly: (w·valid)·keymask ≡ w·(valid·keymask)).
    Peak live memory per step stays (B, block_n) — the (n, G) one-hot
    never materializes."""
    n, d = xp.shape
    nb_n = n // block_n
    xc = xp.reshape(nb_n, block_n, d)
    gc = gp.reshape(nb_n, block_n)
    maskc = None if maskp is None else maskp.reshape(nb_n, block_n)

    def body(carry, k):
        w_tot, s1, s2 = carry
        w = implicit_weight_tile(seed, n_valid, k, B, block_b, block_n,
                                 valid=None if maskc is None else maskc[k])
        xk = xc[k]
        xk2 = xk * xk
        gid = gc[k]
        wt_new, s1_new, s2_new = [], [], []
        for g in range(num_groups):
            wg = w * (gid == g).astype(jnp.float32)[None, :]
            wt_new.append(w_tot[:, g] + jnp.sum(wg, axis=1))
            s1_new.append(s1[:, g] + jax.lax.dot(
                wg.astype(dtype), xk.astype(dtype),
                preferred_element_type=jnp.float32))
            s2_new.append(s2[:, g] + jax.lax.dot(
                wg.astype(dtype), xk2.astype(dtype),
                preferred_element_type=jnp.float32))
        return (jnp.stack(wt_new, axis=1), jnp.stack(s1_new, axis=1),
                jnp.stack(s2_new, axis=1)), None

    init = (jnp.zeros((B, num_groups), jnp.float32),
            jnp.zeros((B, num_groups, d), jnp.float32),
            jnp.zeros((B, num_groups, d), jnp.float32))
    (w_tot, s1, s2), _ = jax.lax.scan(body, init,
                                      jnp.arange(nb_n, dtype=jnp.int32))
    return w_tot, s1, s2


def fused_poisson_moments(seed, values: jax.Array, B: int,
                          backend: str | None = None,
                          block_b: int = 128, block_n: int = 512,
                          n_valid=None, dtype=jnp.float32,
                          valid_mask=None, stream: bool = False,
                          group_ids=None, num_groups: int | None = None):
    """Matrix-free bootstrap moments from an int32 seed (no weight matrix).

    values (n, d) or (n,) -> (w_tot (B,), s1 (B,d), s2 (B,d)) where the
    implicit weights are Poisson(1), keyed per (block_b, block_n) tile by
    (seed, b-tile, n-tile) — bit-identical to
    ``poisson_counts(seed, B, n)`` with the same blocks (see
    ``implicit_weights``).

    ``n_valid`` (traced scalar, default n) masks weight columns >= n_valid
    to zero — callers that pass pre-padded values (e.g. the chunked
    bootstrap's ragged tail) use it so ``w_tot`` ignores padding.

    ``valid_mask`` (traced (n,) f32 of exact 0.0/1.0, default all-valid)
    is the ARBITRARY-mask generalization: the implicit weight tile is
    multiplied by the matching mask slice, so interior holes (failed
    shards, ft/) run on the fused path.  A prefix-shaped mask is bitwise
    identical to the equivalent ``n_valid`` (multiplying f32 by exactly
    1.0/0.0 is exact); both may be combined.

    ``stream=True`` (Pallas backends) routes through the double-buffered
    DMA kernel: x stays in HBM/ANY memory and each (block_n, d) tile is
    async-copied into a 2-slot VMEM scratch while the previous tile is
    contracted — emit_pipeline-style overlap of the n-axis loads, same
    (seed, b-tile, n-tile) weight keying, bit-identical outputs.

    ``dtype`` is the contraction input precision (ROADMAP bf16 study):
    ``jnp.bfloat16`` feeds w and x to the dots in bf16 with f32
    accumulators — halves the X-side HBM/VMEM traffic on TPU for ~1e-3
    relative moment error (weights are small exact integers; see
    benchmarks/kernelbench.run_bootstrap for the quantified cv error).

    ``group_ids`` (traced (n,) integer keys 0..num_groups-1, float storage
    is fine) switches on the GROUP BY path: the SAME implicit weight
    stream is segment-reduced into ``num_groups`` keyed accumulator slots
    per tile (exact 0/1 key-mask multiplies — no (n, G) one-hot), and the
    outputs gain a G axis: (w_tot (B, G), s1 (B, G, d), s2 (B, G, d)).
    Slot g is BITWISE equal to the ungrouped call under
    ``valid_mask = (group_ids == g)`` — i.e. to bootstrapping key g's rows
    alone under the same seed (common random numbers across keys).

    backend: None = auto (pallas on TPU, scan elsewhere), "pallas",
    "pallas_interpret", "scan".
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "scan"
    if n_valid is None:
        n_valid = n

    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    seed = jnp.asarray(seed, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    dtype = jnp.dtype(dtype)
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    mp = None
    if valid_mask is not None:
        mp = _pad_to(jnp.asarray(valid_mask, jnp.float32).reshape(n), bn, 0)

    if group_ids is not None:
        if num_groups is None or int(num_groups) < 1:
            raise ValueError("group_ids requires num_groups >= 1, got "
                             f"{num_groups!r}")
        if stream:
            raise ValueError("stream=True is not supported with group_ids "
                             "(the grouped kernel keeps its G·d "
                             "accumulators resident instead)")
        G = int(num_groups)
        # padding columns keep key 0 — their weights are already exactly
        # zero via the n_valid prefix mask / zero-padded valid_mask.
        gp = _pad_to(jnp.asarray(group_ids, jnp.float32).reshape(n), bn, 0)
        if backend == "scan":
            w_tot, s1, s2 = _grouped_fused_scan(seed, n_valid, xp, gp, Bp,
                                                bb, bn, G, dtype=dtype,
                                                maskp=mp)
            return w_tot[:B], s1[:B], s2[:B]
        bd = 128
        xp = _pad_to(xp, bd, 1)
        w_tot, s1, s2 = fused_poisson_moments_grouped_kernel(
            seed, n_valid, xp, gp[None, :], Bp, G,
            block_b=bb, block_n=bn, block_d=bd,
            interpret=(backend != "pallas"),
            use_tpu_prng=(backend == "pallas"), dtype=dtype,
            mask=None if mp is None else mp[None, :])
        return w_tot[:B], s1[:B, :, :d], s2[:B, :, :d]

    if backend == "scan":
        w_tot, s1, s2 = _fused_scan(seed, n_valid, xp, Bp, bb, bn,
                                    dtype=dtype, maskp=mp)
        return w_tot[:B, 0], s1[:B], s2[:B]

    bd = 128                    # lane width: fixed regardless of d
    xp = _pad_to(xp, bd, 1)
    kern = (fused_poisson_moments_stream_kernel if stream
            else fused_poisson_moments_kernel)
    w_tot, s1, s2 = kern(
        seed, n_valid, xp, Bp,
        block_b=bb, block_n=bn, block_d=bd,
        interpret=(backend != "pallas"),
        use_tpu_prng=(backend == "pallas"), dtype=dtype,
        mask=None if mp is None else mp[None, :])
    return w_tot[:B, 0], s1[:B, :d], s2[:B, :d]


@functools.partial(jax.jit, static_argnames=("B", "n", "block_b", "block_n"))
def implicit_weights(seed, B: int, n: int, block_b: int = 128,
                     block_n: int = 512) -> jax.Array:
    """Materialize the (B, n) weight matrix the threefry-lowered fused paths
    ("scan", "pallas_interpret") use implicitly: same per-tile fold-in and
    CDF ladder, expressed as one vmapped jnp computation (fast on CPU; also
    bit-identical to ``poisson_counts(..., backend="pallas_interpret")``).

    Used as the test oracle and as the fused_rng fallback for statistics
    without a moment decomposition.  Note: on TPU the compiled kernel draws
    its bits from the hardware PRNG (``use_tpu_prng=True``), which is
    distributionally identical but NOT bit-identical to this matrix.
    """
    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    nb_b = (B + (-B) % bb) // bb
    nb_n = (n + (-n) % bn) // bn
    seed = jnp.asarray(seed, jnp.int32)

    def tile(i, k):
        return _poisson_from_bits(_threefry_bits(seed, i, k, (bb, bn)))

    w = jax.vmap(lambda i: jax.vmap(lambda k: tile(i, k))(
        jnp.arange(nb_n)))(jnp.arange(nb_b))     # (nb_b, nb_n, bb, bn)
    w = w.transpose(0, 2, 1, 3).reshape(nb_b * bb, nb_n * bn)
    return w[:B, :n]
