"""jit'd public wrapper for weighted_stats: padding + platform dispatch.

On TPU the Pallas kernel runs compiled; everywhere else it runs in
interpret mode (tests) or falls back to the jnp oracle (fast CPU path for
the benchmarks — interpret mode is a correctness tool, not a perf tool).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.weighted_stats.kernel import weighted_moments_kernel
from repro.kernels.weighted_stats.ref import weighted_moments_ref


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_blocks(B: int, n: int, d: int) -> Tuple[int, int, int]:
    """Hardware-aligned tiles that also stay small for tiny test shapes.

    VMEM budget (f32): bB·bn (W) + bn·bd (X, X²) + 2·bB·bd (acc) — with the
    defaults 128·512 + 512·128 + 2·128·128 floats ≈ 0.7 MB, far under the
    ~16 MB/core VMEM of v5e, leaving room for double buffering.
    """
    bb = min(128, max(8, B))
    bn = min(512, max(128, n))
    bd = min(128, max(128, d))
    return bb, bn, bd


def weighted_moments(weights: jax.Array, values: jax.Array,
                     backend: str | None = None):
    """weights (B, n) × values (n, d) -> (w_tot (B,), s1 (B,d), s2 (B,d)).

    backend: None = auto (pallas on TPU, jnp elsewhere), "pallas",
    "pallas_interpret", "jnp".
    """
    if values.ndim == 1:
        values = values[:, None]
    B, n = weights.shape
    d = values.shape[1]

    if backend is None:
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")

    if backend == "jnp":
        w_tot, s1, s2 = weighted_moments_ref(weights, values)
        return w_tot[:, 0], s1, s2

    interpret = backend != "pallas"
    bb, bn, bd = _pick_blocks(B, n, d)
    wp = _pad_to(_pad_to(weights.astype(jnp.float32), bb, 0), bn, 1)
    xp = _pad_to(_pad_to(values.astype(jnp.float32), bn, 0), bd, 1)
    w_tot, s1, s2 = weighted_moments_kernel(
        wp, xp, block_b=bb, block_n=bn, block_d=bd, interpret=interpret)
    return w_tot[:B, 0], s1[:B, :d], s2[:B, :d]
