"""Pure-jnp oracle for the weighted_stats kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_moments_ref(weights: jax.Array, values: jax.Array):
    """weights (B, n), values (n, d) -> (w_tot (B,1), s1 (B,d), s2 (B,d))."""
    w = weights.astype(jnp.float32)
    x = values.astype(jnp.float32)
    w_tot = jnp.sum(w, axis=1, keepdims=True)
    s1 = w @ x
    s2 = w @ (x * x)
    return w_tot, s1, s2
