"""Fused weighted-moments Pallas kernels.

``weighted_moments_kernel`` computes, for every bootstrap resample b (a row
of the weight matrix W):

    w_tot[b] = Σ_i W[b,i]
    s1[b,:]  = Σ_i W[b,i] · X[i,:]
    s2[b,:]  = Σ_i W[b,i] · X[i,:]²

in a single pass: the (bB, bn) weight tile is read once from VMEM and feeds
two MXU contractions (against X and X²) plus a VPU row-sum — 3 outputs for
one HBM read of W, which is what makes the B-resample loop compute-bound
instead of bandwidth-bound (DESIGN.md §2).

``fused_poisson_moments_kernel`` goes one step further and is the
*matrix-free* bootstrap hot path: the Poisson(1) weight tile is never read
from HBM at all — it is generated inside the kernel from a counter-based
PRNG keyed by ``(seed, b-tile, n-tile)`` (the same threefry/tile discipline
as kernels/poisson_counts, so the implicit weight matrix is bit-identical
to ``poisson_counts(seed, B, n)`` under matching block shapes) and
immediately contracted.  Peak HBM traffic drops from O(B·n) to O(n·d + B·d)
and the (B, n) matrix never exists anywhere.

Grid: (B/bB, d/bd, n/bn); the contraction axis n is the LAST grid axis so
output tiles are revisited sequentially and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.poisson_counts.kernel import (_poisson_from_bits,
                                                 _threefry_bits)


def _ws_kernel(w_ref, x_ref, wtot_ref, s1_ref, s2_ref):
    j = pl.program_id(1)        # d-tile index
    k = pl.program_id(2)        # n-tile index (contraction)

    w = w_ref[...].astype(jnp.float32)       # (bB, bn)
    x = x_ref[...].astype(jnp.float32)       # (bn, bd)

    @pl.when(k == 0)
    def _init_moments():
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    s1_ref[...] += jax.lax.dot(w, x, preferred_element_type=jnp.float32)
    s2_ref[...] += jax.lax.dot(w, x * x, preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_wtot():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)

    @pl.when(j == 0)
    def _acc_wtot():
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_n", "block_d",
                                    "interpret"))
def weighted_moments_kernel(weights: jax.Array, values: jax.Array,
                            block_b: int = 128, block_n: int = 512,
                            block_d: int = 128, interpret: bool = True):
    """Raw kernel entry: shapes must already be padded to block multiples.

    weights: (B, n) f32;  values: (n, d) f32.
    Returns (w_tot (B, 1), s1 (B, d), s2 (B, d)) — all f32.
    """
    B, n = weights.shape
    n2, d = values.shape
    assert n == n2, (weights.shape, values.shape)
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))

    grid = (B // block_b, d // block_d, n // block_n)
    return pl.pallas_call(
        _ws_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        interpret=interpret,
    )(weights, values)


# ============================================================================
# matrix-free path: in-kernel weight generation + contraction
# ============================================================================
def _poisson_tile(seed, i, k, shape, n_valid, block_n: int,
                  use_tpu_prng: bool, valid=None) -> jax.Array:
    """Poisson(1) weight tile for grid position (i, k), padding masked to 0.

    Identical per-tile seeding to kernels/poisson_counts (same fold-in order,
    same CDF ladder), so the implicit weight matrix equals
    ``poisson_counts(seed, B, n)`` under matching block shapes.

    ``valid`` (optional (1, block_n) f32 of exact 0.0/1.0) is this tile's
    slice of an arbitrary validity mask, multiplied in AFTER the prefix
    mask — the kernel-side mirror of ``ops.implicit_weight_tile``'s
    ``valid`` (w·1.0 == w and w·0.0 == 0.0 exactly, so a prefix-shaped
    mask reproduces the ``n_valid`` path bit for bit).
    """
    if use_tpu_prng:
        pltpu.prng_seed(seed, i, k)
        bits = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    else:
        bits = _threefry_bits(seed, i, k, shape)
    w = _poisson_from_bits(bits)
    col = k * block_n + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    w = jnp.where(col < n_valid, w, 0.0)
    if valid is not None:
        w = w * valid
    return w


def _fpm_kernel(scal_ref, x_ref, *refs, block_b: int, block_n: int,
                use_tpu_prng: bool, dtype=jnp.float32, has_mask: bool = False):
    if has_mask:
        m_ref, (wtot_ref, s1_ref, s2_ref) = refs[0], refs[1:]
    else:
        m_ref, (wtot_ref, s1_ref, s2_ref) = None, refs
    i = pl.program_id(0)        # B-tile index
    j = pl.program_id(1)        # d-tile index
    k = pl.program_id(2)        # n-tile index (contraction)

    w = _poisson_tile(scal_ref[0], i, k, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])
    x = x_ref[...].astype(jnp.float32)       # (bn, bd)

    @pl.when(k == 0)
    def _init_moments():
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    # dtype=bf16: inputs enter the MXU in bf16, accumulators stay f32
    # (bf16-multiply/f32-accumulate).  Weights are small Poisson(1)
    # integers — exact in bf16; x² is squared in f32 then rounded ONCE.
    s1_ref[...] += jax.lax.dot(w.astype(dtype), x.astype(dtype),
                               preferred_element_type=jnp.float32)
    s2_ref[...] += jax.lax.dot(w.astype(dtype), (x * x).astype(dtype),
                               preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_wtot():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)

    @pl.when(j == 0)
    def _acc_wtot():
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("B", "block_b", "block_n", "block_d",
                                    "interpret", "use_tpu_prng", "dtype"))
def fused_poisson_moments_kernel(seed: jax.Array, n_valid: jax.Array,
                                 values: jax.Array, B: int,
                                 block_b: int = 128, block_n: int = 512,
                                 block_d: int = 128, interpret: bool = True,
                                 use_tpu_prng: bool = False,
                                 dtype=jnp.float32, mask=None):
    """Matrix-free bootstrap moments: weights generated in VMEM, never in HBM.

    values: (n, d) f32, pre-padded to block multiples (ops.py handles this);
    ``n_valid`` is the unpadded row count — weight columns >= n_valid are
    masked to zero so ``w_tot`` ignores the padding (padded X rows are zero,
    so s1/s2 are unaffected either way).  ``mask`` (optional (1, n) f32 of
    exact 0.0/1.0, zero-padded like values) multiplies the weight tiles —
    arbitrary interior validity holes, not just a prefix.  ``B`` must be a
    block_b multiple.  Returns (w_tot (B, 1), s1 (B, d), s2 (B, d)) — f32.
    """
    n, d = values.shape
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))

    grid = (B // block_b, d // block_d, n // block_n)
    kern = functools.partial(_fpm_kernel, block_b=block_b, block_n=block_n,
                             use_tpu_prng=use_tpu_prng, dtype=dtype,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
    ]
    operands = [scal, values]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, k)))
        operands.append(mask)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


# ============================================================================
# grouped (GROUP BY) variant: per-key accumulator slots, one weight stream
# ============================================================================
def _fpm_grouped_kernel(scal_ref, x_ref, g_ref, *refs, block_b: int,
                        block_n: int, num_groups: int, use_tpu_prng: bool,
                        dtype=jnp.float32, has_mask: bool = False):
    """Keyed segment-reduction of the implicit weight tile: the tile is
    drawn ONCE per grid step (same (seed, b-tile, n-tile) keying as
    ``_fpm_kernel``) and routed into each key's accumulator slot by an
    exact 0/1 key-mask multiply — a static per-key loop of the SAME dot /
    row-sum ops as the ungrouped kernel, so key g's moments are bitwise
    what the ungrouped kernel produces under ``mask = (key == g)``.  No
    (block_b·n) weight tile is ever re-drawn per key and no (block_n,
    num_groups) one-hot is built: the key mask is a (1, block_n) compare
    broadcast into the weight multiply."""
    if has_mask:
        m_ref, (wtot_ref, s1_ref, s2_ref) = refs[0], refs[1:]
    else:
        m_ref, (wtot_ref, s1_ref, s2_ref) = None, refs
    i = pl.program_id(0)        # B-tile index
    k = pl.program_id(1)        # n-tile index (contraction)

    w = _poisson_tile(scal_ref[0], i, k, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])
    gid = g_ref[...]                         # (1, block_n) f32 keys
    x = x_ref[...].astype(jnp.float32)       # (bn, d)

    @pl.when(k == 0)
    def _init():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    x2 = x * x
    for g in range(num_groups):
        wg = w * (gid == g).astype(jnp.float32)          # (bB, bn)
        s1_ref[:, g, :] += jax.lax.dot(
            wg.astype(dtype), x.astype(dtype),
            preferred_element_type=jnp.float32)
        s2_ref[:, g, :] += jax.lax.dot(
            wg.astype(dtype), x2.astype(dtype),
            preferred_element_type=jnp.float32)
        wtot_ref[:, g] += jnp.sum(wg, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("B", "num_groups", "block_b", "block_n",
                                    "block_d", "interpret", "use_tpu_prng",
                                    "dtype"))
def fused_poisson_moments_grouped_kernel(seed: jax.Array, n_valid: jax.Array,
                                         values: jax.Array,
                                         group_ids: jax.Array, B: int,
                                         num_groups: int,
                                         block_b: int = 128,
                                         block_n: int = 512,
                                         block_d: int = 128,
                                         interpret: bool = True,
                                         use_tpu_prng: bool = False,
                                         dtype=jnp.float32, mask=None):
    """GROUP BY bootstrap moments: one implicit Poisson(1) stream, G keyed
    accumulator slots.

    values: (n, d) f32, pre-padded to block multiples; ``group_ids``
    (1, n) f32 of integer keys 0..num_groups-1 (zero-padded — padding
    columns carry zero weight via ``n_valid``/``mask`` so their key is
    irrelevant).  Returns (w_tot (B, G), s1 (B, G, d), s2 (B, G, d)).

    VMEM note: the s1/s2 accumulator blocks are (block_b, G, d) — G scales
    the resident accumulators, so large G·d wants a smaller ``block_b``
    (same escape hatch as weighted_hist's ``block_bins``; see ROADMAP
    Known modeling limits)."""
    n, d = values.shape
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))
    assert group_ids.shape == (1, n), group_ids.shape

    grid = (B // block_b, n // block_n)
    G = num_groups
    kern = functools.partial(_fpm_grouped_kernel, block_b=block_b,
                             block_n=block_n, num_groups=G,
                             use_tpu_prng=use_tpu_prng, dtype=dtype,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((block_n, d), lambda i, k: (k, 0)),
        pl.BlockSpec((1, block_n), lambda i, k: (0, k)),
    ]
    operands = [scal, values, group_ids]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, k: (0, k)))
        operands.append(mask)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, G), lambda i, k: (i, 0)),
            pl.BlockSpec((block_b, G, d), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((block_b, G, d), lambda i, k: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, G), jnp.float32),
            jax.ShapeDtypeStruct((B, G, d), jnp.float32),
            jax.ShapeDtypeStruct((B, G, d), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


# ============================================================================
# streaming variant: double-buffered async HBM->VMEM copies on the n axis
# ============================================================================
def _fpm_stream_kernel(scal_ref, x_hbm_ref, *refs, block_b: int,
                       block_n: int, nt: int, use_tpu_prng: bool,
                       dtype=jnp.float32, has_mask: bool = False):
    """emit_pipeline-style n-axis streaming: x lives in HBM (memory_space
    ANY); each (block_n, d) tile is DMA'd into one slot of a 2-deep VMEM
    scratch while the other slot's tile is being contracted, so the HBM
    load of n-tile t+1 overlaps compute on tile t.  Weight keying, masking
    and f32 accumulation order are identical to ``_fpm_kernel`` with the
    n axis as the last grid dimension — outputs are bit-identical."""
    if has_mask:
        m_hbm_ref = refs[0]
        wtot_ref, s1_ref, s2_ref, xs, xsem, ms, msem = refs[1:]
    else:
        m_hbm_ref = None
        wtot_ref, s1_ref, s2_ref, xs, xsem = refs
    i = pl.program_id(0)        # B-tile index

    wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)
    s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
    s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    def x_dma(slot, t):
        return pltpu.make_async_copy(
            x_hbm_ref.at[pl.ds(t * block_n, block_n), :],
            xs.at[slot], xsem.at[slot])

    def m_dma(slot, t):
        return pltpu.make_async_copy(
            m_hbm_ref.at[:, pl.ds(t * block_n, block_n)],
            ms.at[slot], msem.at[slot])

    x_dma(0, 0).start()
    if has_mask:
        m_dma(0, 0).start()

    def body(t, _):
        slot = jax.lax.rem(t, 2)
        nxt = jax.lax.rem(t + 1, 2)

        @pl.when(t + 1 < nt)
        def _prefetch():
            x_dma(nxt, t + 1).start()
            if has_mask:
                m_dma(nxt, t + 1).start()

        x_dma(slot, t).wait()
        valid = None
        if has_mask:
            m_dma(slot, t).wait()
            valid = ms[slot]
        w = _poisson_tile(scal_ref[0], i, t, (block_b, block_n),
                          scal_ref[1], block_n, use_tpu_prng, valid=valid)
        x = xs[slot].astype(jnp.float32)
        s1_ref[...] += jax.lax.dot(w.astype(dtype), x.astype(dtype),
                                   preferred_element_type=jnp.float32)
        s2_ref[...] += jax.lax.dot(w.astype(dtype), (x * x).astype(dtype),
                                   preferred_element_type=jnp.float32)
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)
        return ()

    jax.lax.fori_loop(0, nt, body, ())


@functools.partial(jax.jit,
                   static_argnames=("B", "block_b", "block_n", "block_d",
                                    "interpret", "use_tpu_prng", "dtype"))
def fused_poisson_moments_stream_kernel(seed: jax.Array, n_valid: jax.Array,
                                        values: jax.Array, B: int,
                                        block_b: int = 128,
                                        block_n: int = 512,
                                        block_d: int = 128,
                                        interpret: bool = True,
                                        use_tpu_prng: bool = False,
                                        dtype=jnp.float32, mask=None):
    """Double-buffered streaming entry: same contract (and bit-identical
    outputs) as ``fused_poisson_moments_kernel``, but x (and the optional
    mask) stay in HBM and the kernel overlaps each tile's DMA with the
    previous tile's contraction — the on-device mirror of the host-side
    driver in core/streaming.py.  The full lane-padded d is kept resident
    (``block_d`` only asserts the lane padding), so VMEM holds
    2·block_n·d + the (block_b, d) accumulators."""
    n, d = values.shape
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))
    nt = n // block_n

    kern = functools.partial(_fpm_stream_kernel, block_b=block_b,
                             block_n=block_n, nt=nt,
                             use_tpu_prng=use_tpu_prng, dtype=dtype,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [scal, values]
    scratch = [pltpu.VMEM((2, block_n, d), jnp.float32),
               pltpu.SemaphoreType.DMA((2,))]
    if mask is not None:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(mask)
        scratch += [pltpu.VMEM((2, 1, block_n), jnp.float32),
                    pltpu.SemaphoreType.DMA((2,))]
    return pl.pallas_call(
        kern,
        grid=(B // block_b,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
