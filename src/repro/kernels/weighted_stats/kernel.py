"""Fused weighted-moments Pallas kernel.

Computes, for every bootstrap resample b (a row of the weight matrix W):

    w_tot[b] = Σ_i W[b,i]
    s1[b,:]  = Σ_i W[b,i] · X[i,:]
    s2[b,:]  = Σ_i W[b,i] · X[i,:]²

in a single pass: the (bB, bn) weight tile is read once from VMEM and feeds
two MXU contractions (against X and X²) plus a VPU row-sum — 3 outputs for
one HBM read of W, which is what makes the B-resample loop compute-bound
instead of bandwidth-bound (DESIGN.md §2).

Grid: (B/bB, d/bd, n/bn); the contraction axis n is the LAST grid axis so
output tiles are revisited sequentially and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ws_kernel(w_ref, x_ref, wtot_ref, s1_ref, s2_ref):
    j = pl.program_id(1)        # d-tile index
    k = pl.program_id(2)        # n-tile index (contraction)

    w = w_ref[...].astype(jnp.float32)       # (bB, bn)
    x = x_ref[...].astype(jnp.float32)       # (bn, bd)

    @pl.when(k == 0)
    def _init_moments():
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    s1_ref[...] += jax.lax.dot(w, x, preferred_element_type=jnp.float32)
    s2_ref[...] += jax.lax.dot(w, x * x, preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_wtot():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)

    @pl.when(j == 0)
    def _acc_wtot():
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_n", "block_d",
                                    "interpret"))
def weighted_moments_kernel(weights: jax.Array, values: jax.Array,
                            block_b: int = 128, block_n: int = 512,
                            block_d: int = 128, interpret: bool = True):
    """Raw kernel entry: shapes must already be padded to block multiples.

    weights: (B, n) f32;  values: (n, d) f32.
    Returns (w_tot (B, 1), s1 (B, d), s2 (B, d)) — all f32.
    """
    B, n = weights.shape
    n2, d = values.shape
    assert n == n2, (weights.shape, values.shape)
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))

    grid = (B // block_b, d // block_d, n // block_n)
    return pl.pallas_call(
        _ws_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        interpret=interpret,
    )(weights, values)
