"""Fused weighted-moments Pallas kernels.

``weighted_moments_kernel`` computes, for every bootstrap resample b (a row
of the weight matrix W):

    w_tot[b] = Σ_i W[b,i]
    s1[b,:]  = Σ_i W[b,i] · X[i,:]
    s2[b,:]  = Σ_i W[b,i] · X[i,:]²

in a single pass: the (bB, bn) weight tile is read once from VMEM and feeds
two MXU contractions (against X and X²) plus a VPU row-sum — 3 outputs for
one HBM read of W, which is what makes the B-resample loop compute-bound
instead of bandwidth-bound (DESIGN.md §2).

``fused_poisson_moments_kernel`` goes one step further and is the
*matrix-free* bootstrap hot path: the Poisson(1) weight tile is never read
from HBM at all — it is generated inside the kernel from a counter-based
PRNG keyed by ``(seed, b-tile, n-tile)`` (the same threefry/tile discipline
as kernels/poisson_counts, so the implicit weight matrix is bit-identical
to ``poisson_counts(seed, B, n)`` under matching block shapes) and
immediately contracted.  Peak HBM traffic drops from O(B·n) to O(n·d + B·d)
and the (B, n) matrix never exists anywhere.

Grid: (B/bB, d/bd, n/bn); the contraction axis n is the LAST grid axis so
output tiles are revisited sequentially and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.poisson_counts.kernel import (_poisson_from_bits,
                                                 _threefry_bits)


def _ws_kernel(w_ref, x_ref, wtot_ref, s1_ref, s2_ref):
    j = pl.program_id(1)        # d-tile index
    k = pl.program_id(2)        # n-tile index (contraction)

    w = w_ref[...].astype(jnp.float32)       # (bB, bn)
    x = x_ref[...].astype(jnp.float32)       # (bn, bd)

    @pl.when(k == 0)
    def _init_moments():
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    s1_ref[...] += jax.lax.dot(w, x, preferred_element_type=jnp.float32)
    s2_ref[...] += jax.lax.dot(w, x * x, preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_wtot():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)

    @pl.when(j == 0)
    def _acc_wtot():
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_n", "block_d",
                                    "interpret"))
def weighted_moments_kernel(weights: jax.Array, values: jax.Array,
                            block_b: int = 128, block_n: int = 512,
                            block_d: int = 128, interpret: bool = True):
    """Raw kernel entry: shapes must already be padded to block multiples.

    weights: (B, n) f32;  values: (n, d) f32.
    Returns (w_tot (B, 1), s1 (B, d), s2 (B, d)) — all f32.
    """
    B, n = weights.shape
    n2, d = values.shape
    assert n == n2, (weights.shape, values.shape)
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))

    grid = (B // block_b, d // block_d, n // block_n)
    return pl.pallas_call(
        _ws_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        interpret=interpret,
    )(weights, values)


# ============================================================================
# matrix-free path: in-kernel weight generation + contraction
# ============================================================================
def _poisson_tile(seed, i, k, shape, n_valid, block_n: int,
                  use_tpu_prng: bool) -> jax.Array:
    """Poisson(1) weight tile for grid position (i, k), padding masked to 0.

    Identical per-tile seeding to kernels/poisson_counts (same fold-in order,
    same CDF ladder), so the implicit weight matrix equals
    ``poisson_counts(seed, B, n)`` under matching block shapes.
    """
    if use_tpu_prng:
        pltpu.prng_seed(seed, i, k)
        bits = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    else:
        bits = _threefry_bits(seed, i, k, shape)
    w = _poisson_from_bits(bits)
    col = k * block_n + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return jnp.where(col < n_valid, w, 0.0)


def _fpm_kernel(scal_ref, x_ref, wtot_ref, s1_ref, s2_ref, *,
                block_b: int, block_n: int, use_tpu_prng: bool,
                dtype=jnp.float32):
    i = pl.program_id(0)        # B-tile index
    j = pl.program_id(1)        # d-tile index
    k = pl.program_id(2)        # n-tile index (contraction)

    w = _poisson_tile(scal_ref[0], i, k, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng)
    x = x_ref[...].astype(jnp.float32)       # (bn, bd)

    @pl.when(k == 0)
    def _init_moments():
        s1_ref[...] = jnp.zeros(s1_ref.shape, s1_ref.dtype)
        s2_ref[...] = jnp.zeros(s2_ref.shape, s2_ref.dtype)

    # dtype=bf16: inputs enter the MXU in bf16, accumulators stay f32
    # (bf16-multiply/f32-accumulate).  Weights are small Poisson(1)
    # integers — exact in bf16; x² is squared in f32 then rounded ONCE.
    s1_ref[...] += jax.lax.dot(w.astype(dtype), x.astype(dtype),
                               preferred_element_type=jnp.float32)
    s2_ref[...] += jax.lax.dot(w.astype(dtype), (x * x).astype(dtype),
                               preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_wtot():
        wtot_ref[...] = jnp.zeros(wtot_ref.shape, wtot_ref.dtype)

    @pl.when(j == 0)
    def _acc_wtot():
        wtot_ref[...] += jnp.sum(w, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("B", "block_b", "block_n", "block_d",
                                    "interpret", "use_tpu_prng", "dtype"))
def fused_poisson_moments_kernel(seed: jax.Array, n_valid: jax.Array,
                                 values: jax.Array, B: int,
                                 block_b: int = 128, block_n: int = 512,
                                 block_d: int = 128, interpret: bool = True,
                                 use_tpu_prng: bool = False,
                                 dtype=jnp.float32):
    """Matrix-free bootstrap moments: weights generated in VMEM, never in HBM.

    values: (n, d) f32, pre-padded to block multiples (ops.py handles this);
    ``n_valid`` is the unpadded row count — weight columns >= n_valid are
    masked to zero so ``w_tot`` ignores the padding (padded X rows are zero,
    so s1/s2 are unaffected either way).  ``B`` must be a block_b multiple.
    Returns (w_tot (B, 1), s1 (B, d), s2 (B, d)) — all f32.
    """
    n, d = values.shape
    assert B % block_b == 0 and n % block_n == 0 and d % block_d == 0, (
        (B, n, d), (block_b, block_n, block_d))

    grid = (B // block_b, d // block_d, n // block_n)
    kern = functools.partial(_fpm_kernel, block_b=block_b, block_n=block_n,
                             use_tpu_prng=use_tpu_prng, dtype=dtype)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        interpret=interpret,
    )(scal, values)
