"""jit'd public wrapper for weighted_hist: padding + platform dispatch.

backend: None = auto (pallas on TPU, jnp scatter-add elsewhere), "pallas",
"pallas_interpret", "jnp".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.weighted_hist.kernel import weighted_hist_kernel
from repro.kernels.weighted_hist.ref import weighted_hist_scatter_ref
from repro.kernels.weighted_stats.ops import _pad_to


def weighted_histogram(values: jax.Array, weights: jax.Array,
                       lo: jax.Array, hi: jax.Array, nbins: int,
                       backend: str | None = None,
                       block_n: int = 256, block_d: int = 8) -> jax.Array:
    """values (n, d) or (n,), weights (n,), lo/hi (d,) -> (d, nbins) f32.

    The (n, d, nbins) one-hot tensor never materializes on any backend.
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (d,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (d,))
    w = jnp.asarray(weights, jnp.float32)

    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return weighted_hist_scatter_ref(values, w, lo, hi, nbins)

    bn = min(block_n, max(8, n))
    bd = min(block_d, max(1, d))
    xp = _pad_to(_pad_to(values.astype(jnp.float32), bn, 0), bd, 1)
    wp = _pad_to(w[:, None], bn, 0)              # zero rows: no mass
    lop = _pad_to(lo[None, :], bd, 1)
    hip = _pad_to(hi[None, :], bd, 1, value=1.0)  # avoid zero span in padding
    counts = weighted_hist_kernel(xp, wp, lop, hip, nbins,
                                  block_n=bn, block_d=bd,
                                  interpret=(backend != "pallas"))
    return counts[:d, :nbins]
