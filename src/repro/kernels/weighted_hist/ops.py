"""jit'd public wrappers for weighted_hist: padding + platform dispatch.

backend: None = auto (pallas on TPU, jnp scatter-add / scan elsewhere),
"pallas", "pallas_interpret", "jnp"/"scan".

Two entry points:

* ``weighted_histogram``   — single-state sketch from explicit weights.
* ``fused_poisson_hist``   — matrix-free bootstrap sketch: B per-resample
  (d, nbins) histograms under implicit in-kernel Poisson(1) weights drawn
  with the shared ``implicit_weight_tile`` discipline, so neither the
  (B, n) weight matrix nor the (n, d, nbins) one-hot ever materializes;
  peak live state is O(B·d·nbins) plus one (block_n, d·nbins) tile-local
  one-hot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.weighted_hist.kernel import (
    fused_poisson_hist_binblocked_kernel, fused_poisson_hist_kernel,
    weighted_hist_kernel)
from repro.kernels.weighted_hist.ref import (_bin_indices, finite_mass_mask,
                                             weighted_hist_scatter_ref)
from repro.kernels.weighted_stats.ops import (_pad_to, implicit_weight_tile,
                                              weight_tile_blocks)


def weighted_histogram(values: jax.Array, weights: jax.Array,
                       lo: jax.Array, hi: jax.Array, nbins: int,
                       backend: str | None = None,
                       block_n: int = 256, block_d: int = 8) -> jax.Array:
    """values (n, d) or (n,), weights (n,), lo/hi (d,) -> (d, nbins) f32.

    The (n, d, nbins) one-hot tensor never materializes on any backend.
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (d,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (d,))
    w = jnp.asarray(weights, jnp.float32)

    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return weighted_hist_scatter_ref(values, w, lo, hi, nbins)

    bn = min(block_n, max(8, n))
    bd = min(block_d, max(1, d))
    xp = _pad_to(_pad_to(values.astype(jnp.float32), bn, 0), bd, 1)
    wp = _pad_to(w[:, None], bn, 0)              # zero rows: no mass
    lop = _pad_to(lo[None, :], bd, 1)
    hip = _pad_to(hi[None, :], bd, 1, value=1.0)  # avoid zero span in padding
    counts = weighted_hist_kernel(xp, wp, lop, hip, nbins,
                                  block_n=bn, block_d=bd,
                                  interpret=(backend != "pallas"))
    return counts[:d, :nbins]


# ============================================================================
# matrix-free bootstrap path
# ============================================================================
@functools.partial(jax.jit, static_argnames=("B", "nbins", "block_b",
                                             "block_n"))
def _fused_hist_scan(seed, n_valid, xp, lo, hi, B, nbins, block_b, block_n,
                     maskp=None):
    """CPU lowering of the fused kernel: scan over n-tiles, weights from the
    SHARED ``implicit_weight_tile`` (same per-tile threefry bits and CDF
    ladder as every fused path), binning from the shared ref rule.

    Accumulation is a per-tile scatter-add (O(B·bn·d) work) rather than the
    kernel's one-hot MXU dots (O(B·bn·d·nbins) flops — the right trade on
    a TPU where the one-hot stays in VMEM, ~3× the wall time on XLA:CPU).
    The two lowerings are still BIT-identical: histogram counts are sums of
    small integer weights, exact in f32 under any summation order.  Peak
    live state per step is the (B, block_n) weight tile plus the
    (B, d·nbins) accumulator — the (n, d, nbins) tensor never exists."""
    n, d = xp.shape
    nt = n // block_n
    xc = xp.reshape(nt, block_n, d)
    maskc = None if maskp is None else maskp.reshape(nt, block_n)

    def body(counts, t):
        w = implicit_weight_tile(seed, n_valid, t, B,
                                 block_b, block_n,
                                 valid=None if maskc is None
                                 else maskc[t])              # (B, bn)
        xt = xc[t]
        idx = _bin_indices(xt, lo[None, :], hi[None, :], nbins)  # (bn, d)
        flat = (idx + jnp.arange(d, dtype=jnp.int32)[None, :]
                * nbins).reshape(-1)                         # (bn·d,)
        wm = (w[:, :, None] * finite_mass_mask(xt)[None, :, :]
              ).reshape(B, block_n * d)
        return counts.at[:, flat].add(wm), None

    init = jnp.zeros((B, d * nbins), jnp.float32)
    counts, _ = jax.lax.scan(body, init, jnp.arange(nt, dtype=jnp.int32))
    return counts.reshape(B, d, nbins)


@functools.partial(jax.jit, static_argnames=("B", "nbins", "num_groups",
                                             "block_b", "block_n"))
def _grouped_fused_hist_scan(seed, n_valid, xp, gp, lo, hi, B, nbins,
                             num_groups, block_b, block_n, maskp=None):
    """GROUP BY sketch lowering: one implicit weight tile per step, keyed
    into ``num_groups`` (d, nbins) sketch slots by exact 0/1 key-mask
    multiplies — the accumulator is the ungrouped (B, d·nbins) scatter
    target replicated per key (flattened to (B, G·d·nbins)), with each
    key's scatter using the SAME bin indices and finite-mass mask on
    ``w * (gid == g)``.  Counts are sums of small integer weights — exact
    in f32 — so slot g is bitwise ``_fused_hist_scan`` under
    ``maskp = (gid == g)``.  Neither the (n, G) one-hot nor any (B, n)
    matrix materializes."""
    n, d = xp.shape
    nt = n // block_n
    xc = xp.reshape(nt, block_n, d)
    gc = gp.reshape(nt, block_n)
    maskc = None if maskp is None else maskp.reshape(nt, block_n)

    def body(counts, t):
        w = implicit_weight_tile(seed, n_valid, t, B,
                                 block_b, block_n,
                                 valid=None if maskc is None
                                 else maskc[t])              # (B, bn)
        xt = xc[t]
        gid = gc[t]
        idx = _bin_indices(xt, lo[None, :], hi[None, :], nbins)  # (bn, d)
        flat = (idx + jnp.arange(d, dtype=jnp.int32)[None, :]
                * nbins).reshape(-1)                         # (bn·d,)
        fm = finite_mass_mask(xt)                            # (bn, d)
        for g in range(num_groups):
            wg = w * (gid == g).astype(jnp.float32)[None, :]
            wm = (wg[:, :, None] * fm[None, :, :]).reshape(B, block_n * d)
            counts = counts.at[:, g * d * nbins + flat].add(wm)
        return counts, None

    init = jnp.zeros((B, num_groups * d * nbins), jnp.float32)
    counts, _ = jax.lax.scan(body, init, jnp.arange(nt, dtype=jnp.int32))
    return counts.reshape(B, num_groups, d, nbins)


def fused_poisson_hist(seed, values: jax.Array, lo, hi, nbins: int, B: int,
                       backend: str | None = None,
                       block_b: int = 128, block_n: int = 512,
                       n_valid=None, valid_mask=None,
                       block_bins: int | None = None,
                       group_ids=None,
                       num_groups: int | None = None) -> jax.Array:
    """Matrix-free bootstrap histogram sketch from an int32 seed.

    values (n, d) or (n,), lo/hi scalar or (d,) -> (B, d, nbins) f32 counts
    where the implicit weights are Poisson(1), keyed per (block_b, block_n)
    tile by (seed, b-tile, n-tile) — the same matrix as
    ``weighted_stats.ops.implicit_weights(seed, B, n)``, which is what lets
    Quantile share one stream with every other fused statistic (common
    random numbers / delta maintenance).

    ``n_valid`` (traced scalar, default n) masks weight columns >= n_valid
    to zero — without it the zero-padded tail would land real mass in each
    dimension's bin 0.  ``valid_mask`` (traced (n,) f32 of exact 0.0/1.0)
    multiplies the weight tiles — arbitrary interior validity holes; a
    prefix-shaped mask reproduces the ``n_valid`` result bit for bit
    (see ``implicit_weight_tile``).

    ``block_bins`` (Pallas backends only; a 128 multiple) tiles the
    d·nbins OUTPUT axis: each kernel instance keeps only a
    (block_b, block_bins) output window in VMEM instead of the whole
    (block_b, d·out_bins) row block — the knob for large d·nbins where the
    default kernel's output block would not fit VMEM.  The weight tile is
    regenerated per output window from the same (seed, b-tile, n-tile)
    keying, so results are identical; the trade is PRNG recompute for
    output residency.  ``None`` (default) keeps the single-block kernel.

    ``group_ids`` (traced (n,) integer keys 0..num_groups-1) switches on
    the GROUP BY path: the SAME implicit weight stream feeds ``num_groups``
    keyed sketch slots and the result gains a G axis —
    (B, num_groups, d, nbins) — with slot g BITWISE equal to the ungrouped
    call under ``valid_mask = (group_ids == g)``.  The grouped sketch is
    scan-lowered (the G·d·nbins output would multiply the Pallas kernel's
    VMEM-resident one-hot output block; see ROADMAP Known modeling
    limits) — auto backend resolves to "scan" and an explicit Pallas
    backend raises.

    backend: None = auto (pallas on TPU, scan elsewhere), "pallas",
    "pallas_interpret", "scan".
    """
    if values.ndim == 1:
        values = values[:, None]
    n, d = values.shape
    if backend is None:
        backend = ("scan" if group_ids is not None
                   else "pallas" if jax.default_backend() == "tpu"
                   else "scan")
    if backend not in ("pallas", "pallas_interpret", "scan"):
        raise ValueError(f"unknown fused_poisson_hist backend: {backend!r}")
    if group_ids is not None and backend != "scan":
        raise ValueError(
            "fused_poisson_hist(group_ids=...) is scan-only: the grouped "
            "sketch's G·d·nbins output block does not fit the Pallas "
            "kernel's VMEM residency model (tile the keys or use "
            f"backend='scan', got backend={backend!r})")
    if n_valid is None:
        n_valid = n

    bb, bn = weight_tile_blocks(B, n, block_b, block_n)
    Bp = B + (-B) % bb
    seed = jnp.asarray(seed, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (d,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (d,))
    xp = _pad_to(values.astype(jnp.float32), bn, 0)
    mp = None
    if valid_mask is not None:
        mp = _pad_to(jnp.asarray(valid_mask, jnp.float32).reshape(n), bn, 0)

    if group_ids is not None:
        if num_groups is None or int(num_groups) < 1:
            raise ValueError("group_ids requires num_groups >= 1, got "
                             f"{num_groups!r}")
        # padding columns keep key 0 — their weights are exactly zero via
        # the n_valid prefix mask / zero-padded valid_mask.
        gp = _pad_to(jnp.asarray(group_ids, jnp.float32).reshape(n), bn, 0)
        counts = _grouped_fused_hist_scan(seed, n_valid, xp, gp, lo, hi,
                                          Bp, nbins, int(num_groups),
                                          bb, bn, maskp=mp)
        return counts[:B]

    if backend == "scan":
        counts = _fused_hist_scan(seed, n_valid, xp, lo, hi, Bp, nbins,
                                  bb, bn, maskp=mp)
        return counts[:B]

    mp2 = None if mp is None else mp[None, :]
    # lane-width discipline (same as the other fused kernels): x/lo/hi are
    # padded to 128 lanes; only the d real columns are ever contracted.
    if block_bins is not None:
        # output-tiled variant: x transposed so the BlockSpec (not a traced
        # lane slice) selects each dimension's value row.
        counts = fused_poisson_hist_binblocked_kernel(
            seed, n_valid, xp.T, lo[:, None], hi[:, None], Bp, nbins,
            d_valid=d, block_bins=block_bins, block_b=bb, block_n=bn,
            interpret=(backend != "pallas"),
            use_tpu_prng=(backend == "pallas"), mask=mp2)
        out_bins = nbins + (-nbins) % block_bins
        return counts.reshape(Bp, d, out_bins)[:B, :, :nbins]
    xpp = _pad_to(xp, 128, 1)
    lop = _pad_to(lo[None, :], 128, 1)
    hip = _pad_to(hi[None, :], 128, 1, value=1.0)  # nonzero padding span
    counts = fused_poisson_hist_kernel(
        seed, n_valid, xpp, lop, hip, Bp, nbins, d_valid=d,
        block_b=bb, block_n=bn,
        interpret=(backend != "pallas"),
        use_tpu_prng=(backend == "pallas"), mask=mp2)
    out_bins = nbins + (-nbins) % 128
    return counts.reshape(Bp, d, out_bins)[:B, :, :nbins]
