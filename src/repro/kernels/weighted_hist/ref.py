"""Oracles for weighted_hist.

``weighted_hist_onehot_ref`` is the original memory-blowup formulation
(materializes the (n, d, nbins) one-hot in HBM) — kept strictly as a
correctness oracle; ``weighted_hist_scatter_ref`` is the O(n·d) scatter-add
formulation that reduce_api.Quantile now uses as its default jnp path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _bin_indices(values: jax.Array, lo: jax.Array, hi: jax.Array,
                 nbins: int) -> jax.Array:
    x = values.astype(jnp.float32)                       # (n, d)
    span = hi - lo + _EPS
    return jnp.clip(((x - lo) / span * nbins).astype(jnp.int32),
                    0, nbins - 1)                        # (n, d)


def weighted_hist_onehot_ref(values: jax.Array, weights: jax.Array,
                             lo: jax.Array, hi: jax.Array,
                             nbins: int) -> jax.Array:
    """(n, d) values, (n,) weights, (d,) lo/hi -> (d, nbins) counts."""
    idx = _bin_indices(values, lo[None, :], hi[None, :], nbins)
    onehot = jax.nn.one_hot(idx, nbins, dtype=jnp.float32)   # (n, d, nbins)
    return jnp.einsum("n,ndb->db", weights.astype(jnp.float32), onehot)


def weighted_hist_scatter_ref(values: jax.Array, weights: jax.Array,
                              lo: jax.Array, hi: jax.Array,
                              nbins: int) -> jax.Array:
    """Same result via a flattened scatter-add: O(n·d) memory, one dispatch."""
    idx = _bin_indices(values, lo[None, :], hi[None, :], nbins)  # (n, d)
    d = idx.shape[1]
    flat = idx + jnp.arange(d, dtype=jnp.int32)[None, :] * nbins
    w = jnp.broadcast_to(weights.astype(jnp.float32)[:, None], idx.shape)
    counts = jnp.zeros((d * nbins,), jnp.float32)
    counts = counts.at[flat.reshape(-1)].add(w.reshape(-1))
    return counts.reshape(d, nbins)
