"""Oracles + shared binning rule for weighted_hist.

``weighted_hist_onehot_ref`` is the original memory-blowup formulation
(materializes the (n, d, nbins) one-hot in HBM) — kept strictly as a
correctness oracle; ``weighted_hist_scatter_ref`` is the O(n·d) scatter-add
formulation that reduce_api.Quantile uses as its default jnp path.

Out-of-range / non-finite policy (shared by EVERY histogram path — the
Pallas kernels import ``_bin_indices``/``finite_mass_mask`` from here so the
rule cannot drift between lowerings):

* out-of-range values are CLIPPED into the edge bins: x <= lo lands in bin
  0, x >= hi (including x == hi exactly, and ±inf) lands in bin nbins-1 —
  a fixed-range sketch must not silently lose tail mass;
* NaN values are DROPPED: their weight contributes to no bin (a NaN has no
  defined bin, and f32→int32 casts of NaN are platform-dependent — the mask
  is what keeps kernel, scan and scatter lowerings bit-consistent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _bin_indices(values: jax.Array, lo: jax.Array, hi: jax.Array,
                 nbins: int) -> jax.Array:
    """Bin index per element, CLIPPED into [0, nbins-1].

    The clip happens in f32 BEFORE the int cast (so ±inf deterministically
    hit the edge bins instead of going through an undefined f32→int32
    cast), then again after (so the garbage a NaN cast produces still
    indexes in-bounds — its mass is zeroed by ``finite_mass_mask``)."""
    x = values.astype(jnp.float32)                       # (n, d)
    span = hi - lo + _EPS
    idx_f = jnp.clip((x - lo) / span * nbins, 0.0, float(nbins - 1))
    return jnp.clip(idx_f.astype(jnp.int32), 0, nbins - 1)


def finite_mass_mask(values: jax.Array) -> jax.Array:
    """1.0 where the value carries histogram mass, 0.0 for NaN."""
    return jnp.where(jnp.isnan(values), 0.0, 1.0).astype(jnp.float32)


def weighted_hist_onehot_ref(values: jax.Array, weights: jax.Array,
                             lo: jax.Array, hi: jax.Array,
                             nbins: int) -> jax.Array:
    """(n, d) values, (n,) weights, (d,) lo/hi -> (d, nbins) counts."""
    idx = _bin_indices(values, lo[None, :], hi[None, :], nbins)
    onehot = jax.nn.one_hot(idx, nbins, dtype=jnp.float32)   # (n, d, nbins)
    onehot = onehot * finite_mass_mask(values)[:, :, None]
    return jnp.einsum("n,ndb->db", weights.astype(jnp.float32), onehot)


def weighted_hist_scatter_ref(values: jax.Array, weights: jax.Array,
                              lo: jax.Array, hi: jax.Array,
                              nbins: int) -> jax.Array:
    """Same result via a flattened scatter-add: O(n·d) memory, one dispatch."""
    idx = _bin_indices(values, lo[None, :], hi[None, :], nbins)  # (n, d)
    d = idx.shape[1]
    flat = idx + jnp.arange(d, dtype=jnp.int32)[None, :] * nbins
    w = jnp.broadcast_to(weights.astype(jnp.float32)[:, None], idx.shape)
    w = w * finite_mass_mask(values)
    counts = jnp.zeros((d * nbins,), jnp.float32)
    counts = counts.at[flat.reshape(-1)].add(w.reshape(-1))
    return counts.reshape(d, nbins)
