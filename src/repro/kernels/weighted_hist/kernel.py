"""Fused weighted-histogram Pallas kernel (mergeable quantile sketch).

Computes, per value dimension c, a fixed-range weighted histogram

    counts[c, b] = Σ_i  w[i] · 1[ bin(x[i, c]) = b ]

without ever materializing the (n, d, nbins) one-hot tensor the naive
``jax.nn.one_hot`` + einsum path builds in HBM (the §6.2 median/quantile
memory blowup).  Each (bn, bd) value tile is binned in VMEM and the per-bin
mass is accumulated with one (1, bn) × (bn, nbins) MXU contraction per
dimension column — the one-hot exists only tile-at-a-time in VMEM.

Grid: (d/bd, n/bn); the n axis is LAST so each (bd, nbins) output tile is
revisited sequentially and accumulated in place.  Histogram counts are a
mergeable synopsis (Jestes et al., wavelet histograms on MapReduce), so
per-shard outputs psum cleanly — same merge discipline as
``reduce_api.HistogramState``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


def _wh_kernel(x_ref, w_ref, lo_ref, hi_ref, out_ref, *, nbins: int,
               out_bins: int, block_d: int):
    k = pl.program_id(1)        # n-tile index (accumulation axis)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    x = x_ref[...].astype(jnp.float32)           # (bn, bd)
    w = w_ref[...].astype(jnp.float32)           # (bn, 1)
    lo = lo_ref[...]                             # (1, bd)
    hi = hi_ref[...]
    span = hi - lo + jnp.float32(_EPS)
    # bin against the TRUE nbins; out_bins >= nbins is only lane padding,
    # so bins [nbins, out_bins) stay empty and slicing them off is exact.
    idx = jnp.clip(((x - lo) / span * nbins).astype(jnp.int32),
                   0, nbins - 1)                 # (bn, bd)

    bn = x.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (bn, out_bins), 1)
    wt = w.reshape(1, bn)
    for c in range(block_d):                     # static unroll, bd is small
        onehot = (idx[:, c:c + 1] == bins).astype(jnp.float32)  # (bn, ob)
        out_ref[c:c + 1, :] += jax.lax.dot(
            wt, onehot, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("nbins", "block_n", "block_d",
                                    "interpret"))
def weighted_hist_kernel(values: jax.Array, weights: jax.Array,
                         lo: jax.Array, hi: jax.Array, nbins: int,
                         block_n: int = 256, block_d: int = 8,
                         interpret: bool = True) -> jax.Array:
    """Raw kernel entry: shapes must already be padded to block multiples.

    values (n, d) f32, weights (n, 1) f32 (zero-padded rows contribute
    nothing), lo/hi (1, d) f32.  ``nbins`` is the true bin count; the
    output's last dim is padded up to the 128-lane multiple (extra bins are
    always zero — callers slice [:, :nbins]).  Returns (d, out_bins) f32.
    """
    n, d = values.shape
    assert n % block_n == 0 and d % block_d == 0, ((n, d), (block_n, block_d))
    out_bins = nbins + (-nbins) % 128

    grid = (d // block_d, n // block_n)
    kern = functools.partial(_wh_kernel, nbins=nbins, out_bins=out_bins,
                             block_d=block_d)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda j, k: (k, j)),
            pl.BlockSpec((block_n, 1), lambda j, k: (k, 0)),
            pl.BlockSpec((1, block_d), lambda j, k: (0, j)),
            pl.BlockSpec((1, block_d), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_d, out_bins), lambda j, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((d, out_bins), jnp.float32),
        interpret=interpret,
    )(values, weights, lo, hi)
