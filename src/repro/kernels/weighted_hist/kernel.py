"""Fused weighted-histogram Pallas kernels (mergeable quantile sketch).

``weighted_hist_kernel`` computes, per value dimension c, a fixed-range
weighted histogram

    counts[c, b] = Σ_i  w[i] · 1[ bin(x[i, c]) = b ]

without ever materializing the (n, d, nbins) one-hot tensor the naive
``jax.nn.one_hot`` + einsum path builds in HBM (the §6.2 median/quantile
memory blowup).  Each (bn, bd) value tile is binned in VMEM and the per-bin
mass is accumulated with one (1, bn) × (bn, nbins) MXU contraction per
dimension column — the one-hot exists only tile-at-a-time in VMEM.

``fused_poisson_hist_kernel`` is the matrix-free bootstrap path for
Quantile/Median: the B Poisson(1) resample weight rows are generated
*inside* the kernel from the same counter-based PRNG tile discipline as
kernels/weighted_stats.fused_poisson_moments (keyed by (seed, b-tile,
n-tile), so the implicit weight matrix is bit-identical to
``implicit_weights(seed, B, n)`` under matching blocks) and contracted
against the tile-local one-hot — neither the (B, n) weight matrix nor the
(n, d, nbins) one-hot ever exists in HBM; peak live state is the
O(B·d·nbins) per-resample histogram accumulators.

Binning rule (clip out-of-range into edge bins, drop NaN mass) is imported
from ref.py so kernel, scan lowering and scatter path can never drift.

Grids: ``(d/bd, n/bn)`` for the single-state pass; ``(B/bB, n/bn)`` for the
fused bootstrap pass with the contraction axis n LAST so output tiles are
revisited sequentially and accumulated in place (same discipline as
weighted_stats / kmeans_assign).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.weighted_hist.ref import _bin_indices, finite_mass_mask
from repro.kernels.weighted_stats.kernel import _poisson_tile


def _wh_kernel(x_ref, w_ref, lo_ref, hi_ref, out_ref, *, nbins: int,
               out_bins: int, block_d: int):
    k = pl.program_id(1)        # n-tile index (accumulation axis)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    x = x_ref[...].astype(jnp.float32)           # (bn, bd)
    w = w_ref[...].astype(jnp.float32)           # (bn, 1)
    # bin against the TRUE nbins; out_bins >= nbins is only lane padding,
    # so bins [nbins, out_bins) stay empty and slicing them off is exact.
    idx = _bin_indices(x, lo_ref[...], hi_ref[...], nbins)      # (bn, bd)
    mass = finite_mass_mask(x)                   # (bn, bd); NaN carries none

    bn = x.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (bn, out_bins), 1)
    wt = w.reshape(1, bn)
    for c in range(block_d):                     # static unroll, bd is small
        onehot = (idx[:, c:c + 1] == bins).astype(jnp.float32)  # (bn, ob)
        out_ref[c:c + 1, :] += jax.lax.dot(
            wt * mass[:, c].reshape(1, bn), onehot,
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("nbins", "block_n", "block_d",
                                    "interpret"))
def weighted_hist_kernel(values: jax.Array, weights: jax.Array,
                         lo: jax.Array, hi: jax.Array, nbins: int,
                         block_n: int = 256, block_d: int = 8,
                         interpret: bool = True) -> jax.Array:
    """Raw kernel entry: shapes must already be padded to block multiples.

    values (n, d) f32, weights (n, 1) f32 (zero-padded rows contribute
    nothing), lo/hi (1, d) f32.  ``nbins`` is the true bin count; the
    output's last dim is padded up to the 128-lane multiple (extra bins are
    always zero — callers slice [:, :nbins]).  Returns (d, out_bins) f32.
    """
    n, d = values.shape
    assert n % block_n == 0 and d % block_d == 0, ((n, d), (block_n, block_d))
    out_bins = nbins + (-nbins) % 128

    grid = (d // block_d, n // block_n)
    kern = functools.partial(_wh_kernel, nbins=nbins, out_bins=out_bins,
                             block_d=block_d)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda j, k: (k, j)),
            pl.BlockSpec((block_n, 1), lambda j, k: (k, 0)),
            pl.BlockSpec((1, block_d), lambda j, k: (0, j)),
            pl.BlockSpec((1, block_d), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_d, out_bins), lambda j, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((d, out_bins), jnp.float32),
        interpret=interpret,
    )(values, weights, lo, hi)


# ============================================================================
# matrix-free bootstrap path: in-kernel weight generation + binning
# ============================================================================
def _fph_kernel(scal_ref, x_ref, lo_ref, hi_ref, *refs, nbins: int,
                out_bins: int, d: int, block_b: int, block_n: int,
                use_tpu_prng: bool, has_mask: bool = False):
    if has_mask:
        m_ref, out_ref = refs
    else:
        m_ref, (out_ref,) = None, refs
    i = pl.program_id(0)        # B-tile index
    t = pl.program_id(1)        # n-tile index (contraction)

    w = _poisson_tile(scal_ref[0], i, t, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])  # (bB, bn)
    x = x_ref[...].astype(jnp.float32)                       # (bn, dp)
    idx = _bin_indices(x, lo_ref[...], hi_ref[...], nbins)   # (bn, dp)
    mass = finite_mass_mask(x)                               # (bn, dp)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    bn = x.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (bn, out_bins), 1)
    # per-dim masked one-hot: out[:, c·ob:(c+1)·ob] is dimension c's (B,
    # nbins) counts — d lane-aligned dots reusing the one (bB, bn) weight
    # tile, same layout discipline as fused_poisson_kmeans' kp·dp columns.
    # Only the d REAL columns get a dot; the lane padding of x (dp >= d,
    # ops.py pads to 128 like every other fused kernel) is never read.
    for c in range(d):
        onehot = ((idx[:, c:c + 1] == bins).astype(jnp.float32)
                  * mass[:, c:c + 1])                        # (bn, ob)
        out_ref[:, c * out_bins:(c + 1) * out_bins] += jax.lax.dot(
            w, onehot, preferred_element_type=jnp.float32)


def _fph_binblocked_kernel(scal_ref, xt_ref, lo_ref, hi_ref, *refs,
                           nbins: int, nb_j: int, block_bins: int,
                           block_b: int, block_n: int, use_tpu_prng: bool,
                           has_mask: bool = False):
    """Output-tiled variant of ``_fph_kernel``: grid axis 1 enumerates
    (dimension, bin-block) pairs ``cj = c·nb_j + j`` so each kernel
    instance holds only a (block_b, block_bins) slice of the output in
    VMEM instead of the whole (block_b, d·out_bins) row block — the
    ROADMAP "TPU tiling of the fused hist kernel's output" knob for large
    d·nbins.

    The weight tile is keyed by (seed, i, t) only — regenerating it per
    (c, j) cell trades PRNG recompute for VMEM residency, and keeps the
    implicit weight matrix bit-identical to every other fused path.  x
    arrives TRANSPOSED as (dp, n) so the value row for dimension c is
    selected by the BlockSpec (no traced lane slicing in-kernel); lo/hi
    arrive as (dp, 1) blocks selected the same way.
    """
    if has_mask:
        m_ref, out_ref = refs
    else:
        m_ref, (out_ref,) = None, refs
    i = pl.program_id(0)        # B-tile index
    cj = pl.program_id(1)       # flattened (dim, bin-block) index
    t = pl.program_id(2)        # n-tile index (contraction)
    j = cj % nb_j               # bin-block within the dimension

    w = _poisson_tile(scal_ref[0], i, t, (block_b, block_n), scal_ref[1],
                      block_n, use_tpu_prng,
                      valid=None if m_ref is None else m_ref[...])  # (bB, bn)
    x = xt_ref[...].astype(jnp.float32)                       # (1, bn)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    # bin against the TRUE nbins, then localize into this block's window
    idx = _bin_indices(x, lo_ref[...], hi_ref[...], nbins)    # (1, bn)
    mass = finite_mass_mask(x)                                # (1, bn)
    bn = x.shape[1]
    local = (idx - j * block_bins).reshape(bn, 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (bn, block_bins), 1)
    onehot = (local == bins).astype(jnp.float32) * mass.reshape(bn, 1)
    out_ref[...] += jax.lax.dot(w, onehot,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("B", "nbins", "d_valid", "block_b",
                                    "block_n", "block_bins", "interpret",
                                    "use_tpu_prng"))
def fused_poisson_hist_binblocked_kernel(seed: jax.Array, n_valid: jax.Array,
                                         values_t: jax.Array, lo: jax.Array,
                                         hi: jax.Array, B: int, nbins: int,
                                         d_valid: int, block_bins: int,
                                         block_b: int = 128,
                                         block_n: int = 512,
                                         interpret: bool = True,
                                         use_tpu_prng: bool = False,
                                         mask=None) -> jax.Array:
    """Raw entry for the output-tiled fused hist kernel.

    values_t is the TRANSPOSED (dp, n) value matrix (n pre-padded to
    block_n, dp the lane-padded dimension count); lo/hi are (dp, 1).
    ``block_bins`` (a 128 multiple) is the per-instance output window —
    out_bins = nbins padded up to a block_bins multiple, nb_j = out_bins /
    block_bins output blocks per dimension (>= 2 is the interesting
    regime).  Returns (B, d_valid·out_bins) f32; callers reshape to
    (B, d_valid, out_bins) and slice [..., :nbins] (bins past the true
    nbins stay empty: binning is against the true nbins).
    """
    dp, n = values_t.shape
    assert B % block_b == 0 and n % block_n == 0, ((B, n), (block_b, block_n))
    assert block_bins % 128 == 0 and block_bins > 0, block_bins
    assert d_valid <= dp, (d_valid, dp)
    out_bins = nbins + (-nbins) % block_bins
    nb_j = out_bins // block_bins

    kern = functools.partial(_fph_binblocked_kernel, nbins=nbins, nb_j=nb_j,
                             block_bins=block_bins, block_b=block_b,
                             block_n=block_n, use_tpu_prng=use_tpu_prng,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    grid = (B // block_b, d_valid * nb_j, n // block_n)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, block_n), lambda i, cj, t: (cj // nb_j, t)),
        pl.BlockSpec((1, 1), lambda i, cj, t: (cj // nb_j, 0)),
        pl.BlockSpec((1, 1), lambda i, cj, t: (cj // nb_j, 0)),
    ]
    operands = [scal, values_t, lo, hi]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, cj, t: (0, t)))
        operands.append(mask)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_bins),
                               lambda i, cj, t: (i, cj)),
        out_shape=jax.ShapeDtypeStruct((B, d_valid * out_bins), jnp.float32),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("B", "nbins", "d_valid", "block_b",
                                    "block_n", "interpret", "use_tpu_prng"))
def fused_poisson_hist_kernel(seed: jax.Array, n_valid: jax.Array,
                              values: jax.Array, lo: jax.Array,
                              hi: jax.Array, B: int, nbins: int,
                              d_valid: int,
                              block_b: int = 128, block_n: int = 512,
                              interpret: bool = True,
                              use_tpu_prng: bool = False,
                              mask=None) -> jax.Array:
    """Matrix-free bootstrap histogram sketch: B per-resample (d, nbins)
    count states under implicit in-kernel Poisson(1) weights.

    values (n, dp) f32 pre-padded on n AND on the lane dim (dp = d padded
    to 128, same lane-width discipline as the other fused kernels; ops.py
    handles both); ``d_valid`` is the real dimension count — padded lanes
    are never contracted.  ``n_valid`` masks weight columns >= the unpadded
    row count, so padded rows (which would otherwise land real mass in bin
    0) contribute nothing.  lo/hi are (1, dp) f32 (padding spans must be
    nonzero).  ``B`` must be a ``block_b`` multiple.  Returns
    (B, d_valid·out_bins) f32 with out_bins = nbins lane-padded to 128 —
    callers reshape to (B, d_valid, out_bins) and slice [..., :nbins].
    """
    n, dp = values.shape
    assert B % block_b == 0 and n % block_n == 0, ((B, n), (block_b, block_n))
    assert d_valid <= dp, (d_valid, dp)
    out_bins = nbins + (-nbins) % 128

    kern = functools.partial(_fph_kernel, nbins=nbins, out_bins=out_bins,
                             d=d_valid, block_b=block_b, block_n=block_n,
                             use_tpu_prng=use_tpu_prng,
                             has_mask=mask is not None)
    scal = jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    grid = (B // block_b, n // block_n)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((block_n, dp), lambda i, t: (t, 0)),
        pl.BlockSpec((1, dp), lambda i, t: (0, 0)),
        pl.BlockSpec((1, dp), lambda i, t: (0, 0)),
    ]
    operands = [scal, values, lo, hi]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, t: (0, t)))
        operands.append(mask)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, d_valid * out_bins),
                               lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d_valid * out_bins), jnp.float32),
        interpret=interpret,
    )(*operands)
