"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis crosses DCN; batch shards over it, gradient all-reduce rides it
(optionally bf16-compressed, optim/compression.py).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    """The batch/sample-sharding axes of a production mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
