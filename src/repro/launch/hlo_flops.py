"""Trip-multiplied FLOP/byte accounting from partitioned HLO text.

XLA's ``compiled.cost_analysis()`` does not multiply ``while`` bodies by
their trip counts, so for scan-structured models (layers, loss chunks,
attention blocks) it undercounts by orders of magnitude.  This module
parses every ``dot`` op (operand shapes resolved through each
computation's def lines), computes FLOPs = 2 · |out| · K from the dot
dimension numbers, and multiplies by the enclosing while trip counts
recursively — the HLO-level analogue of the analytic MODEL_FLOPS.

All shapes in the partitioned module are per-chip, so totals are per-chip.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.launch.hlo_analysis import (_CALL_RE, _COLL_RE, _CONST_RE,
                                       _DTYPE_BYTES, _WHILE_RE, _shape_bytes,
                                       split_computations)

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+([\w\-]+)\(")
_SHAPE1_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _dims_of(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE1_RE.search(type_str)
    if not m:
        return ("", [])
    return m.group(1), [int(d) for d in m.group(2).split(",") if d]


def _comp_defs(lines: List[str]) -> Dict[str, str]:
    defs: Dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)
    return defs


def _dot_stats(line: str, defs: Dict[str, str]) -> Tuple[float, float]:
    """(flops, bytes) for one dot line."""
    m = _DEF_RE.match(line)
    if not m or m.group(3) != "dot":
        return 0.0, 0.0
    out_dtype, out_dims = _dims_of(m.group(2))
    ops = _OPERANDS_RE.search(line)
    cons = _LHS_CONTRACT_RE.search(line)
    if not ops or not cons:
        return 0.0, 0.0
    names = _NAME_RE.findall(ops.group(1))
    if len(names) < 2:
        return 0.0, 0.0
    lhs_type = defs.get(names[0], "")
    rhs_type = defs.get(names[1], "")
    _, lhs_dims = _dims_of(lhs_type)
    k = 1
    for c in (int(c) for c in cons.group(1).split(",") if c):
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    flops = 2.0 * out_elems * k
    byts = sum(_shape_bytes(t) for t in (m.group(2), lhs_type, rhs_type))
    return flops, byts


def _trip_of(cond_name: str, comps) -> int:
    if cond_name not in comps:
        return 1
    consts = [int(c) for ln in comps[cond_name].lines
              for c in _CONST_RE.findall(ln)]
    return max(consts) if consts else 1


def dot_flops(hlo: str) -> Dict[str, float]:
    """Per-chip dot FLOPs and dot operand/result bytes, trip-multiplied."""
    comps = split_computations(hlo)
    memo: Dict[str, Tuple[float, float, int]] = {}

    def visit(name: str, stack=()) -> Tuple[float, float, int]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0)
        comp = comps[name]
        defs = _comp_defs(comp.lines)
        flops = byts = 0.0
        ndots = 0
        for line in comp.lines:
            f, b = _dot_stats(line, defs)
            if f > 0:
                flops += f
                byts += b
                ndots += 1
        text = "\n".join(comp.lines)
        for m in _WHILE_RE.finditer(text):
            trip = _trip_of(m.group(1), comps)
            f, b, n = visit(m.group(2), stack + (name,))
            flops += trip * f
            byts += trip * b
            ndots += n
        for m in _CALL_RE.finditer(text):
            f, b, n = visit(m.group(1), stack + (name,))
            flops += f
            byts += b
            ndots += n
        memo[name] = (flops, byts, ndots)
        return memo[name]

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    f, b, n = visit(entry) if entry else (0.0, 0.0, 0)
    return {"flops": f, "dot_bytes": b, "num_dots": n}


def collective_breakdown(hlo: str) -> List[dict]:
    """Top collective contributors: (computation, kind, bytes, multiplier)."""
    comps = split_computations(hlo)
    mult: Dict[str, float] = {}

    def mark(name: str, m: float, stack=()):
        if name not in comps or name in stack:
            return
        mult[name] = mult.get(name, 0.0) + m
        text = "\n".join(comps[name].lines)
        for w in _WHILE_RE.finditer(text):
            trip = _trip_of(w.group(1), comps)
            mark(w.group(2), m * trip, stack + (name,))
        for cm in _CALL_RE.finditer(text):
            mark(cm.group(1), m, stack + (name,))

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry:
        mark(entry, 1.0)

    meta_re = re.compile(r'op_name="([^"]*)"')
    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            kind = cm.group(2)
            if kind == "reduce-scatter":
                byts = _shape_bytes(line[cm.end():].split(")")[0])
            else:
                byts = _shape_bytes(cm.group(1))
            factor = 2.0 if kind == "all-reduce" else 1.0
            mm = meta_re.search(line)
            rows.append(dict(computation=name, kind=kind,
                             bytes_once=factor * byts, mult=m,
                             bytes_total=factor * byts * m,
                             op_name=mm.group(1) if mm else "",
                             shape=cm.group(1)))
    rows.sort(key=lambda r: -r["bytes_total"])
    return rows
