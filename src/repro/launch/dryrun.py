import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation) and record the roofline terms.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and every other repro import pulls
jax in.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_is_supported)
from repro.launch.hlo_analysis import collective_bytes, while_trip_counts
from repro.launch.hlo_flops import dot_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (SERVE_RULES, TRAIN_RULES, replicated_like,
                                   resolve_tree)
from repro.models import decoder
from repro.models.act_shard import activation_sharding, mapping_from_mesh
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.partitioning import (batch_axes, cache_axes, param_axes)
from repro.optim.adamw import AdamWConfig
from repro.train.steps import (init_train_state, make_decode_step,
                               make_prefill_step, make_train_step,
                               train_state_axes)

KEY0 = jax.random.PRNGKey(0)


def _cfg_overrides(cfg: ModelConfig, overrides: Optional[Dict[str, Any]]
                   ) -> ModelConfig:
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               rules_train=TRAIN_RULES, rules_serve=SERVE_RULES,
               rule_overrides: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    cfg = _cfg_overrides(get_config(arch), overrides)
    if rule_overrides:
        def _norm(v):
            return tuple(v) if isinstance(v, list) else v
        rules_train = dict(rules_train,
                           **{k: _norm(v) for k, v in rule_overrides.items()})
        rules_serve = dict(rules_serve,
                           **{k: _norm(v) for k, v in rule_overrides.items()})
    shape: ShapeConfig = SHAPES[shape_name]
    record: Dict[str, Any] = dict(arch=arch, shape=shape_name,
                                  mesh="2x16x16" if multi_pod else "16x16")

    reason = shape_is_supported(cfg, shape)
    if reason is not None:
        record.update(status="skipped", reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    rules_act = rules_train if shape.kind == "train" else rules_serve
    with mesh, activation_sharding(mapping_from_mesh(mesh, rules_act),
                                   mesh=mesh):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(state_dtype=cfg.adam_dtype)
            state_shapes = jax.eval_shape(
                lambda: init_train_state(KEY0, cfg, opt_cfg))
            st_axes = train_state_axes(state_shapes)
            st_sh = resolve_tree(state_shapes, st_axes, mesh, rules_train)
            b_sh = resolve_tree(specs, batch_axes(specs), mesh, rules_train)
            out_sh = (st_sh, None)
            step = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                              out_shardings=out_sh).lower(state_shapes,
                                                          specs)
            n_state_bytes = sum(
                s.size * s.dtype.itemsize
                for s in jax.tree_util.tree_leaves(state_shapes))
        else:
            params_shapes = jax.eval_shape(
                lambda: decoder.init_params(KEY0, cfg))
            p_axes = param_axes(params_shapes)
            p_sh = resolve_tree(params_shapes, p_axes, mesh, rules_serve)
            n_state_bytes = sum(
                s.size * s.dtype.itemsize
                for s in jax.tree_util.tree_leaves(params_shapes))
            if shape.kind == "prefill":
                b_sh = resolve_tree(specs, batch_axes(specs), mesh,
                                    rules_serve)
                step = make_prefill_step(cfg)
                lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                                  out_shardings=None
                                  ).lower(params_shapes, specs)
            else:
                cache_shapes = specs["cache"]
                c_sh = resolve_tree(cache_shapes, cache_axes(cache_shapes),
                                    mesh, rules_serve)
                tok_sh = resolve_tree(
                    {"token": specs["token"]},
                    batch_axes({"token": specs["token"]}),
                    mesh, rules_serve)["token"]
                pos_sh = replicated_like(specs["pos"], mesh)
                step = make_decode_step(cfg)
                lowered = jax.jit(
                    step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                    out_shardings=None
                ).lower(params_shapes, cache_shapes, specs["token"],
                        specs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses --------------------------------------------------------
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes",
                                         None),
        )
    except Exception as e:                      # CPU backend may lack it
        mem_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    trips = while_trip_counts(hlo)
    dots = dot_flops(hlo)

    record.update(
        status="ok",
        chips=int(mesh.size),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops=cost.get("flops"),
        bytes_accessed=cost.get("bytes accessed"),
        cost_analysis={k: v for k, v in cost.items()
                       if isinstance(v, (int, float))},
        memory=mem_rec,
        collective_bytes_per_chip=coll,
        dot_flops_per_chip=dots["flops"],
        dot_bytes_per_chip=dots["dot_bytes"],
        num_dots=dots["num_dots"],
        num_while_loops=len(trips),
        max_trip_count=max((t for _, t in trips), default=0),
        state_bytes_global=n_state_bytes,
        state_bytes_per_chip=n_state_bytes / mesh.size,
        model_params=cfg.num_params(),
        model_active_params=cfg.num_active_params(),
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch, shape) for both meshes")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--rule-overrides", default=None,
                    help="JSON dict of sharding-rule overrides (perf exps)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    overrides = json.loads(args.overrides) if args.overrides else None
    rule_overrides = (json.loads(args.rule_overrides)
                      if args.rule_overrides else None)

    if args.all:
        cells = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                 for mp in (False, True)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in cells:
        tagpart = f".{args.tag}" if args.tag else ""
        name = f"{arch}.{shape}.{'pod2' if mp else 'pod1'}{tagpart}.json"
        path = os.path.join(args.out, name)
        if os.path.exists(path) and args.all:
            print(f"[skip existing] {name}")
            continue
        print(f"[dryrun] {arch} × {shape} × "
              f"{'2x16x16' if mp else '16x16'} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, mp, overrides, rule_overrides=rule_overrides)
        except Exception as e:
            rec = dict(arch=arch, shape=shape,
                       mesh="2x16x16" if mp else "16x16",
                       status="error", error=str(e),
                       traceback=traceback.format_exc())
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops={rec['flops']:.3e}"
                     f" coll/chip={rec['collective_bytes_per_chip']['total']:.3e}B"
                     f" compile={rec['compile_s']}s")
            mem = rec.get("memory", {})
            if mem.get("temp_bytes") is not None:
                print("  memory_analysis:", mem)
            print("  cost_analysis flops:", rec["flops"])
        print(f"[{status}] {name}{extra}", flush=True)


if __name__ == "__main__":
    main()
