"""Launcher: production meshes, sharding resolution, dry-run, train driver."""
