"""Production training driver.

Wires together: config registry, mesh + sharding rules, data pipeline,
AdamW, checkpointing (restart-safe), EARL-adaptive gradient accumulation,
and early-accurate eval — the EARL technique as a first-class feature of
the training loop.

On a real TPU cluster this runs under `jax.distributed.initialize()`; on
this CPU container it runs the same code path on smoke configs (see
examples/train_100m.py for the end-to-end ~100M-parameter driver).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        [--eval-every 25] [--adaptive-accum] [--resume]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import synthetic_tokens
from repro.data.pipeline import EvalSamplePipeline, TokenBatchPipeline
from repro.optim.adamw import AdamWConfig
from repro.optim.adaptive_accum import earl_accumulate_gradients
from repro.optim.adamw import adamw_update
from repro.train import EarlEval, make_eval_step, make_train_step
from repro.train.steps import TrainState, init_train_state, make_grad_step


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--override", default=None,
                    help="JSON ModelConfig overrides (e.g. custom dims)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--docs", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--eval-sigma", type=float, default=0.01)
    ap.add_argument("--adaptive-accum", action="store_true",
                    help="EARL bootstrap-CI gradient accumulation")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None) -> dict:
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.override:
        cfg = dataclasses.replace(cfg, **json.loads(args.override))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          state_dtype=cfg.adam_dtype)

    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg, opt_cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(state.params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    docs = synthetic_tokens(args.docs, args.seq + 1, cfg.vocab,
                            seed=args.seed)
    pipeline = TokenBatchPipeline(docs, batch=args.batch, seq_len=args.seq,
                                  seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3)

    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        template = jax.eval_shape(lambda: state)
        state, extra = mgr.restore(template)
        pipeline.load_state_dict(extra["pipeline"])
        start_step = extra["step"]
        print(f"[train] resumed from step {start_step}")

    train_step = jax.jit(make_train_step(cfg, opt_cfg))
    grad_step = jax.jit(make_grad_step(cfg))
    eval_step = jax.jit(make_eval_step(cfg))

    history = []
    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        if args.adaptive_accum:
            mbs = []
            for _ in range(args.microbatches):
                tokens, labels = pipeline.next_batch()
                mbs.append({"tokens": tokens, "labels": labels})
            grads, decision = earl_accumulate_gradients(
                grad_step, state.params, mbs, sigma=0.02)
            new_params, new_opt, m = adamw_update(
                state.params, grads, state.opt, opt_cfg)
            state = TrainState(new_params, new_opt)
            metrics = {"loss": decision.mean_loss, **m,
                       "micro_used": decision.microbatches_used,
                       "grad_cv": decision.cv}
        else:
            tokens, labels = pipeline.next_batch()
            state, metrics = train_step(state,
                                        {"tokens": tokens, "labels": labels})
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics.get("loss", float("nan")))
            extra_s = (f" micro={metrics['micro_used']}"
                       if "micro_used" in metrics else "")
            print(f"[train] step {step:5d} loss={loss:.4f}"
                  f" gnorm={float(metrics['grad_norm']):.3f}{extra_s}")
        history.append({k: float(v) if hasattr(v, "item") or
                        isinstance(v, (int, float)) else v
                        for k, v in metrics.items()})

        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state,
                     extra={"step": step + 1,
                            "pipeline": pipeline.state_dict()})

        if args.eval_every and (step + 1) % args.eval_every == 0:
            eval_docs = synthetic_tokens(2048, args.seq + 1, cfg.vocab,
                                         seed=args.seed + 1)
            ev = EarlEval(eval_step, state.params,
                          EvalSamplePipeline(eval_docs, seq_len=args.seq),
                          sigma=args.eval_sigma, eval_batch=args.batch * 4)
            res = ev.run(jax.random.fold_in(key, step))
            info = res.history[-1]
            print(f"[earl_eval] step {step + 1}: "
                  f"loss={float(np.ravel(res.result)[0]):.4f}±cv {res.cv:.4f} "
                  f"using {info['model_forwards']}/{info['full_pass_forwards']}"
                  f" forwards ({info['full_pass_forwards'] / max(info['model_forwards'], 1):.1f}x saved)")

    mgr.save(args.steps, state,
             extra={"step": args.steps, "pipeline": pipeline.state_dict()})
    mgr.wait()
    wall = time.perf_counter() - t0
    print(f"[train] done: {args.steps - start_step} steps in {wall:.1f}s")
    return {"steps": args.steps, "wall_s": wall, "history": history}


if __name__ == "__main__":
    main()
