"""Logical-axis → mesh PartitionSpec resolution with divisibility fallback.

Rules map logical axis names to an ordered tuple of candidate mesh axes;
the resolver takes the longest prefix whose product divides the dim and
isn't already used in the same spec.  Non-divisible dims fall back to
replication instead of failing — this is what lets one rule table cover
all 40 (arch × shape) cells (8 KV heads or 8 experts on a 16-way model
axis replicate gracefully; a batch of 1 frees the data axis for the
KV-cache sequence — the flash-decoding layout).

Two profiles:
  TRAIN — ZeRO-3-style: params FSDP-shard "embed" over the in-pod data
  axis AND tensor-shard heads/mlp/vocab/experts over "model"; batch over
  ("pod","data").  Cross-pod traffic is gradient-only (DP across pods).
  SERVE — identical tensor sharding; "embed" additionally FSDP-shards so
  90B-class checkpoints fit; KV cache seq claims ("pod","data") whenever
  the batch dim can't.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Optional[Tuple[str, ...]]]

TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "rnn": ("model",),
    "rnn2": None,
    "head_dim": None,
    "head_dim2": None,
    "seq": None,
    "cache_seq": None,
    "aux_seq": None,
    "layers": None,
}

SERVE_RULES: Rules = dict(
    TRAIN_RULES,
    cache_seq=("pod", "data"),      # flash-decode: claims what batch didn't
)

#: §Perf iteration H4b (EXPERIMENTS.md): pure ZeRO-3 for DENSE training —
#: batch data-parallel over the whole mesh, weights sharded 256-way on
#: "embed"; per-layer weight all-gathers replace the TP activation
#: all-reduces (2.6× less wire traffic for gemma3-27b train_4k).  MoE archs
#: keep TRAIN_RULES + moe_impl="shard_map" (H2) instead.
ZERO3_TRAIN_RULES: Rules = dict(
    TRAIN_RULES,
    batch=("pod", "data", "model"),
    heads=None, kv_heads=None, mlp=None, rnn=None,
    embed=("data", "model"),
)

#: §Perf iteration H3 (arctic decode): when "heads" cannot split over the
#: model axis (56 % 16 != 0), letting head_dim claim the data axis turns
#: the per-layer wo all-gather into a tiny activation psum (4.2× less
#: decode wire traffic).
SERVE_RULES_HEADDIM: Rules = dict(SERVE_RULES, head_dim=("data",))


def resolve_spec(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                 mesh: Mesh, rules: Rules) -> P:
    parts = []
    used = set()
    for dim, ax in zip(shape, axes):
        targets = rules.get(ax) if ax is not None else None
        if targets is None:
            parts.append(None)
            continue
        if isinstance(targets, str):
            targets = (targets,)
        sel = []
        prod = 1
        for m in targets:
            if m in used or m not in mesh.shape:
                continue
            if dim % (prod * mesh.shape[m]) == 0:
                sel.append(m)
                prod *= mesh.shape[m]
        if not sel:
            parts.append(None)
        else:
            parts.append(sel[0] if len(sel) == 1 else tuple(sel))
            used.update(sel)
    return P(*parts)


def resolve_tree(shapes_tree: Any, axes_tree: Any, mesh: Mesh,
                 rules: Rules) -> Any:
    """Pytree of ShapeDtypeStructs × pytree of logical-axis tuples ->
    NamedShardings.  (tree_map flattens up to shapes_tree's leaves, so the
    axis tuples in axes_tree arrive whole.)"""
    return jax.tree_util.tree_map(
        lambda s, a: NamedSharding(
            mesh, resolve_spec(tuple(s.shape), tuple(a), mesh, rules)),
        shapes_tree, axes_tree)


def replicated_like(tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
