"""HLO text analysis: collective-traffic accounting with while-loop trip
multiplication.

``compiled.as_text()`` is the SPMD-partitioned module — shapes are
per-partition (local), so a collective op's printed shapes directly give
per-chip wire bytes.  Scan bodies (layers, loss chunks, attention blocks)
lower to ``while`` ops whose bodies contain the per-iteration collectives;
a flat text scan would undercount them by the trip count, so we build the
computation graph, extract each while's trip count from the integer bound
in its condition computation, and multiply recursively.

Wire-byte conventions (ring algorithms, group size n; factors on the
printed local shapes):
    all-reduce        2 × result      (reduce-scatter + all-gather phases)
    all-gather        1 × result      (result is the gathered local tensor)
    reduce-scatter    1 × operand     (operand is the pre-scatter tensor)
    all-to-all        1 × result
    collective-permute 1 × result

The totals are PER-CHIP bytes; benchmarks/roofline.py multiplies by chip
count to match the prescribed  collective_bytes / (chips · link_bw)  form.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    is_entry: bool = False


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
        if m and not line.startswith(" "):
            cur = Computation(m.group(1), [],
                              is_entry=line.lstrip().startswith("ENTRY"))
            comps[cur.name] = cur
        elif cur is not None:
            cur.lines.append(line)
    return comps


def _direct_collectives(comp: Computation) -> Dict[str, float]:
    """Per-op-kind per-chip wire bytes for one computation (no recursion)."""
    out: Dict[str, float] = defaultdict(float)
    for line in comp.lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        if kind == "reduce-scatter":
            # operand is printed inside the parens
            rest = line[m.end():]
            bytes_ = _shape_bytes(rest.split(")")[0])
        else:
            bytes_ = _shape_bytes(result_type)
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += factor * bytes_
    return out


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for line in cond.lines for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, while-loops multiplied out.

    Returns dict kind -> bytes, plus "total"."""
    comps = split_computations(hlo)

    memo: Dict[str, Dict[str, float]] = {}

    def visit(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}
        comp = comps[name]
        total = defaultdict(float, _direct_collectives(comp))
        body_text = "\n".join(comp.lines)
        for m in _WHILE_RE.finditer(body_text):
            cond_name, body_name = m.group(1), m.group(2)
            trip = _trip_count(comps[cond_name]) if cond_name in comps else 1
            sub = visit(body_name, stack + (name,))
            for k, v in sub.items():
                total[k] += trip * v
        for m in _CALL_RE.finditer(body_text):
            sub = visit(m.group(1), stack + (name,))
            for k, v in sub.items():
                total[k] += v
        memo[name] = dict(total)
        return memo[name]

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    result = visit(entry) if entry else {}
    result = dict(result)
    result["total"] = sum(v for k, v in result.items())
    return result


def while_trip_counts(hlo: str) -> List[Tuple[str, int]]:
    """(body name, trip count) for every while op — scan-depth diagnostics."""
    comps = split_computations(hlo)
    out = []
    for comp in comps.values():
        for m in _WHILE_RE.finditer("\n".join(comp.lines)):
            cond, body = m.group(1), m.group(2)
            out.append((body, _trip_count(comps[cond])
                        if cond in comps else 1))
    return out
