"""Samplers over the sharded store (paper §3.3).

* ``PreMapSampler``  — samples row *indices* first and reads only those
  rows (the paper's pre-map sampling: sample line offsets inside splits,
  backtrack to line start, never load the rest).  Low load cost; the
  ⟨k,v⟩-count estimate is the sampled fraction (correct() uses p=n/N).

* ``PostMapSampler`` — reads the full store once, hash-buckets rows, then
  draws the sample (paper's post-map: exact key accounting, full load
  cost).

* ``PermutationSampler`` — the EarlSession-facing wrapper: a fixed pseudo-
  random permutation of [0, N); ``take(a, b)`` returns permutation rows
  [a, b), so growing samples are prefix-extends (uniform without
  replacement — DESIGN.md §7.2) and delta maintenance gets pure Δs rows.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.data.store import ShardedStore


class PermutationSampler:
    """Uniform without-replacement prefixes via a fixed permutation.

    ``mode="pre_map"`` reads row-granular (cheap); ``mode="post_map"``
    materializes the full store on first touch (exact counts, expensive) —
    both expose identical take() semantics so EarlSession is agnostic.
    """

    def __init__(self, store: ShardedStore, seed: int = 0,
                 mode: str = "pre_map"):
        if mode not in ("pre_map", "post_map"):
            raise ValueError(mode)
        self.store = store
        self.mode = mode
        self.N = store.N
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(self.N)
        self._cache: Optional[np.ndarray] = None

    def take(self, start: int, stop: int) -> jnp.ndarray:
        stop = min(stop, self.N)
        rows = self.perm[start:stop]
        if self.mode == "post_map":
            if self._cache is None:
                self._cache = self.store.read_all()
            return jnp.asarray(self._cache[rows])
        # pre-map: group the requested rows by split, read row-granular
        split, local = self.store.locate(rows)
        order = np.argsort(split, kind="stable")
        out = np.empty((len(rows),) + self.store.splits[0].shape[1:],
                       dtype=self.store.splits[0].dtype)
        i = 0
        while i < len(order):
            j = i
            s = split[order[i]]
            while j < len(order) and split[order[j]] == s:
                j += 1
            sel = order[i:j]
            out[sel] = self.store.read_rows(int(s), local[sel])
            i = j
        return jnp.asarray(out)


class PreMapSampler(PermutationSampler):
    def __init__(self, store: ShardedStore, seed: int = 0):
        super().__init__(store, seed=seed, mode="pre_map")


class PostMapSampler(PermutationSampler):
    """Paper's post-map: read-then-select with hash bucketing.

    The hash layer reproduces Algorithm 1: every row is assigned a random
    key bucket on load; draws pop buckets without replacement.  Counting
    is exact: ``kv_count`` is known after load (pre-map only estimates it).
    """

    def __init__(self, store: ShardedStore, seed: int = 0,
                 num_buckets: int = 1024):
        super().__init__(store, seed=seed, mode="post_map")
        self.num_buckets = num_buckets
        self._loaded = False
        self.kv_count: Optional[int] = None

    def _load(self) -> None:
        self._cache = self.store.read_all()
        self.kv_count = len(self._cache)
        rng = np.random.default_rng(0xB0B)
        self.bucket_of = rng.integers(0, self.num_buckets,
                                      size=self.kv_count)
        self._loaded = True

    def take(self, start: int, stop: int) -> jnp.ndarray:
        if not self._loaded:
            self._load()
        return super().take(start, stop)
