"""Samplers over the sharded store (paper §3.3).

* ``PreMapSampler``  — samples row *indices* first and reads only those
  rows (the paper's pre-map sampling: sample line offsets inside splits,
  backtrack to line start, never load the rest).  Low load cost; the
  ⟨k,v⟩-count estimate is the sampled fraction (correct() uses p=n/N).

* ``PostMapSampler`` — reads the full store once, hash-buckets rows, then
  draws the sample (paper's post-map: exact key accounting, full load
  cost).

* ``PermutationSampler`` — the EarlSession-facing wrapper: a fixed pseudo-
  random permutation of [0, N); ``take(a, b)`` returns permutation rows
  [a, b), so growing samples are prefix-extends (uniform without
  replacement — DESIGN.md §7.2) and delta maintenance gets pure Δs rows.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.data.store import ShardedStore


class PermutationSampler:
    """Uniform without-replacement prefixes via a fixed permutation.

    ``mode="pre_map"`` reads row-granular (cheap); ``mode="post_map"``
    materializes the full store on first touch (exact counts, expensive) —
    both expose identical take() semantics so EarlSession is agnostic.
    """

    def __init__(self, store: ShardedStore, seed: int = 0,
                 mode: str = "pre_map"):
        if mode not in ("pre_map", "post_map"):
            raise ValueError(mode)
        self.store = store
        self.mode = mode
        self.N = store.N
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(self.N)
        self._cache: Optional[np.ndarray] = None

    def take(self, start: int, stop: int) -> jnp.ndarray:
        stop = min(stop, self.N)
        rows = self.perm[start:stop]
        if self.mode == "post_map":
            if self._cache is None:
                self._cache = self.store.read_all()
            return jnp.asarray(self._cache[rows])
        # pre-map: group the requested rows by split, read row-granular
        split, local = self.store.locate(rows)
        order = np.argsort(split, kind="stable")
        out = np.empty((len(rows),) + self.store.splits[0].shape[1:],
                       dtype=self.store.splits[0].dtype)
        i = 0
        while i < len(order):
            j = i
            s = split[order[i]]
            while j < len(order) and split[order[j]] == s:
                j += 1
            sel = order[i:j]
            out[sel] = self.store.read_rows(int(s), local[sel])
            i = j
        return jnp.asarray(out)


class StratifiedSampler(PermutationSampler):
    """Skew-aware prefix sampler for keyed (GROUP BY) sessions.

    A uniform prefix of a skewed table starves rare keys: with key g at
    frequency f_g, an n-row prefix holds ~f_g·n of its rows, so the
    worst key's c_v — what a keyed ``EarlSession`` gates on via
    ``KeyedAccuracyReport`` — is stuck at the rarest key's trickle and
    the session grows the sample far past what the heavy hitters need.
    This sampler reorders the base permutation by stride scheduling:
    within each stratum rows keep the base permutation's order (so each
    stratum's portion of any prefix is a uniform without-replacement
    sample of that key), and across strata row i of stratum g is
    scheduled at virtual time (i+1)/share_g, the global order being the
    stable ascending sort of those times.  Every prefix then holds the
    strata in ~share proportions — ``shares=None`` gives EQUAL shares,
    surfacing rare keys at the same rate as heavy hitters — and a
    stratum's budget being exhausted simply lets the others fill in.

    The stratum is the integer KEY COLUMN (default: last), matching
    ``GroupedStatistic``'s key-is-last-column convention.  Reading the
    keys is one column scan over the store at construction — the exact
    key accounting of the paper's post-map sampling, paid once.

    Caveat (also in ROADMAP "Known modeling limits"): prefixes are
    uniform WITHIN each key but deliberately non-uniform across keys, so
    whole-table ``correct(p)`` fractions no longer describe any single
    key — keyed sessions should correct per key with that key's own
    sampled fraction (``stratum_counts`` / ``stratum_sizes`` expose the
    numbers).
    """

    def __init__(self, store: ShardedStore, num_groups: int, seed: int = 0,
                 shares=None, key_column: int = -1, mode: str = "pre_map"):
        super().__init__(store, seed=seed, mode=mode)
        self.num_groups = int(num_groups)
        cols = []
        for s in store.splits:
            a = np.asarray(s)
            if a.ndim < 2 or a.shape[1] < 2:
                raise ValueError("StratifiedSampler needs keyed rows: data "
                                 "columns plus an integer key column")
            cols.append(a[:, key_column])
        keys = np.concatenate(cols)
        if np.any(keys != np.floor(keys)):
            raise ValueError("key column must hold integers")
        keys = keys.astype(np.int64)
        if keys.min() < 0 or keys.max() >= self.num_groups:
            raise ValueError(f"keys must lie in [0, {self.num_groups}); got "
                             f"range [{keys.min()}, {keys.max()}]")
        if shares is None:
            shares = np.ones(self.num_groups)
        shares = np.asarray(shares, np.float64)
        if shares.shape != (self.num_groups,) or not np.all(shares > 0):
            raise ValueError("shares must be positive, one per group")
        self.shares = shares / shares.sum()
        #: rows of key g in the whole store — the per-key N for correct(p).
        self.stratum_sizes = np.bincount(keys, minlength=self.num_groups)

        # stride-schedule the base permutation (see class docstring)
        kperm = keys[self.perm]
        order = np.argsort(kperm, kind="stable")
        sorted_k = kperm[order]
        starts = np.searchsorted(sorted_k, np.arange(self.num_groups))
        ranks = np.empty(self.N, np.int64)
        ranks[order] = np.arange(self.N) - starts[sorted_k]
        vtime = (ranks + 1) / self.shares[kperm]
        self.perm = self.perm[np.argsort(vtime, kind="stable")]
        self._kperm = keys[self.perm]

    def stratum_counts(self, stop: int) -> np.ndarray:
        """Rows of each key inside the prefix [0, stop) — with
        ``stratum_sizes`` this gives the per-key sampled fraction a keyed
        ``correct`` should use."""
        stop = min(int(stop), self.N)
        return np.bincount(self._kperm[:stop], minlength=self.num_groups)


class PreMapSampler(PermutationSampler):
    def __init__(self, store: ShardedStore, seed: int = 0):
        super().__init__(store, seed=seed, mode="pre_map")


class PostMapSampler(PermutationSampler):
    """Paper's post-map: read-then-select with hash bucketing.

    The hash layer reproduces Algorithm 1: every row is assigned a random
    key bucket on load; draws pop buckets without replacement.  Counting
    is exact: ``kv_count`` is known after load (pre-map only estimates it).
    """

    def __init__(self, store: ShardedStore, seed: int = 0,
                 num_buckets: int = 1024):
        super().__init__(store, seed=seed, mode="post_map")
        self.num_buckets = num_buckets
        self._loaded = False
        self.kv_count: Optional[int] = None

    def _load(self) -> None:
        self._cache = self.store.read_all()
        self.kv_count = len(self._cache)
        rng = np.random.default_rng(0xB0B)
        self.bucket_of = rng.integers(0, self.num_buckets,
                                      size=self.kv_count)
        self._loaded = True

    def take(self, start: int, stop: int) -> jnp.ndarray:
        if not self._loaded:
            self._load()
        return super().take(start, stop)
