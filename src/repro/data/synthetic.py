"""Synthetic data generators (the paper's evaluation uses synthetic data
so the true answer is known — §6: "The synthetic dataset allows us to
easily validate the accuracy measure produced by EARL")."""
from __future__ import annotations

from typing import Tuple

import numpy as np


def synthetic_numeric(n: int, mean: float = 10.0, std: float = 2.0,
                      dim: int = 1, seed: int = 0,
                      dist: str = "normal") -> np.ndarray:
    rng = np.random.default_rng(seed)
    if dist == "normal":
        x = rng.normal(mean, std, size=(n, dim))
    elif dist == "lognormal":
        x = rng.lognormal(np.log(max(mean, 1e-6)), std / mean, size=(n, dim))
    elif dist == "uniform":
        x = rng.uniform(mean - std, mean + std, size=(n, dim))
    elif dist == "heavy":   # pareto-ish heavy tail — stresses the bootstrap
        x = mean + std * (rng.pareto(3.0, size=(n, dim)) - 0.5)
    else:
        raise ValueError(dist)
    return x.astype(np.float32)


def synthetic_clusters(n: int, k: int = 5, dim: int = 2, spread: float = 0.4,
                       seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian blobs for the K-Means experiment (paper §6.3)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-5.0, 5.0, size=(k, dim)).astype(np.float32)
    assign = rng.integers(0, k, size=n)
    x = centers[assign] + rng.normal(0, spread, size=(n, dim))
    return x.astype(np.float32), centers


def synthetic_tokens(n_docs: int, doc_len: int, vocab: int,
                     seed: int = 0) -> np.ndarray:
    """Zipf-ish token documents for the LM pipeline / earl_eval."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    return rng.choice(vocab, size=(n_docs, doc_len), p=probs).astype(np.int32)
