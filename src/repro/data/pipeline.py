"""Batch pipelines feeding the training / eval loops.

``TokenBatchPipeline``  — deterministic, restartable LM batches: the epoch
order is a seeded permutation and the cursor is a single integer, so a
checkpoint restore resumes the exact stream (fault tolerance substrate).

``EvalSamplePipeline``  — the earl_eval data path: per-example rows from a
PermutationSampler, device-ready and mesh-shardable, grown prefix-wise so
the EARL loop's Δs is the literal array suffix.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.sampler import PermutationSampler
from repro.data.store import ShardedStore


@dataclasses.dataclass
class PipelineState:
    """Checkpointable cursor."""
    epoch: int = 0
    step: int = 0


class TokenBatchPipeline:
    """(tokens, labels) batches of shape (batch, seq) from a doc store."""

    def __init__(self, docs: np.ndarray, batch: int, seq_len: int,
                 seed: int = 0, pad_id: int = 0):
        assert docs.ndim == 2
        self.docs = docs
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.pad_id = pad_id
        self.state = PipelineState()
        self._reperm()

    def _reperm(self) -> None:
        rng = np.random.default_rng(self.seed + self.state.epoch)
        self.perm = rng.permutation(len(self.docs))

    def steps_per_epoch(self) -> int:
        return len(self.docs) // self.batch

    def next_batch(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if self.state.step >= self.steps_per_epoch():
            self.state = PipelineState(self.state.epoch + 1, 0)
            self._reperm()
        i = self.state.step * self.batch
        idx = self.perm[i:i + self.batch]
        self.state.step += 1
        docs = self.docs[idx]
        L = self.seq_len + 1
        if docs.shape[1] < L:
            docs = np.pad(docs, ((0, 0), (0, L - docs.shape[1])),
                          constant_values=self.pad_id)
        tokens = jnp.asarray(docs[:, :self.seq_len])
        labels = jnp.asarray(docs[:, 1:self.seq_len + 1])
        return tokens, labels

    # -- checkpoint hooks ------------------------------------------------
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState(**d)
        self._reperm()


class EvalSamplePipeline:
    """Growing per-example eval sample for earl_eval.

    Items are documents; ``take(a, b)`` yields token arrays for permutation
    rows [a, b).  The EARL statistic is the per-document mean loss, so each
    row is one iid sample item (paper's ⟨k,v⟩ independence assumption)."""

    def __init__(self, docs: np.ndarray, seq_len: int, seed: int = 0,
                 split_size: int = 4096):
        store = ShardedStore.from_array(docs, split_size, interleave=True,
                                        seed=seed)
        self.sampler = PermutationSampler(store, seed=seed, mode="pre_map")
        self.seq_len = seq_len
        self.N = store.N

    def take(self, start: int, stop: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        docs = np.asarray(self.sampler.take(start, stop))
        tokens = jnp.asarray(docs[:, :self.seq_len])
        labels = jnp.asarray(docs[:, 1:self.seq_len + 1])
        return tokens, labels
