"""Data substrate: sharded store (HDFS-splits analogue), samplers, pipeline."""
from repro.data.store import ShardedStore
from repro.data.sampler import (PermutationSampler, PostMapSampler,
                                PreMapSampler, StratifiedSampler)
from repro.data.pipeline import EvalSamplePipeline, TokenBatchPipeline
from repro.data.synthetic import (synthetic_clusters, synthetic_numeric,
                                  synthetic_tokens)

__all__ = [
    "ShardedStore", "PermutationSampler", "PostMapSampler", "PreMapSampler",
    "StratifiedSampler",
    "EvalSamplePipeline", "TokenBatchPipeline",
    "synthetic_clusters", "synthetic_numeric", "synthetic_tokens",
]
