"""ShardedStore — the HDFS-splits analogue (paper §3.3).

A dataset is a set of fixed-size *splits* (shards).  Reads are split-
granular and counted, so the benchmarks can report load cost exactly the
way the paper does (pre-map sampling reads only the splits/rows it needs;
post-map reads everything).

The paper warns (§7, block sampling) that naive split-level sampling is
non-uniform when the layout is clustered; ingest therefore offers an
``interleave`` option that scatters rows across splits by a hash
permutation, making every split an unbiased slice (tests/test_sampler.py
checks this with a chi-square bound).
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class ReadStats:
    """Counted-read totals.  Thread-safe: the streaming driver's prefetch
    thread and the main thread both touch the counters (core/streaming.py),
    so all mutation goes through ``add``/``reset`` under a lock.  Reading
    the plain int attributes without the lock stays safe (int loads are
    atomic under the GIL); only read-modify-write needed guarding."""
    splits_opened: int = 0
    rows_read: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, splits: int = 0, rows: int = 0) -> None:
        with self._lock:
            self.splits_opened += splits
            self.rows_read += rows

    def reset(self) -> None:
        with self._lock:
            self.splits_opened = 0
            self.rows_read = 0


class ShardedStore:
    """Row-oriented store partitioned into splits of ``split_size`` rows."""

    def __init__(self, splits: List[np.ndarray]):
        self.splits = splits
        self.split_sizes = [len(s) for s in splits]
        self.offsets = np.cumsum([0] + self.split_sizes)
        self.N = int(self.offsets[-1])
        self.stats = ReadStats()
        self._checksums: dict = {}

    def split_checksum(self, i: int) -> int:
        """crc32 of split ``i``'s pristine bytes (computed lazily, cached).

        This is the per-batch integrity oracle the fault-tolerant read path
        (ft/inject.py) validates against: a wrapper that corrupts or
        truncates a read cannot also forge this checksum, because wrappers
        delegate ``split_checksum`` to the underlying store.

        The cache is keyed by split IDENTITY, not index: when
        ``replace_split`` swaps in a recovered/rewritten segment's bytes,
        the stale crc must not survive the swap (the cached entry holds a
        reference to the array it hashed, so the identity check is safe
        against id() reuse)."""
        s = self.splits[i]
        cached = self._checksums.get(i)
        if cached is not None and cached[0] is s:
            return cached[1]
        crc = zlib.crc32(np.ascontiguousarray(s).tobytes())
        self._checksums[i] = (s, crc)
        return crc

    # -- construction --------------------------------------------------
    @staticmethod
    def from_array(data: np.ndarray, split_size: int,
                   interleave: bool = True,
                   seed: int = 0) -> "ShardedStore":
        data = np.asarray(data)
        if interleave:
            # hash-permute rows at ingest so clustered layouts (paper §7's
            # block-sampling hazard) cannot bias split-level samples.
            rng = np.random.default_rng(seed)
            data = data[rng.permutation(len(data))]
        splits = [data[i:i + split_size]
                  for i in range(0, len(data), split_size)]
        return ShardedStore(splits)

    # -- append (live-ingest path) -------------------------------------
    def append_split(self, data: np.ndarray) -> int:
        """Seal ``data`` as a new split at the end of the store and return
        its split index.

        This is the segmented-writer primitive the live ``IngestLog``
        builds on: ingest batches become immutable splits one at a time,
        so every existing read path (``iter_batches``, ``read_split``,
        checksums) works over a growing store without rebuilding it.
        Cached checksums of earlier splits stay valid because splits are
        immutable once sealed."""
        data = np.asarray(data)
        if len(data) == 0:
            raise ValueError("append_split needs a non-empty batch")
        if self.splits and data.shape[1:] != self.splits[0].shape[1:]:
            raise ValueError(
                f"append_split shape {data.shape[1:]} does not match the "
                f"store's row shape {self.splits[0].shape[1:]}")
        i = len(self.splits)
        self.splits.append(data)
        self.split_sizes.append(len(data))
        self.offsets = np.append(self.offsets, self.N + len(data))
        self.N = int(self.offsets[-1])
        return i

    def replace_split(self, i: int, data: np.ndarray) -> None:
        """Swap split ``i``'s bytes in place — the repaired-segment path
        (a batch the durable log degraded to zeros is re-read after its
        file is restored from a replica).  The geometry is immutable:
        the replacement must match the split's shape exactly, so offsets
        and every downstream row placement stay valid.  The checksum
        cache is identity-keyed, so the new bytes get a fresh crc."""
        data = np.asarray(data)
        if data.shape != self.splits[i].shape:
            raise ValueError(
                f"replace_split must preserve the split's shape "
                f"{self.splits[i].shape}, got {data.shape}")
        if data.dtype != self.splits[i].dtype:
            raise ValueError(
                f"replace_split must preserve the split's dtype "
                f"{self.splits[i].dtype}, got {data.dtype}")
        self.splits[i] = data

    # -- counted reads ---------------------------------------------------
    def read_split(self, i: int) -> np.ndarray:
        self.stats.add(splits=1, rows=self.split_sizes[i])
        return self.splits[i]

    def read_rows(self, split: int, rows: np.ndarray) -> np.ndarray:
        """Pre-map style row-granular read (the LineRecordReader analogue)."""
        self.stats.add(splits=1, rows=len(rows))
        return self.splits[split][rows]

    def iter_batches(self, chunk: int,
                     start_row: int = 0) -> Iterator[np.ndarray]:
        """Counted sequential read as fixed-size ``chunk``-row batches.

        Yields ``ceil((N - start_row) / chunk)`` arrays of ``chunk`` rows
        each (the last one ragged), crossing split boundaries — the
        disk-order stream the streaming bootstrap driver
        (core/streaming.py) consumes.  Each split is opened exactly once,
        so ``stats`` records one full pass.  Batches that fall inside a
        single split are zero-copy views of it; treat them as read-only.

        ``start_row`` resumes the stream at that global row (the
        checkpoint-restart path): splits entirely before it are SKIPPED
        without being opened (no counted read — a resumed run pays only
        for the rows it still needs), and a split straddling it is opened
        once with only its tail consumed.
        """
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        if start_row < 0 or start_row > self.N:
            raise ValueError(f"start_row must be in [0, {self.N}], "
                             f"got {start_row}")
        parts: List[np.ndarray] = []
        have = 0
        for i in range(len(self.splits)):
            if self.offsets[i + 1] <= start_row:
                continue                       # entirely consumed: skip read
            s = self.read_split(i)
            pos = max(0, start_row - int(self.offsets[i]))
            while pos < len(s):
                take = min(chunk - have, len(s) - pos)
                parts.append(s[pos:pos + take])
                have += take
                pos += take
                if have == chunk:
                    yield (parts[0] if len(parts) == 1
                           else np.concatenate(parts, axis=0))
                    parts, have = [], 0
        if have:
            yield (parts[0] if len(parts) == 1
                   else np.concatenate(parts, axis=0))

    def read_all(self) -> np.ndarray:
        """Everything, in store order — one preallocated buffer filled from
        ``iter_batches`` (the old ``np.concatenate`` of all splits held two
        full copies live at the peak)."""
        if not self.splits:
            return np.empty((0,), np.float32)
        head = self.splits[0]
        out = np.empty((self.N,) + head.shape[1:], head.dtype)
        pos = 0
        for b in self.iter_batches(max(self.split_sizes)):
            out[pos:pos + len(b)] = b
            pos += len(b)
        return out

    def locate(self, global_rows: np.ndarray):
        """global row ids -> (split ids, local rows)."""
        split = np.searchsorted(self.offsets, global_rows, side="right") - 1
        local = global_rows - self.offsets[split]
        return split, local
