"""ShardedStore — the HDFS-splits analogue (paper §3.3).

A dataset is a set of fixed-size *splits* (shards).  Reads are split-
granular and counted, so the benchmarks can report load cost exactly the
way the paper does (pre-map sampling reads only the splits/rows it needs;
post-map reads everything).

The paper warns (§7, block sampling) that naive split-level sampling is
non-uniform when the layout is clustered; ingest therefore offers an
``interleave`` option that scatters rows across splits by a hash
permutation, making every split an unbiased slice (tests/test_sampler.py
checks this with a chi-square bound).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class ReadStats:
    splits_opened: int = 0
    rows_read: int = 0

    def reset(self) -> None:
        self.splits_opened = 0
        self.rows_read = 0


class ShardedStore:
    """Row-oriented store partitioned into splits of ``split_size`` rows."""

    def __init__(self, splits: List[np.ndarray]):
        self.splits = splits
        self.split_sizes = [len(s) for s in splits]
        self.offsets = np.cumsum([0] + self.split_sizes)
        self.N = int(self.offsets[-1])
        self.stats = ReadStats()

    # -- construction --------------------------------------------------
    @staticmethod
    def from_array(data: np.ndarray, split_size: int,
                   interleave: bool = True,
                   seed: int = 0) -> "ShardedStore":
        data = np.asarray(data)
        if interleave:
            # hash-permute rows at ingest so clustered layouts (paper §7's
            # block-sampling hazard) cannot bias split-level samples.
            rng = np.random.default_rng(seed)
            data = data[rng.permutation(len(data))]
        splits = [data[i:i + split_size]
                  for i in range(0, len(data), split_size)]
        return ShardedStore(splits)

    # -- counted reads ---------------------------------------------------
    def read_split(self, i: int) -> np.ndarray:
        self.stats.splits_opened += 1
        self.stats.rows_read += self.split_sizes[i]
        return self.splits[i]

    def read_rows(self, split: int, rows: np.ndarray) -> np.ndarray:
        """Pre-map style row-granular read (the LineRecordReader analogue)."""
        self.stats.splits_opened += 1
        self.stats.rows_read += len(rows)
        return self.splits[split][rows]

    def read_all(self) -> np.ndarray:
        return np.concatenate([self.read_split(i)
                               for i in range(len(self.splits))], axis=0)

    def locate(self, global_rows: np.ndarray):
        """global row ids -> (split ids, local rows)."""
        split = np.searchsorted(self.offsets, global_rows, side="right") - 1
        local = global_rows - self.offsets[split]
        return split, local
