"""Version-compatibility shims (the container pins jax 0.4.37)."""
from __future__ import annotations


def shard_map_compat():
    """Return ``(shard_map, kwargs)`` with replication checking disabled,
    across the jax>=0.6 (``jax.shard_map``/``check_vma``) and jax 0.4.x
    (``jax.experimental.shard_map``/``check_rep``) APIs."""
    try:
        from jax import shard_map
        return shard_map, {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map
        return shard_map, {"check_rep": False}
