"""Checkpoint manager: atomic, async, keep-k, restore-with-resharding.

Layout (one directory per step, atomically renamed into place):

    <root>/ckpt_00001230/
        arrays.npz          flat {path -> array} of the state pytree
        meta.json           step, extra state (data-pipeline cursor, rng)

Restore takes a *template* pytree (e.g. from jax.eval_shape) and an
optional target sharding tree — restoring onto a different mesh is just
device_put with the new NamedShardings (the elastic-rescale path in
ft/elastic.py).  On a real multi-host cluster each host would write its
address-space shards (orbax-style); the format and the atomic-commit /
keep-k / async logic here are the substrate that sits under that.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

SEP = "/"

#: a staging dir older than this is reaped even if its pid LOOKS alive —
#: an in-flight _write is seconds old, so a "live" owner this stale is a
#: recycled pid, not a peer mid-write (pid reuse would otherwise pin a
#: crashed writer's garbage forever).
STALE_TMP_S = 3600.0


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3,
                 async_save: bool = True):
        self.root = root
        self.keep_last = keep_last
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._closed = False
        os.makedirs(root, exist_ok=True)
        self._gc_orphans()

    @classmethod
    def for_run(cls, root: str, fingerprint: str,
                keep_last: int = 3, async_save: bool = True
                ) -> "CheckpointManager":
        """A manager scoped to ONE run under a shared ``root``.

        Several standing sessions can point at the same checkpoint
        directory; without scoping they would overwrite each other's
        ``ckpt_<step>`` dirs (step counters collide) and keep-k GC would
        reap a peer's snapshots.  Scoping by the run fingerprint gives
        each distinct run its own subdirectory — same fingerprint, same
        subdirectory, so resume finds its own snapshots by construction.
        """
        return cls(os.path.join(root, f"run_{fingerprint[:16]}"),
                   keep_last=keep_last, async_save=async_save)

    @staticmethod
    def _pid_alive(pid_s: str) -> bool:
        """Liveness of a pid string from a staging-dir name.  Anything
        unparseable or out of range has no live owner claim — treating it
        as dead is what lets GC make progress instead of skipping forever
        (a huge bogus pid used to raise OverflowError out of listdir)."""
        try:
            pid = int(pid_s)
        except ValueError:
            return False
        if pid <= 0:
            return False
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            return False        # owner is gone: orphaned
        except PermissionError:
            return True         # pid exists under another uid: assume live
        except (OverflowError, ValueError):
            return False        # absurd pid: no live owner claim
        return True

    def _gc_orphans(self) -> None:
        """Remove stale write debris: ``.tmp_ckpt_*`` staging directories
        (a crash during ``_write``) and ``ckpt_*.old.*`` backup directories
        (a crash during the commit swap).  Neither holds a committed
        checkpoint, so leftovers would otherwise accumulate forever.

        Both name forms carry the writer's pid; a dir whose writer is
        still ALIVE belongs to a concurrent peer mid-write and is spared —
        unless it is older than ``STALE_TMP_S``: an in-flight write is
        seconds old, so a stale "live" owner is a recycled pid and the dir
        is reaped (the stale-pid regression, tests/test_checkpoint_ft.py).
        Suffixless/unparseable names have no live owner claim and are
        reaped.
        """
        for name in os.listdir(self.root):
            staging = name.startswith(".tmp_ckpt_")
            backup = name.startswith("ckpt_") and ".old." in name
            if not (staging or backup):
                continue
            path = os.path.join(self.root, name)
            if self._pid_alive(name.rpartition(".")[2]):
                try:
                    age = time.time() - os.path.getmtime(path)
                except OSError:
                    continue    # raced with its owner's rename/cleanup
                if age < STALE_TMP_S:
                    continue    # a live peer's in-flight write
            shutil.rmtree(path, ignore_errors=True)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        """Flush the pending async write and shut the executor down."""
        if self._closed:
            return
        try:
            self.wait()
        finally:
            self._closed = True
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- save -------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Dict[str, Any]) -> str:
        # pid-suffixed staging name: a concurrent manager sharing this root
        # can tell a LIVE peer's in-flight write from a crashed one's
        # leftovers (see _gc_orphans).
        tmp = os.path.join(self.root, f".tmp_ckpt_{step:08d}.{os.getpid()}")
        final = os.path.join(self.root, f"ckpt_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        try:
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "extra": extra}, f)
                f.flush()
                os.fsync(f.fileno())
        except BaseException:
            # ENOSPC / partial write: remove the half-written staging dir
            # and raise loudly.  ``final`` was never touched, so whatever
            # checkpoint existed before this save is still loadable.
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        # commit by swap, never by delete-then-rename: if this process
        # dies between the renames, the old snapshot survives in the
        # pid-suffixed backup (reaped by _gc_orphans once we are dead)
        # instead of having been rmtree'd before the new one landed.
        backup = None
        if os.path.exists(final):
            backup = f"{final}.old.{os.getpid()}"
            if os.path.exists(backup):
                shutil.rmtree(backup)
            os.rename(final, backup)
        os.rename(tmp, final)           # atomic commit
        if backup is not None:
            shutil.rmtree(backup, ignore_errors=True)
        self._gc()
        return final

    def save(self, step: int, state: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot on the caller thread (device_get), write async."""
        flat = _flatten(state)           # synchronous snapshot
        extra = extra or {}
        self.wait()
        if self.async_save:
            self._pending = self._pool.submit(self._write, step, flat, extra)
        else:
            self._write(step, flat, extra)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ----------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            # skip anything that merely LOOKS like a checkpoint (stray
            # files, hand-made dirs like "ckpt_old") instead of raising —
            # a foreign entry must not brick every restore under this root.
            if not name.startswith("ckpt_"):
                continue
            suffix = name[len("ckpt_"):]
            if not suffix.isdigit():
                continue
            if not os.path.isdir(os.path.join(self.root, name)):
                continue
            out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def meta(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The ``extra`` dict of the given (default: latest) checkpoint,
        without loading the arrays — resume paths validate the cursor
        (fingerprint etc.) BEFORE committing to an array restore, so a
        wrong-run checkpoint fails with the right diagnostic instead of a
        shape mismatch."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"ckpt_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            return json.load(f)["extra"]

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, Dict[str, Any]]:
        """Restore into ``template``'s structure; optionally re-place onto
        ``shardings`` (a pytree of NamedSharding — the elastic path)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"ckpt_{step:08d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, meta["extra"]

    # -- gc ---------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"ckpt_{s:08d}"),
                          ignore_errors=True)
