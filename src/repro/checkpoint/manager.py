"""Checkpoint manager: atomic, async, keep-k, restore-with-resharding.

Layout (one directory per step, atomically renamed into place):

    <root>/ckpt_00001230/
        arrays.npz          flat {path -> array} of the state pytree
        meta.json           step, extra state (data-pipeline cursor, rng)

Restore takes a *template* pytree (e.g. from jax.eval_shape) and an
optional target sharding tree — restoring onto a different mesh is just
device_put with the new NamedShardings (the elastic-rescale path in
ft/elastic.py).  On a real multi-host cluster each host would write its
address-space shards (orbax-style); the format and the atomic-commit /
keep-k / async logic here are the substrate that sits under that.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3,
                 async_save: bool = True):
        self.root = root
        self.keep_last = keep_last
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._closed = False
        os.makedirs(root, exist_ok=True)
        self._gc_orphans()

    @classmethod
    def for_run(cls, root: str, fingerprint: str,
                keep_last: int = 3, async_save: bool = True
                ) -> "CheckpointManager":
        """A manager scoped to ONE run under a shared ``root``.

        Several standing sessions can point at the same checkpoint
        directory; without scoping they would overwrite each other's
        ``ckpt_<step>`` dirs (step counters collide) and keep-k GC would
        reap a peer's snapshots.  Scoping by the run fingerprint gives
        each distinct run its own subdirectory — same fingerprint, same
        subdirectory, so resume finds its own snapshots by construction.
        """
        return cls(os.path.join(root, f"run_{fingerprint[:16]}"),
                   keep_last=keep_last, async_save=async_save)

    def _gc_orphans(self) -> None:
        """Remove ``.tmp_ckpt_*`` staging directories left by a crash during
        ``_write`` — they were never renamed into place, so they hold no
        committed checkpoint and would otherwise accumulate forever.

        Staging names carry the writer's pid (``.tmp_ckpt_<step>.<pid>``);
        a tmp dir whose writer is still ALIVE belongs to a concurrent peer
        mid-``_write`` and must not be reaped out from under it.  Suffixless
        names (the pre-pid format) have no live owner claim and are reaped.
        """
        for name in os.listdir(self.root):
            if not name.startswith(".tmp_ckpt_"):
                continue
            pid_s = name.rpartition(".")[2]
            if pid_s.isdigit():
                try:
                    os.kill(int(pid_s), 0)
                except ProcessLookupError:
                    pass        # owner is gone: orphaned
                except PermissionError:
                    continue    # pid exists under another uid: assume live
                else:
                    continue    # owner alive: a live peer's staging dir
            shutil.rmtree(os.path.join(self.root, name),
                          ignore_errors=True)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        """Flush the pending async write and shut the executor down."""
        if self._closed:
            return
        try:
            self.wait()
        finally:
            self._closed = True
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- save -------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Dict[str, Any]) -> str:
        # pid-suffixed staging name: a concurrent manager sharing this root
        # can tell a LIVE peer's in-flight write from a crashed one's
        # leftovers (see _gc_orphans).
        tmp = os.path.join(self.root, f".tmp_ckpt_{step:08d}.{os.getpid()}")
        final = os.path.join(self.root, f"ckpt_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "extra": extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        self._gc()
        return final

    def save(self, step: int, state: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot on the caller thread (device_get), write async."""
        flat = _flatten(state)           # synchronous snapshot
        extra = extra or {}
        self.wait()
        if self.async_save:
            self._pending = self._pool.submit(self._write, step, flat, extra)
        else:
            self._write(step, flat, extra)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ----------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            # skip anything that merely LOOKS like a checkpoint (stray
            # files, hand-made dirs like "ckpt_old") instead of raising —
            # a foreign entry must not brick every restore under this root.
            if not name.startswith("ckpt_"):
                continue
            suffix = name[len("ckpt_"):]
            if not suffix.isdigit():
                continue
            if not os.path.isdir(os.path.join(self.root, name)):
                continue
            out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def meta(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The ``extra`` dict of the given (default: latest) checkpoint,
        without loading the arrays — resume paths validate the cursor
        (fingerprint etc.) BEFORE committing to an array restore, so a
        wrong-run checkpoint fails with the right diagnostic instead of a
        shape mismatch."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"ckpt_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            return json.load(f)["extra"]

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, Dict[str, Any]]:
        """Restore into ``template``'s structure; optionally re-place onto
        ``shardings`` (a pytree of NamedSharding — the elastic path)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"ckpt_{step:08d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, meta["extra"]

    # -- gc ---------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"ckpt_{s:08d}"),
                          ignore_errors=True)
