"""Checkpointing substrate: async sharded save/restore with atomic commits."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
