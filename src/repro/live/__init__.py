"""Live ingest: append-only log + standing windowed bootstrap sessions.

The production shape of the paper's incremental-results claim: batches
arrive continuously (``IngestLog``, or its crash-safe cross-process
sibling ``DurableIngestLog`` over on-disk sealed segments), one or more
standing ``LiveSession``s fold each batch into mergeable per-pane states
(O(Δn) per arrival, the ``PoissonDelta`` discipline) and re-emit an
accuracy report per batch — bounded memory, bounded lag, honest CIs
under duplication, reordering, loss, torn writes and load shedding.
"""
from repro.live.durable_log import (DurableIngestLog, LogLockedError,
                                    RecoveryReport)
from repro.live.log import BackpressureError, IngestLog, LogBatch
from repro.live.segment import (CorruptSegmentError, SegmentError,
                                TornSegmentError)
from repro.live.session import LiveCounters, LiveReport, LiveSession

__all__ = [
    "BackpressureError",
    "CorruptSegmentError",
    "DurableIngestLog",
    "IngestLog",
    "LiveCounters",
    "LiveReport",
    "LiveSession",
    "LogBatch",
    "LogLockedError",
    "RecoveryReport",
    "SegmentError",
    "TornSegmentError",
]
