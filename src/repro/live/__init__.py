"""Live ingest: append-only log + standing windowed bootstrap sessions.

The production shape of the paper's incremental-results claim: batches
arrive continuously (``IngestLog``), one or more standing ``LiveSession``s
fold each batch into mergeable per-pane states (O(Δn) per arrival, the
``PoissonDelta`` discipline) and re-emit an accuracy report per batch —
bounded memory, bounded lag, honest CIs under duplication, reordering,
loss and load shedding.
"""
from repro.live.log import BackpressureError, IngestLog, LogBatch
from repro.live.session import LiveCounters, LiveReport, LiveSession

__all__ = [
    "BackpressureError",
    "IngestLog",
    "LiveCounters",
    "LiveReport",
    "LiveSession",
    "LogBatch",
]
