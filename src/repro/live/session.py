"""LiveSession — a standing windowed bootstrap over an ingest stream.

One session = one statistic (optionally windowed via
``core.reduce_api.TumblingWindow`` / ``SlidingWindow``) kept warm against
a stream of ``LogBatch``es.  Every arrival folds O(Δn) work into
mergeable per-pane states and re-emits a ``LiveReport`` (estimate + CI +
stream health), the live form of the paper's ever-improving early
results.

Correctness under stream pathology (the robustness contract, all
asserted bitwise in tests/test_live.py):

* **Exactly-once folding.**  Batches are folded strictly in sequence
  order through a reorder buffer; a re-delivered sequence number is
  counted and dropped.  Because batch ``seq``'s Poisson weight stream is
  keyed ``offset_seed(base_seed, seq)`` (position, not arrival order),
  any duplicated/reordered delivery that folds the same set of batches
  produces bitwise identical states.
* **Watermark / late data.**  The watermark is the contiguous fold point
  (``next_seq`` / ``watermark_row``).  When the newest delivered seq runs
  ``LagPolicy.max_lag_batches`` ahead of it, the missing batches are
  declared lost: their row extent is charged to their panes as invalid
  (so ``p_eff`` drops and the CI widens — EARL §3.4, never a silent
  hole), and the watermark advances.  A lost batch that shows up later is
  folded into its pane if the pane is still live (``late="fold"``) or
  counted-and-dropped (``late="drop"``).
* **Sample shedding.**  When the observed backlog at fold time exceeds
  ``LagPolicy.shed_backlog``, the batch is Poisson-thinned by a seeded
  row mask (survival ``p_shed``, keyed by sequence number) and folded
  through the same exact-0/1 ``valid_mask`` multiply as every degraded
  path — bitwise equal to handing the mask to the kernels directly — and
  the report's ``correct(p_eff)`` widens the CI by the shed fraction.
* **Bounded memory.**  Windowed state is a ring of at most
  ``window.panes`` per-pane states; eviction is dropping a pane and
  re-merging survivors (never subtraction, never re-reading the log).
  The bound is enforced, not just intended: exceeding it raises.
* **Crash safety.**  ``checkpoint=`` snapshots the pane ring + fold
  cursor through ``CheckpointManager`` every ``checkpoint_every`` folds;
  ``resume=True`` restores (fingerprint-gated) and replays the log from
  ``next_seq``.  Kill-at-any-batch resume is bitwise equal to the
  uninterrupted run — position-keyed streams + in-order folding leave
  nothing dependent on wall-clock or arrival history.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import (fused_resample_states, offset_seed,
                                  seed_from_key)
from repro.core.reduce_api import (Statistic, Window, bind_params,
                                   split_params)
from repro.core.streaming import run_fingerprint
from repro.ft.policy import LagPolicy
from repro.live.log import IngestLog, LogBatch


@dataclasses.dataclass
class LiveCounters:
    """Stream-health totals for one standing session."""
    folded: int = 0              # batches folded exactly once
    duplicates: int = 0          # re-deliveries dropped by seq dedup
    reordered: int = 0           # arrivals ahead of the fold point
    gaps_skipped: int = 0        # batches declared lost by the watermark
    gap_rows: int = 0            # rows charged invalid for those batches
    late_folded: int = 0         # lost batches that arrived and folded
    late_dropped: int = 0        # lost batches dropped per policy
    shed_batches: int = 0        # batches folded through a shed mask
    shed_rows: int = 0           # rows removed by shedding


@dataclasses.dataclass(frozen=True)
class LiveReport:
    """One per-arrival emission: the windowed estimate + CI, the
    ``p_eff`` it was corrected with, and where the stream stands."""
    seq: int                     # the batch this emission folded
    watermark_seq: int           # highest contiguously folded seq
    watermark_row: int           # rows accounted below the watermark
    window_start: int            # first row the report covers (pane-aligned)
    window_end: int              # == watermark_row
    rows: int                    # rows charged to the window (incl. lost/shed)
    valid_rows: int              # rows that actually contributed
    p_eff: float                 # valid_rows / rows — the correct() fraction
    panes_live: int              # ring occupancy (<= memory bound)
    shed: bool                   # this fold went through a shed mask
    estimate: Any                # corrected point estimate
    thetas: Any                  # corrected bootstrap distribution
    report: Any                  # AccuracyReport / Group- / KeyedAccuracyReport
    counters: LiveCounters       # snapshot at emission time


@dataclasses.dataclass
class _Pane:
    """Ring slot: per-resample states (leading B), the unweighted estimate
    state, and the rows charged / validated against this pane."""
    states: Any
    est: Any
    rows: int = 0
    valid: int = 0


@partial(jax.jit, static_argnames=("spec", "B"), donate_argnums=(0, 1))
def _live_fold_jit(states, est, xb, mask, base_seed, seq, params, spec, B):
    """Fold one batch slice into one pane's carry.

    Same math and operand layout as ``core.streaming._stream_chunk_jit``
    (the bitwise contracts ride on that): the batch's implicit Poisson(1)
    weights come from ``offset_seed(base_seed, seq)`` — position-keyed,
    so fold-time (resume, replay, late arrival) never changes the draw —
    and ``mask`` is the exact 0/1 row mask (pane overlap ∩ shed ∩ valid).
    """
    stat = bind_params(spec, params)
    est = stat.update(est, xb, mask)
    delta = fused_resample_states(stat, offset_seed(base_seed, seq), xb, B,
                                  valid_mask=mask)
    return jax.vmap(stat.merge)(states, delta), est


class LiveSession:
    """Standing session over an ``IngestLog`` (see module docstring).

    ``stat`` is a ``Statistic`` (cumulative over the whole stream — one
    ever-growing pane) or a ``Window`` wrapping one.  ``feed(batch)`` is
    the delivery entry point (fault-injected tests drive it directly);
    ``poll()`` pulls everything new from the log and feeds it.  Both
    return the ``LiveReport``s emitted by the folds they caused.
    """

    def __init__(self, log: Optional[IngestLog], stat, B: int,
                 key: jax.Array, policy: Optional[LagPolicy] = None,
                 alpha: float = 0.05, checkpoint=None,
                 checkpoint_every: int = 1, resume: bool = False,
                 name: str = "live"):
        if isinstance(stat, Window):
            self.window: Optional[Window] = stat
            stat = stat.stat
        else:
            self.window = None
        if not isinstance(stat, Statistic):
            raise TypeError("stat must be a reduce_api.Statistic or a "
                            "Window around one")
        if not getattr(stat, "mergeable", True):
            raise ValueError(
                f"LiveSession folds per-batch states with merge(), but "
                f"{type(stat).__name__} sets mergeable=False")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        if resume and checkpoint is None:
            raise ValueError("resume=True needs checkpoint= (where would "
                             "the cursor come from?)")
        self.log = log
        self.stat = stat
        self.B = int(B)
        self.alpha = float(alpha)
        self.policy = policy if policy is not None else LagPolicy()
        self.name = name
        self._slide = self.window.slide if self.window else None
        #: hard ring-occupancy bound: the window's panes, or the single
        #: cumulative pane.  Enforced after every fold/eviction.
        self.memory_bound = self.window.panes if self.window else 1

        self._spec, self._params = split_params(stat)
        self._base_seed = seed_from_key(key)
        wsz = self.window.size if self.window else 0
        wsl = self.window.slide if self.window else 0
        self.fingerprint = run_fingerprint(
            self._spec, self._params, self.B, int(self._base_seed), wsz, wsl)

        mgr = checkpoint
        if isinstance(mgr, str):
            from repro.checkpoint.manager import CheckpointManager
            # several standing sessions may share one root path: scope by
            # run fingerprint so steps/GC never clobber a peer (satellite
            # of ISSUE 9; tested in tests/test_checkpoint_ft.py)
            mgr = CheckpointManager.for_run(mgr, self.fingerprint)
        self.checkpoint = mgr
        self.checkpoint_every = int(checkpoint_every)

        self._dim: Optional[int] = None
        self._ring: Dict[int, _Pane] = {}
        self._buffer: Dict[int, LogBatch] = {}
        self._lost: set = set()
        self._next_seq = 0
        self._max_seen = -1
        self._end_row = 0
        self.counters = LiveCounters()

        if resume:
            self._restore()
        if log is not None:
            log.register(self.name)
            if self._next_seq > 0:
                log.ack(self.name, self._next_seq - 1)

    # -- geometry -------------------------------------------------------
    def _pane_of(self, row: int) -> int:
        return 0 if self._slide is None else int(row) // self._slide

    def _keep_lo(self) -> int:
        """Lowest pane index the ring retains at the current watermark."""
        if self.window is None:
            return 0
        hi = self._pane_of(max(self._end_row - 1, 0))
        return max(0, hi - self.window.panes + 1)

    @property
    def panes_live(self) -> int:
        return len(self._ring)

    @property
    def watermark_seq(self) -> int:
        return self._next_seq - 1

    @property
    def watermark_row(self) -> int:
        return self._end_row

    # -- pane plumbing --------------------------------------------------
    def _init_pane(self) -> _Pane:
        stat, dim = self.stat, self._dim

        def _fresh(tree):       # unaliased buffers for the donated carry
            return jax.tree_util.tree_map(
                lambda a: jax.device_put(np.asarray(a)), tree)
        states = _fresh(jax.vmap(lambda _: stat.init_state(dim))(
            jnp.arange(self.B)))
        return _Pane(states=states, est=_fresh(stat.init_state(dim)))

    def _pane(self, p: int) -> _Pane:
        if p not in self._ring:
            self._ring[p] = self._init_pane()
        return self._ring[p]

    def _account_gap(self, row_a: int, row_b: int) -> None:
        """Charge lost rows [row_a, row_b) to their panes as invalid —
        they widen ``p_eff`` instead of leaving a silent hole.  Panes the
        advancing watermark is about to evict anyway are not created."""
        if row_b <= row_a:
            return
        if self.window is None:
            self._pane(0).rows += row_b - row_a
            return
        keep_lo = max(0, self._pane_of(row_b - 1) - self.window.panes + 1)
        for p in range(max(self._pane_of(row_a), keep_lo),
                       self._pane_of(row_b - 1) + 1):
            lo, hi = self.window.pane_rows(p)
            self._pane(p).rows += min(row_b, hi) - max(row_a, lo)

    def _evict(self) -> None:
        keep_lo = self._keep_lo()
        for p in [p for p in self._ring if p < keep_lo]:
            del self._ring[p]
        if len(self._ring) > self.memory_bound:
            raise RuntimeError(
                f"pane ring holds {len(self._ring)} panes, bound is "
                f"{self.memory_bound} — memory-bound invariant violated")

    # -- delivery -------------------------------------------------------
    def feed(self, batch: LogBatch) -> List[LiveReport]:
        """Deliver one batch (any order, any multiplicity); returns the
        reports emitted by the folds this delivery unlocked."""
        s = int(batch.seq)
        self._max_seen = max(self._max_seen, s)
        if s < self._next_seq:
            if s in self._lost:
                return self._late(batch)
            self.counters.duplicates += 1
            return []
        if s in self._buffer:
            self.counters.duplicates += 1
            return []
        if s != self._next_seq:
            self.counters.reordered += 1
        self._buffer[s] = batch
        return self._drain()

    def poll(self) -> List[LiveReport]:
        """Pull everything new from the log and fold it.  The backlog is
        observed FIRST (``_max_seen`` jumps to the newest sealed batch),
        so shedding decisions depend on the log state at poll time — a
        resumed session polling the same log makes the same decisions."""
        if self.log is None:
            raise ValueError("poll() needs a log; feed() batches directly")
        avail = self.log.next_seq
        self._max_seen = max(self._max_seen, avail - 1)
        out: List[LiveReport] = []
        for b in self.log.batches_from(self._next_seq):
            if b.seq in self._buffer or b.seq < self._next_seq:
                continue
            out.extend(self.feed(b))
        return out

    # -- folding --------------------------------------------------------
    def _drain(self) -> List[LiveReport]:
        out: List[LiveReport] = []
        while True:
            if self._next_seq in self._buffer:
                b = self._buffer.pop(self._next_seq)
                # advance BEFORE folding so the emitted report's
                # watermark_seq includes the batch it just folded
                self._next_seq += 1
                out.append(self._fold(b))
                if self.log is not None:
                    self.log.ack(self.name, self._next_seq - 1)
                if (self.checkpoint is not None and
                        self.counters.folded % self.checkpoint_every == 0):
                    self._save()
                continue
            if (self._buffer and
                    self._max_seen - self._next_seq
                    >= self.policy.max_lag_batches):
                # watermark advance: the gap [next_seq, first buffered)
                # is declared lost — charged invalid, CI widens
                m = min(self._buffer)
                self.counters.gaps_skipped += m - self._next_seq
                gap = self._buffer[m].row0 - self._end_row
                self.counters.gap_rows += gap
                self._lost.update(range(self._next_seq, m))
                self._account_gap(self._end_row, self._buffer[m].row0)
                self._end_row = self._buffer[m].row0
                self._next_seq = m
                self._evict()
                continue
            return out

    def _masks_for(self, row0: int, nb: int, valid: np.ndarray):
        """(pane, 0/1 mask over the batch) for every pane the rows
        [row0, row0+nb) overlap — masks are ``valid`` restricted to the
        pane's row range, exact 0.0/1.0 f32."""
        if self.window is None:
            return [(0, valid)]
        out = []
        for p in range(self._pane_of(row0), self._pane_of(row0 + nb - 1) + 1):
            lo, hi = self.window.pane_rows(p)
            m = np.zeros(nb, np.float32)
            a, b = max(lo, row0) - row0, min(hi, row0 + nb) - row0
            m[a:b] = valid[a:b]
            out.append((p, m))
        return out

    def _fold_into_panes(self, batch: LogBatch,
                         valid: np.ndarray) -> None:
        xb = np.asarray(batch.data, np.float32)
        if xb.ndim == 1:
            xb = xb[:, None]
        xd = jax.device_put(xb)
        seq = jnp.asarray(batch.seq, jnp.int32)
        for p, m in self._masks_for(batch.row0, len(xb), valid):
            pane = self._pane(p)
            pane.states, pane.est = _live_fold_jit(
                pane.states, pane.est, xd, jax.device_put(m),
                self._base_seed, seq, self._params, self._spec, self.B)
            pane.valid += int(m.sum())

    def _shed_mask(self, seq: int, nb: int) -> np.ndarray:
        """Seeded Poisson thinning mask for batch ``seq`` — deterministic
        under (shed_seed, seq), so a resumed run sheds identically."""
        rng = np.random.default_rng((int(self.policy.shed_seed), int(seq)))
        return (rng.random(nb) < self.policy.p_shed).astype(np.float32)

    def _fold(self, batch: LogBatch) -> LiveReport:
        if self._dim is None:
            d = np.asarray(batch.data)
            self._dim = 1 if d.ndim == 1 else int(d.shape[1])
        nb = batch.rows
        lag = self._max_seen - batch.seq
        shed = (self.policy.shed_backlog is not None
                and lag > self.policy.shed_backlog)
        valid = self._shed_mask(batch.seq, nb) if shed \
            else np.ones(nb, np.float32)
        if shed:
            self.counters.shed_batches += 1
            self.counters.shed_rows += nb - int(valid.sum())
        # charge the batch's full extent to its panes (shed rows stay in
        # the denominator — that is exactly what widens the CI)
        for p, m in self._masks_for(batch.row0, nb, np.ones(nb, np.float32)):
            self._pane(p).rows += int(m.sum())
        self._fold_into_panes(batch, valid)
        self._end_row = batch.row_end
        self.counters.folded += 1
        self._evict()
        return self._emit(batch.seq, shed)

    def _late(self, batch: LogBatch) -> List[LiveReport]:
        """A batch the watermark already declared lost showed up."""
        self._lost.discard(batch.seq)
        panes = ([0] if self.window is None else
                 list(range(self._pane_of(batch.row0),
                            self._pane_of(batch.row_end - 1) + 1)))
        if (self.policy.late != "fold"
                or any(p not in self._ring for p in panes)):
            self.counters.late_dropped += 1
            return []
        # rows were already charged at gap time; folding now adds their
        # valid contribution under the batch's own position-keyed stream
        self._fold_into_panes(batch,
                              np.ones(batch.rows, np.float32))
        self.counters.late_folded += 1
        return [self._emit(batch.seq, False)]

    # -- reporting ------------------------------------------------------
    def _emit(self, seq: int, shed: bool) -> LiveReport:
        stat = self.stat
        panes = sorted(self._ring)
        merged = self._ring[panes[0]]
        states, est = merged.states, merged.est
        for p in panes[1:]:
            states = jax.vmap(stat.merge)(states, self._ring[p].states)
            est = stat.merge(est, self._ring[p].est)
        rows = sum(self._ring[p].rows for p in panes)
        valid = sum(self._ring[p].valid for p in panes)
        p_eff = (valid / rows) if rows > 0 else 1.0
        thetas = stat.correct(jax.vmap(stat.finalize)(states), p_eff)
        estimate = stat.correct(stat.finalize(est), p_eff)
        window_start = (self._keep_lo() * self._slide
                        if self.window is not None else 0)
        return LiveReport(
            seq=int(seq), watermark_seq=self.watermark_seq,
            watermark_row=self._end_row, window_start=int(window_start),
            window_end=self._end_row, rows=int(rows), valid_rows=int(valid),
            p_eff=float(p_eff), panes_live=len(self._ring), shed=bool(shed),
            estimate=estimate, thetas=thetas,
            report=accuracy.report_for(
                thetas, alpha=self.alpha,
                num_groups=getattr(stat, "num_groups", None)),
            counters=dataclasses.replace(self.counters))

    def report(self) -> Optional[LiveReport]:
        """The current window's report without folding anything."""
        if not self._ring:
            return None
        return self._emit(self.watermark_seq, False)

    # -- crash safety ---------------------------------------------------
    def _save(self) -> None:
        mgr = self.checkpoint
        state = {f"p{p}": (self._ring[p].states, self._ring[p].est)
                 for p in sorted(self._ring)}
        mgr.save(self.counters.folded, state, extra={"cursor": {
            "kind": "live", "fingerprint": self.fingerprint,
            "next_seq": int(self._next_seq), "end_row": int(self._end_row),
            "max_seen": int(self._max_seen), "dim": int(self._dim),
            "lost": sorted(int(s) for s in self._lost),
            "counters": dataclasses.asdict(self.counters),
            "panes": {str(p): [int(self._ring[p].rows),
                               int(self._ring[p].valid)]
                      for p in sorted(self._ring)}}})

    def _restore(self) -> None:
        mgr = self.checkpoint
        cur = mgr.meta().get("cursor")
        if cur is None or cur.get("kind") != "live":
            raise ValueError(
                f"checkpoint under {mgr.root} has no LiveSession cursor — "
                "not a LiveSession checkpoint")
        if cur["fingerprint"] != self.fingerprint:
            raise ValueError(
                "checkpoint fingerprint mismatch: the snapshot was taken "
                "under a different (statistic, B, key, window) — resuming "
                "it would silently produce a different estimator "
                f"(checkpoint {cur['fingerprint'][:12]}…, "
                f"run {self.fingerprint[:12]}…)")
        self._dim = int(cur["dim"])
        template = {f"p{p}": jax.eval_shape(
            lambda: (jax.vmap(lambda _: self.stat.init_state(self._dim))(
                jnp.arange(self.B)),
                self.stat.init_state(self._dim)))
            for p in sorted(int(k) for k in cur["panes"])}
        state, _ = mgr.restore(template)

        def _fresh(tree):
            return jax.tree_util.tree_map(
                lambda a: jax.device_put(np.asarray(a)), tree)
        for p_str, (rows, valid) in cur["panes"].items():
            p = int(p_str)
            states, est = _fresh(state[f"p{p}"])
            self._ring[p] = _Pane(states=states, est=est,
                                  rows=int(rows), valid=int(valid))
        self._next_seq = int(cur["next_seq"])
        self._end_row = int(cur["end_row"])
        self._max_seen = int(cur["max_seen"])
        self._lost = set(int(s) for s in cur["lost"])
        self.counters = LiveCounters(**cur["counters"])
