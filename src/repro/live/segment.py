"""On-disk segment format for the durable ingest log.

One sealed segment file holds one ingest batch (= one immutable
``ShardedStore`` split), so the file at ``seg_<seq>.seg`` IS batch
``seq`` and recovery never has to guess where a batch starts:

    header   magic "EARLSEG1" | version u32 | dim u32 | first_seq u64
             | header_crc u32                                  (28 bytes)
    record   rec_magic u32 | seq u64 | rows u32 | payload_len u32
             | rec_crc u32                                     (24 bytes)
             payload: rows x dim float32, little-endian
             payload_crc u32
    footer   foot_magic u32 | n_records u32 | last_seq u64
             | body_crc u32 | foot_crc u32                     (24 bytes)

Every region is covered by a CRC32 (the header/record/footer CRCs cover
their own fixed-size prefix; ``payload_crc`` covers the rows;
``body_crc`` chains the record *metadata* — each sealed record header
plus its payload_crc bytes — so the footer binds the structure without
re-scanning payloads the record CRCs already cover; sealing a segment
costs exactly one CRC pass over the data).  Any single torn tail or
flipped bit is detectable.  The two failure classes recovery
must tell apart get distinct exceptions:

* ``TornSegmentError`` — the file ENDS before the structure does (a
  producer died mid-write, or the filesystem dropped un-fsynced pages).
  Recovery truncates here and resumes appending.
* ``CorruptSegmentError`` — the file is long enough but its bytes fail a
  CRC/magic check (bit rot, torn overwrite).  Same truncation response
  from the writer-side scanner; a tailing consumer may instead degrade
  the batch to a zero/invalid split under ``FailurePolicy``.

Sealing uses the checkpoint manager's atomic-rename discipline: the
segment is written to ``.tmp_seg_<seq>.<pid>`` and renamed into place,
so a half-written segment can never carry a sealed name.  Durability is
the caller's knob: ``sync=True`` fsyncs the file before the rename (and
the caller then fsyncs the directory); group commit re-syncs a batch of
sealed files at once via ``sync_file``/``sync_dir``.

All file bytes pass through ``_write`` — the seam the disk-fault
injectors in ``ft/inject.py`` patch to simulate ENOSPC mid-append.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"EARLSEG1"
VERSION = 1
REC_MAGIC = 0x30434552      # "REC0" little-endian
FOOT_MAGIC = 0x30544F46     # "FOT0" little-endian

_HEADER_BODY = struct.Struct("<8sIIQ")   # magic, version, dim, first_seq
_REC_BODY = struct.Struct("<IQII")       # magic, seq, rows, payload_len
_FOOT_BODY = struct.Struct("<IIQI")      # magic, n_records, last_seq, body_crc
_CRC = struct.Struct("<I")

HEADER_SIZE = _HEADER_BODY.size + _CRC.size      # 28
REC_HEADER_SIZE = _REC_BODY.size + _CRC.size     # 24
FOOTER_SIZE = _FOOT_BODY.size + _CRC.size        # 24

_CHUNK = 1 << 20


class SegmentError(IOError):
    """A segment file failed validation."""


class TornSegmentError(SegmentError):
    """The file ends before its structure does (crash mid-write)."""


class CorruptSegmentError(SegmentError):
    """The file is structurally complete but fails a CRC/magic check."""


def _write(f, data) -> None:
    """Single funnel for all segment bytes — the disk-fault injection
    seam (``ft.inject.enospc_after`` patches this to fail mid-append)."""
    f.write(data)


def _sealed(body: struct.Struct, *fields) -> bytes:
    b = body.pack(*fields)
    return b + _CRC.pack(zlib.crc32(b))


def segment_name(seq: int) -> str:
    return f"seg_{seq:08d}.seg"


def parse_segment_name(name: str) -> Optional[int]:
    if not (name.startswith("seg_") and name.endswith(".seg")):
        return None
    digits = name[len("seg_"):-len(".seg")]
    return int(digits) if digits.isdigit() else None


def list_segments(root: str) -> Dict[int, str]:
    """seq -> absolute path of every sealed segment file under ``root``."""
    out: Dict[int, str] = {}
    for name in os.listdir(root):
        seq = parse_segment_name(name)
        if seq is not None:
            out[seq] = os.path.join(root, name)
    return out


def _segment_pieces(seq: int, data: np.ndarray):
    """The byte regions of one sealed single-record segment, in file
    order: (prefix bytes, payload buffer, suffix bytes).  The payload is
    a zero-copy view of the (contiguous) array — ``write_segment``
    streams it straight to the file, and the single CRC pass over the
    data happens here."""
    data = np.ascontiguousarray(data, np.float32)
    if data.ndim == 1:
        data = data[:, None]
    if data.ndim != 2 or data.size == 0:
        raise ValueError(f"segment payload must be non-empty 2-D, "
                         f"got shape {data.shape}")
    rows, dim = data.shape
    payload = memoryview(data).cast("B")
    rec_header = _sealed(_REC_BODY, REC_MAGIC, seq, rows, len(payload))
    pcrc = _CRC.pack(zlib.crc32(payload))
    body_crc = zlib.crc32(pcrc, zlib.crc32(rec_header))
    prefix = _sealed(_HEADER_BODY, MAGIC, VERSION, dim, seq) + rec_header
    suffix = pcrc + _sealed(_FOOT_BODY, FOOT_MAGIC, 1, seq, body_crc)
    return prefix, payload, suffix


def build_segment(seq: int, data: np.ndarray) -> bytes:
    """Serialize one batch as one sealed single-record segment."""
    prefix, payload, suffix = _segment_pieces(seq, data)
    return prefix + bytes(payload) + suffix


def _check_crc(buf: bytes, pos: int, body: struct.Struct,
               what: str) -> Tuple:
    fields = body.unpack_from(buf, pos)
    (crc,) = _CRC.unpack_from(buf, pos + body.size)
    if zlib.crc32(buf[pos:pos + body.size]) != crc:
        raise CorruptSegmentError(f"{what} CRC mismatch at byte {pos}")
    return fields


def parse_segment(buf: bytes, *, expect_seq: Optional[int] = None,
                  expect_dim: Optional[int] = None
                  ) -> Tuple[int, int, List[Tuple[int, np.ndarray]]]:
    """Validate a full segment image; returns (first_seq, dim, records).

    Raises ``TornSegmentError`` whenever the buffer ends before the
    structure does (any truncation point maps here) and
    ``CorruptSegmentError`` for any in-place byte damage (any bit flip
    maps here) — the recovery scanner's two verdicts.
    """
    if len(buf) < HEADER_SIZE:
        raise TornSegmentError(
            f"short header ({len(buf)}/{HEADER_SIZE} bytes)")
    if buf[:len(MAGIC)] != MAGIC:
        raise CorruptSegmentError(f"bad magic {buf[:len(MAGIC)]!r}")
    magic, version, dim, first_seq = _check_crc(buf, 0, _HEADER_BODY,
                                                "header")
    if version != VERSION:
        raise CorruptSegmentError(f"unsupported version {version}")
    if dim < 1:
        raise CorruptSegmentError(f"bad dim {dim}")
    if expect_seq is not None and first_seq != expect_seq:
        raise CorruptSegmentError(
            f"segment claims first_seq {first_seq}, expected {expect_seq}")
    if expect_dim is not None and dim != expect_dim:
        raise CorruptSegmentError(
            f"segment dim {dim} does not match the log's dim {expect_dim}")

    pos = HEADER_SIZE
    records: List[Tuple[int, np.ndarray]] = []
    body_crc = 0
    while True:
        remaining = len(buf) - pos
        if remaining < _CRC.size:
            raise TornSegmentError(f"file ends at byte {pos + remaining} "
                                   "before a footer")
        (peek,) = _CRC.unpack_from(buf, pos)
        if peek == FOOT_MAGIC:
            break
        if peek != REC_MAGIC:
            raise CorruptSegmentError(
                f"bad record magic 0x{peek:08x} at byte {pos}")
        if remaining < REC_HEADER_SIZE:
            raise TornSegmentError(f"short record header at byte {pos}")
        _, seq, rows, payload_len = _check_crc(buf, pos, _REC_BODY,
                                               "record header")
        if rows < 1 or payload_len != rows * dim * 4:
            raise CorruptSegmentError(
                f"record at byte {pos} claims {rows} rows / "
                f"{payload_len} payload bytes (dim {dim})")
        end = pos + REC_HEADER_SIZE + payload_len + _CRC.size
        if len(buf) < end:
            raise TornSegmentError(
                f"short payload for record seq {seq} "
                f"({len(buf) - pos - REC_HEADER_SIZE}/{payload_len} bytes)")
        payload = buf[pos + REC_HEADER_SIZE:end - _CRC.size]
        (pcrc,) = _CRC.unpack_from(buf, end - _CRC.size)
        if zlib.crc32(payload) != pcrc:
            raise CorruptSegmentError(
                f"payload CRC mismatch for record seq {seq}")
        # the footer chains record METADATA (header + payload_crc), not
        # the payload bytes — those are the record CRC's job (one CRC
        # pass per byte of data, at write time and at read time)
        body_crc = zlib.crc32(buf[pos:pos + REC_HEADER_SIZE], body_crc)
        body_crc = zlib.crc32(buf[end - _CRC.size:end], body_crc)
        arr = np.frombuffer(payload, np.float32).reshape(rows, dim)
        records.append((int(seq), arr))
        pos = end

    if len(buf) - pos < FOOTER_SIZE:
        raise TornSegmentError(f"short footer at byte {pos}")
    _, n_records, last_seq, crc = _check_crc(buf, pos, _FOOT_BODY, "footer")
    if len(buf) != pos + FOOTER_SIZE:
        raise CorruptSegmentError(
            f"{len(buf) - pos - FOOTER_SIZE} trailing bytes after footer")
    if not records:
        raise CorruptSegmentError("segment has a footer but no records")
    if n_records != len(records):
        raise CorruptSegmentError(
            f"footer claims {n_records} records, found {len(records)}")
    if last_seq != records[-1][0]:
        raise CorruptSegmentError(
            f"footer claims last_seq {last_seq}, found {records[-1][0]}")
    if crc != body_crc:
        raise CorruptSegmentError("footer body CRC mismatch")
    return int(first_seq), int(dim), records


def read_segment(path: str, *, expect_seq: Optional[int] = None,
                 expect_dim: Optional[int] = None
                 ) -> Tuple[int, int, List[Tuple[int, np.ndarray]]]:
    """Read and fully validate one sealed segment file."""
    with open(path, "rb") as f:
        buf = f.read()
    return parse_segment(buf, expect_seq=expect_seq, expect_dim=expect_dim)


@dataclasses.dataclass(frozen=True)
class SegmentProbe:
    """Best-effort metadata of a (possibly damaged) segment file: what the
    degrade path needs to zero-fill a batch it cannot read — the extent
    (``rows`` x ``dim``) is trusted only if its own header CRCs held."""
    ok: bool
    error: Optional[str]            # None | "torn" | "corrupt"
    reason: str
    first_seq: Optional[int] = None
    dim: Optional[int] = None
    rows: Optional[int] = None


def probe_segment(path: str) -> SegmentProbe:
    try:
        with open(path, "rb") as f:
            buf = f.read()
    except OSError as exc:
        return SegmentProbe(ok=False, error="torn", reason=str(exc))
    first_seq = dim = rows = None
    try:
        if len(buf) >= HEADER_SIZE:
            try:
                _, _, dim, first_seq = _check_crc(buf, 0, _HEADER_BODY,
                                                  "header")
            except CorruptSegmentError:
                dim = first_seq = None
        if dim is not None and len(buf) >= HEADER_SIZE + REC_HEADER_SIZE:
            try:
                _, _, rows, _ = _check_crc(buf, HEADER_SIZE, _REC_BODY,
                                           "record header")
            except CorruptSegmentError:
                rows = None
        parse_segment(buf)
    except TornSegmentError as exc:
        return SegmentProbe(ok=False, error="torn", reason=str(exc),
                            first_seq=first_seq, dim=dim, rows=rows)
    except CorruptSegmentError as exc:
        return SegmentProbe(ok=False, error="corrupt", reason=str(exc),
                            first_seq=first_seq, dim=dim, rows=rows)
    return SegmentProbe(ok=True, error=None, reason="",
                        first_seq=first_seq, dim=dim, rows=rows)


def sync_file(path: str) -> None:
    """Make a sealed segment's bytes durable.  ``fdatasync`` (where the
    platform has it) flushes the data and the size-changing metadata a
    reader needs, but skips the pure-timestamp inode update — one fewer
    journal commit per segment than a full ``fsync``."""
    fd = os.open(path, os.O_RDONLY)
    try:
        getattr(os, "fdatasync", os.fsync)(fd)
    finally:
        os.close(fd)


def sync_dir(root: str) -> None:
    """fsync the directory so renames of sealed segments are durable."""
    fd = os.open(root, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_segment(root: str, seq: int, data: np.ndarray, *,
                  sync: bool = False) -> str:
    """Seal one batch as ``seg_<seq>.seg`` under ``root`` (atomic rename).

    ``sync=True`` fsyncs the file before the rename and the directory
    after it — the batch is durable when this returns.  ``sync=False``
    leaves flushing to the caller's group-commit (``sync_file`` +
    ``sync_dir``) or to the OS.  On any write failure the staging file is
    removed: a failed append never leaves a sealed name behind, so the
    log stays readable (ENOSPC contract).
    """
    prefix, payload, suffix = _segment_pieces(seq, data)
    tmp = os.path.join(root, f".tmp_seg_{seq:08d}.{os.getpid()}")
    final = os.path.join(root, segment_name(seq))
    try:
        with open(tmp, "wb") as f:
            _write(f, prefix)
            for off in range(0, len(payload), _CHUNK):
                _write(f, payload[off:off + _CHUNK])
            _write(f, suffix)
            if sync:
                f.flush()
                getattr(os, "fdatasync", os.fsync)(f.fileno())
        os.rename(tmp, final)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    if sync:
        sync_dir(root)
    return final
