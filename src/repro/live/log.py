"""IngestLog — append-only segmented batch log with backpressure.

A thin ingest facade over ``data/store.ShardedStore``: every appended
batch is sealed as one immutable split (``append_split``) and stamped
with a monotone *sequence number* (= its split index), so

* the log IS a ShardedStore — every existing read path (``iter_batches``,
  checksums, ``bootstrap_streaming``) works over the growing log;
* a batch's global row offset is ``store.offsets[seq]``, which is what
  lets a standing session place a (possibly late or re-delivered) batch
  into the correct window pane and key its Poisson weight stream by
  position (``offset_seed(base, seq)`` — the bitwise-resume contract);
* crash recovery is replay: a session checkpoint records its fold cursor
  (``next_seq``) and resumes by re-reading the log from there.

Backpressure is explicit: with ``capacity=k``, ``append`` blocks while
the slowest *registered* consumer is more than ``k`` batches behind, and
raises ``BackpressureError`` on timeout — the producer always learns it
is outrunning the analytics instead of growing an unbounded backlog.
(Consumers that want shedding instead of blocking set
``LagPolicy.shed_backlog`` on their session; the two compose.)
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.data.store import ShardedStore


class BackpressureError(RuntimeError):
    """``append`` timed out waiting for consumers to drain the backlog."""


@dataclasses.dataclass(frozen=True)
class LogBatch:
    """One delivered batch: its sequence number, the global row offset of
    its first row, and the rows themselves (2-D float32)."""
    seq: int
    row0: int
    data: np.ndarray

    @property
    def rows(self) -> int:
        return len(self.data)

    @property
    def row_end(self) -> int:
        return self.row0 + len(self.data)


class IngestLog:
    """Append-only batch log (see module docstring)."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.store = ShardedStore([])
        self._cv = threading.Condition()
        self._acked: Dict[str, int] = {}     # consumer -> last folded seq

    # -- producer side --------------------------------------------------
    @property
    def next_seq(self) -> int:
        return len(self.store.splits)

    @property
    def total_rows(self) -> int:
        return self.store.N

    def _backlog(self) -> int:
        """Batches the slowest registered consumer has not folded yet."""
        if not self._acked:
            return 0
        return self.next_seq - 1 - min(self._acked.values())

    def append(self, data: np.ndarray,
               timeout: Optional[float] = None) -> int:
        """Seal ``data`` as the next batch; returns its sequence number.

        Blocks while the backlog is at ``capacity`` (backpressure);
        ``timeout`` seconds of no progress raises ``BackpressureError``.
        With no registered consumers the log cannot measure lag and
        appends are never gated.

        The log owns the sealed bytes: ``data`` is copied, so a producer
        that reuses its staging buffer cannot mutate sealed history (or
        invalidate cached split checksums) — and a durable log's writer
        thread can seal the batch to disk after ``append`` returns.
        """
        data = np.array(data, np.float32, copy=True)
        if data.ndim == 1:
            data = data[:, None]
        with self._cv:
            if self.capacity is not None and self._acked:
                ok = self._cv.wait_for(
                    lambda: self._backlog() < self.capacity,
                    timeout=timeout)
                if not ok:
                    raise BackpressureError(
                        f"backlog {self._backlog()} >= capacity "
                        f"{self.capacity} for {timeout}s — consumers are "
                        "not keeping up")
            return self._seal(data)

    def _seal(self, data: np.ndarray) -> int:
        """Commit one normalized batch as the next split (called under
        ``_cv``).  ``DurableIngestLog`` overrides this to also hand the
        batch to its segment writer, keeping the on-disk sealing order
        identical to the in-memory sequence order."""
        return self.store.append_split(data)

    def flush(self) -> None:
        """Durability barrier — a no-op for the in-memory log."""

    def close(self) -> None:
        """Release producer-side resources — a no-op for the in-memory
        log (kept so producer code is generic over log kinds)."""

    def __enter__(self) -> "IngestLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- consumer side --------------------------------------------------
    def register(self, name: str) -> None:
        """Declare a consumer; its ack cursor now gates ``capacity``."""
        with self._cv:
            self._acked.setdefault(name, -1)

    def ack(self, name: str, seq: int) -> None:
        """Consumer ``name`` has durably folded everything through ``seq``
        — releases backpressured producers."""
        with self._cv:
            if seq > self._acked.get(name, -1):
                self._acked[name] = int(seq)
                self._cv.notify_all()

    def batch(self, seq: int) -> LogBatch:
        return LogBatch(seq=int(seq), row0=int(self.store.offsets[seq]),
                        data=self.store.read_split(seq))

    def batches_from(self, seq: int) -> List[LogBatch]:
        """All sealed batches with sequence number >= ``seq`` (snapshot)."""
        with self._cv:
            n = self.next_seq
        return [self.batch(s) for s in range(max(seq, 0), n)]
