"""DurableIngestLog — crash-safe, cross-process ``IngestLog``.

The in-memory ``IngestLog`` (live/log.py) dies with its process; this
subclass seals every appended batch as one on-disk segment file
(live/segment.py) while keeping the exact ``IngestLog`` API and the
monotone *seq = split index* contract, so ``LiveSession``,
``bootstrap_streaming`` and every other read path work unchanged over
the growing log.  Producers and consumers now share only a directory:

* **Producer** (``mode="append"``, single writer enforced by a pid lock
  file): ``append`` seals the batch in memory immediately and hands it
  to a background segment writer — write-behind group commit, the WAL
  idiom.  ``fsync`` picks the durability point:

  - ``"never"``  — write + atomic rename, no fsync.  Crash-safe against
    the *process* (a sealed name is always a complete file) but an OS
    crash may tear the tail — exactly what the recovery scanner exists
    for.
  - ``"batch"``  — group commit: sealed files are handed to a dedicated
    sync thread as they land and fsynced in coalesced groups of up to
    ``group`` files per directory sync.  The default: the dir-entry
    flush amortizes across the group, and because fsync is device I/O
    that releases the GIL, the commits overlap the writer's CPU-bound
    segment writes instead of serializing behind them.  ``flush()``
    drains both threads — the durability barrier is unchanged.
  - ``"always"`` — ``append`` returns only after the batch AND the
    directory entry are fsynced.  Zero loss window, full tax.

  ``flush()`` is the durability barrier (drains the writer and syncs);
  ``close()`` flushes, stops the writer, and releases the lock.  A
  writer failure (ENOSPC mid-append) is *loud*: the failed segment's
  staging file is removed (the sealed prefix stays readable) and the
  error re-raises from the next ``append``/``flush``.

* **Recovery** (producer start-up): scan ``seg_*.seg`` in strict seq
  order, fully CRC-validate each, and load the valid prefix into the
  in-memory store.  At the first torn/short/corrupt/missing segment the
  log TRUNCATES — that file and everything after it are unlinked, the
  damage is counted into ``FaultCounters`` (torn → ``short_reads``,
  CRC → ``checksum_failures``), and appending resumes at the truncation
  point.  The recovered prefix is bitwise identical to an in-memory
  ``IngestLog`` fed the same surviving batches (tests/test_durable_log.py
  asserts this at every truncation offset).

* **Consumer** (``mode="tail"``): read-only; ``next_seq`` /
  ``batches_from`` re-scan the directory for newly sealed segments, so a
  ``LiveSession`` polls a producer in another process with no other
  coordination, seeing every sealed batch exactly once (seq order
  dedups).  An unreadable segment follows ``FailurePolicy``:
  ``on_exhausted="degrade"`` zero-fills the batch's extent (known from
  its record header) as a LOST split that is never delivered — the
  session's watermark charges those rows invalid and ``correct(p_eff)``
  widens the CI (EARL §3.4) instead of the session dying;
  ``"raise"`` (default) surfaces the fault to the caller.

Known limits (ROADMAP): backpressure ack cursors are per-process (a
remote consumer cannot slow a producer yet — multi-consumer fan-out is
the open item), and the in-memory store mirrors the whole log (no
eviction/mmap of cold segments yet).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
from typing import List, Optional

import numpy as np

from repro.ft.inject import FaultCounters
from repro.ft.policy import FailurePolicy
from repro.live.log import IngestLog, LogBatch
from repro.live.segment import (CorruptSegmentError, SegmentError,
                                TornSegmentError, list_segments,
                                probe_segment, read_segment, segment_name,
                                sync_dir, sync_file, write_segment)

_LOCK_NAME = "writer.lock"
_STOP = object()

FSYNC_POLICIES = ("never", "batch", "always")


class LogLockedError(RuntimeError):
    """The log directory already has a live producer."""


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What the start-up scan found and did."""
    batches: int                 # sealed batches recovered into the store
    rows: int                    # total rows recovered
    truncated_at: Optional[int]  # first seq dropped (None: clean log)
    reason: str                  # why truncation happened ("" if clean)
    files_dropped: int           # segment files unlinked at/after the cut
    bytes_dropped: int           # their total size on disk
    tmp_reaped: int              # stale .tmp_seg_* staging files removed


def _pid_alive(pid_s: str) -> bool:
    try:
        pid = int(pid_s)
    except ValueError:
        return False
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    except (OverflowError, ValueError):
        return False
    return True


class DurableIngestLog(IngestLog):
    """On-disk ``IngestLog`` over a directory of sealed segment files
    (see module docstring)."""

    def __init__(self, root: str, capacity: Optional[int] = None,
                 fsync: str = "batch", group: int = 8,
                 mode: str = "append",
                 policy: Optional[FailurePolicy] = None,
                 counters: Optional[FaultCounters] = None,
                 queue_depth: int = 32):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync must be one of {FSYNC_POLICIES}, "
                             f"got {fsync!r}")
        if mode not in ("append", "tail"):
            raise ValueError(f"mode must be 'append' or 'tail', "
                             f"got {mode!r}")
        if group < 1:
            raise ValueError(f"group must be >= 1, got {group}")
        super().__init__(capacity)
        self.root = root
        self.fsync = fsync
        self.group = int(group)
        self.mode = mode
        self.policy = policy
        self.counters = counters if counters is not None else FaultCounters()
        self.lost_seqs: set = set()          # degraded (zero-filled) seqs
        self.recovery: Optional[RecoveryReport] = None
        self._stalled: set = set()           # unreadable, extent unknown
        self._lock_owned = False
        self._closed = False
        os.makedirs(root, exist_ok=True)

        if mode == "append":
            self._acquire_lock()
            self.recovery = self.recover()
            self._writer_exc: Optional[BaseException] = None
            self._wq: "queue.Queue" = queue.Queue(maxsize=queue_depth)
            self._writer = threading.Thread(
                target=self._writer_loop, name="segment-writer", daemon=True)
            self._writer.start()
            self._syncer: Optional[threading.Thread] = None
            if fsync == "batch":
                # group fsyncs run on their own thread: fsync is device
                # I/O that releases the GIL, so it overlaps the writer's
                # CPU-bound segment writes instead of serializing behind
                # them
                self._sq: "queue.Queue" = queue.Queue()
                self._syncer = threading.Thread(
                    target=self._syncer_loop, name="segment-syncer",
                    daemon=True)
                self._syncer.start()

    # -- geometry helpers ----------------------------------------------
    def _dim(self) -> Optional[int]:
        return int(self.store.splits[0].shape[1]) if self.store.splits \
            else None

    # -- producer side --------------------------------------------------
    def _acquire_lock(self) -> None:
        """Single-writer exclusivity via a pid lock file.  A lock whose
        owner is dead (or unparseable) is stale and reclaimed — the same
        liveness discipline as the checkpoint manager's orphan GC."""
        path = os.path.join(self.root, _LOCK_NAME)
        for _ in range(3):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                try:
                    os.write(fd, f"{os.getpid()}\n".encode())
                finally:
                    os.close(fd)
                self._lock_owned = True
                return
            except FileExistsError:
                try:
                    with open(path) as f:
                        pid_s = f.read().strip()
                except OSError:
                    pid_s = ""
                if pid_s and _pid_alive(pid_s):
                    raise LogLockedError(
                        f"{self.root} already has a live producer "
                        f"(pid {pid_s}); one writer per log")
                try:
                    os.unlink(path)          # stale lock: owner is dead
                except OSError:
                    pass
        raise LogLockedError(f"could not acquire writer lock in {self.root}")

    def recover(self) -> RecoveryReport:
        """Start-up scan: load the valid sealed prefix, truncate the rest
        (see module docstring).  Runs once, on an empty store."""
        if self.store.splits:
            raise RuntimeError("recover() runs at producer start-up, "
                               "before any batch is loaded")
        tmp_reaped = 0
        for name in os.listdir(self.root):
            # any staging file is garbage: we hold the writer lock, so
            # its writer is either us-in-a-past-life or dead
            if name.startswith(".tmp_seg_"):
                try:
                    os.unlink(os.path.join(self.root, name))
                    tmp_reaped += 1
                except OSError:
                    pass
        segs = list_segments(self.root)
        expect, rows, reason = 0, 0, ""
        while expect in segs:
            try:
                _, _, recs = read_segment(segs[expect], expect_seq=expect,
                                          expect_dim=self._dim())
                if len(recs) != 1:
                    raise CorruptSegmentError(
                        f"{len(recs)} records in one segment (the log "
                        "seals exactly one batch per segment)")
            except TornSegmentError as exc:
                self.counters.short_reads += 1
                reason = f"torn segment {expect}: {exc}"
                break
            except CorruptSegmentError as exc:
                self.counters.checksum_failures += 1
                reason = f"corrupt segment {expect}: {exc}"
                break
            self.store.append_split(np.asarray(recs[0][1]))
            rows += len(recs[0][1])
            expect += 1
        dropped = sorted(s for s in segs if s >= expect)
        if dropped and not reason:
            reason = (f"hole at seq {expect} "
                      f"(later segments {dropped} are unreachable)")
        bytes_dropped = 0
        for s in dropped:
            try:
                bytes_dropped += os.path.getsize(segs[s])
                os.unlink(segs[s])
            except OSError:
                pass
        return RecoveryReport(
            batches=expect, rows=rows,
            truncated_at=dropped[0] if dropped else None,
            reason=reason, files_dropped=len(dropped),
            bytes_dropped=bytes_dropped, tmp_reaped=tmp_reaped)

    def _seal(self, data: np.ndarray) -> int:
        """In-memory seal + hand-off to the segment writer, under ``_cv``
        so the on-disk sealing order is the sequence order."""
        if self.mode != "append":
            raise RuntimeError("append() needs mode='append' "
                               "(this log is a tailing consumer)")
        self._raise_writer_failure()
        seq = super()._seal(data)
        while True:
            try:
                self._wq.put((seq, data), timeout=0.1)
                return seq
            except queue.Full:
                self._raise_writer_failure()

    def append(self, data: np.ndarray,
               timeout: Optional[float] = None) -> int:
        seq = super().append(data, timeout)
        if self.fsync == "always":
            self.flush()
        return seq

    def _raise_writer_failure(self) -> None:
        if getattr(self, "_writer_exc", None) is not None:
            raise self._writer_exc

    def _writer_loop(self) -> None:
        while True:
            item = self._wq.get()
            try:
                if item is _STOP:
                    return
                if self._writer_exc is not None:
                    continue                 # drain after failure
                seq, data = item
                try:
                    path = write_segment(self.root, seq, data,
                                         sync=self.fsync == "always")
                    if self.fsync == "always":
                        pass                 # write_segment synced the dir
                    elif self.fsync == "batch":
                        self._sq.put(path)
                except BaseException as exc:
                    if isinstance(exc, OSError):
                        self.counters.io_errors += 1
                    self._writer_exc = exc
            finally:
                self._wq.task_done()

    def _syncer_loop(self) -> None:
        """Group commit: coalesce up to ``group`` sealed segments per
        commit cycle — one fsync per file plus ONE directory sync — so
        the dir-entry flush amortizes across the group while the device
        I/O overlaps the writer's next segment."""
        while True:
            paths = [self._sq.get()]
            done = 1
            try:
                while len(paths) < self.group:      # coalesce what's queued
                    try:
                        paths.append(self._sq.get_nowait())
                        done += 1
                    except queue.Empty:
                        break
                if paths[-1] is _STOP:
                    paths.pop()
                if not paths:
                    return
                if self._writer_exc is None:
                    try:
                        for path in paths:
                            sync_file(path)
                        # a full group earns its dir sync here; smaller
                        # drains defer it to the flush() barrier, which
                        # always dir-syncs — one rename flush per group
                        # instead of one per segment
                        if len(paths) >= self.group:
                            sync_dir(self.root)
                    except OSError as exc:
                        self.counters.io_errors += 1
                        self._writer_exc = exc
            finally:
                for _ in range(done):
                    self._sq.task_done()
            if done > len(paths):                   # _STOP was coalesced
                return

    def flush(self) -> None:
        """Durability barrier: every batch appended so far is sealed and
        (under ``fsync != "never"``) fsynced when this returns.  Re-raises
        a writer failure (e.g. ENOSPC) loudly."""
        if self.mode != "append" or self._closed:
            return
        self._wq.join()
        if self.fsync == "batch":
            self._sq.join()
            if self._writer_exc is None:
                try:
                    sync_dir(self.root)      # make every rename durable
                except OSError as exc:
                    self.counters.io_errors += 1
                    self._writer_exc = exc
        self._raise_writer_failure()

    def close(self) -> None:
        """Flush, stop the writer, release the lock.  Raises if the final
        flush finds a writer failure — but always releases."""
        if self.mode != "append" or self._closed:
            return
        try:
            self.flush()
        finally:
            self._closed = True
            self._wq.put(_STOP)
            self._writer.join(timeout=30.0)
            if self._syncer is not None:
                self._sq.put(_STOP)
                self._syncer.join(timeout=30.0)
            if self._lock_owned:
                try:
                    os.unlink(os.path.join(self.root, _LOCK_NAME))
                except OSError:
                    pass
                self._lock_owned = False

    # -- consumer side (cross-process tailing) -------------------------
    def refresh(self) -> int:
        """Pull newly sealed segments from disk into the in-memory store
        (tail mode only; the producer's own store is authoritative).
        Returns how many new batches became readable."""
        if self.mode != "append":
            return self._refresh_tail()
        return 0

    def _refresh_tail(self) -> int:
        added = 0
        while True:
            seq = len(self.store.splits)
            if seq in self._stalled:
                return added
            path = os.path.join(self.root, segment_name(seq))
            if not os.path.exists(path):
                return added
            try:
                _, _, recs = read_segment(path, expect_seq=seq,
                                          expect_dim=self._dim())
                if len(recs) != 1:
                    raise CorruptSegmentError(
                        f"{len(recs)} records in one segment")
            except SegmentError as exc:
                if isinstance(exc, TornSegmentError):
                    self.counters.short_reads += 1
                else:
                    self.counters.checksum_failures += 1
                if not (self.policy is not None
                        and self.policy.on_exhausted == "degrade"):
                    raise
                probe = probe_segment(path)
                dim = self._dim() if probe.dim is None else probe.dim
                if probe.rows is None or dim is None:
                    # extent unknown: later batches cannot be placed —
                    # stop here (and stay stopped) rather than guess
                    self._stalled.add(seq)
                    return added
                with self._cv:
                    self.store.append_split(
                        np.zeros((probe.rows, dim), np.float32))
                self.lost_seqs.add(seq)
                self.counters.splits_lost += 1
                continue
            with self._cv:
                self.store.append_split(np.asarray(recs[0][1]))
            added += 1

    def reload(self, seq: int) -> None:
        """Re-read segment ``seq`` from disk after out-of-band repair
        (e.g. the file was restored from a replica).  A batch previously
        degraded to zeros gets its real bytes swapped back in via
        ``replace_split`` — the identity-keyed checksum cache hands out a
        fresh crc for the new bytes.  Validation failures propagate."""
        if seq in self._stalled:
            self._stalled.discard(seq)       # retry the stalled scan
            self.refresh()
            return
        path = os.path.join(self.root, segment_name(seq))
        _, _, recs = read_segment(path, expect_seq=seq,
                                  expect_dim=self._dim())
        if len(recs) != 1:
            raise CorruptSegmentError(f"{len(recs)} records in one segment")
        with self._cv:
            self.store.replace_split(seq, np.asarray(recs[0][1]))
        self.lost_seqs.discard(seq)

    @property
    def next_seq(self) -> int:
        self.refresh()
        return IngestLog.next_seq.fget(self)        # type: ignore[attr-defined]

    def batches_from(self, seq: int) -> List[LogBatch]:
        """Sealed batches >= ``seq``, skipping degraded (lost) ones — the
        session's watermark sees the gap and charges it invalid."""
        self.refresh()
        return [b for b in super().batches_from(seq)
                if b.seq not in self.lost_seqs]

    def __enter__(self) -> "DurableIngestLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
