"""Approximation-based node-failure recovery (paper §3.4).

"Given a user specified approximation bound, even when most of the nodes
have been lost, a reasonable result can still be provided" — the surviving
shards are a uniform sample of the data (uniform because the store
hash-interleaves at ingest), so the AES machinery bounds the error of the
survivors-only result, and correct(·, p) rescales count-like statistics.

``failure_mask`` zeroes interior row blocks, so this path runs on EVERY
``DistributedEarl`` backend: ``backend="fused_rng"`` multiplies its
implicit weight tiles by the mask (``valid_mask``) instead of refusing
non-prefix masks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accuracy import AccuracyReport
from repro.core.bootstrap import BootstrapResult
from repro.core.distributed import DistributedEarl
from repro.core.reduce_api import Statistic, _as_2d


@dataclasses.dataclass
class ShardLossReport:
    result: Any
    cv: float
    ci_lo: Any
    ci_hi: Any
    shards_total: int
    shards_lost: int
    p_surviving: float
    meets_bound: bool             # cv <= sigma -> no recovery needed
    recommendation: str


def failure_mask(n_rows: int, n_shards: int,
                 lost: Sequence[int]) -> jnp.ndarray:
    """Row mask with the given shards zeroed (rows split contiguously)."""
    per = n_rows // n_shards
    mask = np.ones((n_rows,), np.float32)
    for s in lost:
        mask[s * per:(s + 1) * per] = 0.0
    return jnp.asarray(mask)


def estimate_with_failures(earl: DistributedEarl, values: jax.Array,
                           lost_shards: Sequence[int], n_shards: int,
                           sigma: float, key: jax.Array
                           ) -> ShardLossReport:
    """Bound the error of the survivors-only statistic (no task restart)."""
    x = _as_2d(values)
    mask = failure_mask(x.shape[0], n_shards, lost_shards)
    p = float(mask.mean())
    res: BootstrapResult = earl.estimate_with_loss_mask(
        x, mask, key, p=p)
    ok = res.cv <= sigma
    return ShardLossReport(
        result=res.estimate, cv=res.cv,
        ci_lo=res.report.ci_lo, ci_hi=res.report.ci_hi,
        shards_total=n_shards, shards_lost=len(lost_shards),
        p_surviving=p, meets_bound=ok,
        recommendation=("serve approximate result (within bound); "
                        "defer node recovery" if ok else
                        "error bound exceeded: trigger checkpoint restart "
                        "of lost shards"),
    )
