"""Approximation-based node-failure recovery (paper §3.4).

"Given a user specified approximation bound, even when most of the nodes
have been lost, a reasonable result can still be provided" — the surviving
shards are a uniform sample of the data (uniform because the store
hash-interleaves at ingest), so the AES machinery bounds the error of the
survivors-only result, and correct(·, p) rescales count-like statistics.

``failure_mask`` zeroes interior row blocks, so this path runs on EVERY
``DistributedEarl`` backend: ``backend="fused_rng"`` multiplies its
implicit weight tiles by the mask (``valid_mask``) instead of refusing
non-prefix masks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import DistributedEarl


@dataclasses.dataclass
class ShardLossReport:
    result: Any
    cv: float
    ci_lo: Any
    ci_hi: Any
    shards_total: int
    shards_lost: int
    p_surviving: float
    meets_bound: bool             # cv <= sigma -> no recovery needed
    recommendation: str


def failure_mask(n_rows: int, n_shards: int,
                 lost: Sequence[int]) -> jnp.ndarray:
    """Row mask with the given shards zeroed (rows split contiguously).

    Shard extents mirror ``pad_to_shards``/``sharded_fused_states``: rows
    are padded to a multiple of ``n_shards`` and split into ceil-sized
    blocks, so shard s owns rows [s·m, min((s+1)·m, n)) with
    m = ceil(n/n_shards).  The old floor-division extents drifted off the
    real shard boundaries whenever ``n_rows % n_shards != 0`` — and the
    last shard's tail rows could never be masked at all.
    """
    if not (0 < n_shards):
        raise ValueError(f"n_shards must be positive, got {n_shards}")
    for s in lost:
        if not (0 <= s < n_shards):
            raise ValueError(f"lost shard {s} out of range "
                             f"[0, {n_shards})")
    m = -(-n_rows // n_shards)                  # ceil: rows per shard
    mask = np.ones((n_rows,), np.float32)
    for s in lost:
        mask[s * m:min((s + 1) * m, n_rows)] = 0.0
    return jnp.asarray(mask)


def estimate_with_failures(earl: DistributedEarl, values: jax.Array,
                           lost_shards: Sequence[int], n_shards: int,
                           sigma: float, key: jax.Array
                           ) -> ShardLossReport:
    """Bound the error of the survivors-only statistic (no task restart).

    Thin veneer over the unified ``ft.policy.elastic_estimate`` path —
    kept for API stability; the report is identical to running the policy
    with ``ShardEvents(lost=lost_shards)``."""
    from repro.ft.policy import (FailurePolicy, ShardEvents,
                                 elastic_estimate)
    er = elastic_estimate(
        earl, values, key,
        ShardEvents(n_shards=n_shards, lost=tuple(lost_shards)),
        FailurePolicy(sigma=sigma))
    return er.report
