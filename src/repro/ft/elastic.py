"""Elastic scaling: rebuild the mesh at a new size and reshard a restored
checkpoint onto it.

Because checkpoints store full (unsharded) arrays keyed by tree path, a
restore onto any mesh is a device_put with that mesh's NamedShardings; the
sharding resolver (launch/sharding.py) recomputes divisibility-aware specs
for the new axis sizes, so e.g. dropping from 256 to 192 chips reshards
every dim that stops being divisible instead of failing.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint.manager import CheckpointManager


def mesh_for_devices(n_devices: int, model_parallel: int = 16,
                     devices=None) -> Mesh:
    """Largest (data, model) mesh that fits n_devices (elastic rescale)."""
    model = model_parallel
    while model > 1 and (n_devices % model or n_devices // model < 1):
        model //= 2
    data = n_devices // model
    devices = (jax.devices() if devices is None else devices)[:data * model]
    import numpy as np
    arr = np.array(devices).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def elastic_restore(manager: CheckpointManager, template: Any,
                    shardings: Any, step: Optional[int] = None
                    ) -> Tuple[Any, dict]:
    """Restore a checkpoint onto a (possibly different-size) mesh."""
    return manager.restore(template, step=step, shardings=shardings)
