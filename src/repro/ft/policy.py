"""One FailurePolicy behind recovery, stragglers, and elastic degradation.

The three pre-existing ft/ entry points answered the same question with
three ad-hoc surfaces: a shard died (`recovery`), a shard is late
(`straggler`), how do we keep running (`elastic`)?  EARL's §3.4 answer is
uniform — at the reduce, a dead shard and a late shard are the SAME event
(a missing partial), and the right response is never "wait" but "psum what
arrived, bound the error of the survivors, and only restart if the bound
misses sigma".  This module is that one code path:

* ``ShardEvents`` — what actually happened mid-run: shards lost outright,
  per-shard completion times (against ``FailurePolicy.deadline_s``).
* ``elastic_estimate`` — folds every failed-or-late shard into ONE row
  mask (``failure_mask``, mirroring the real ceil-division shard extents)
  and runs the mesh step once with that mask: each lost shard feeds a
  *masked partial psum* through the PR 6 ``valid_mask`` machinery —
  survivors' work is never recomputed, the lost shard's partial is exactly
  zero — and the CI widens honestly through ``correct(p)`` with
  p = surviving fraction.
* ``FailurePolicy`` — the verdict: ``meets_bound`` (cv ≤ sigma) drives
  ``continue_approximate`` (serve the bounded answer, defer recovery) vs
  ``checkpoint_restart`` (the bound is blown; restore from
  ``checkpoint``/``CheckpointManager`` and recompute the lost shards).
  The same policy object also carries the prefetch-path knobs the
  streaming driver uses (``retry``, ``on_exhausted``), so ONE object
  describes a deployment's failure behavior end to end.

``ft.recovery.estimate_with_failures`` and ``ft.straggler.DeadlineReducer``
are now thin veneers over this path (kept for API stability); their
results are bitwise identical to calling ``elastic_estimate`` directly
with the equivalent events.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core.reduce_api import _as_2d
from repro.ft.inject import RetryPolicy
from repro.ft.recovery import ShardLossReport, failure_mask

CONTINUE = "continue_approximate"
RESTART = "checkpoint_restart"


@dataclasses.dataclass
class ShardEvents:
    """What happened to the shards of one run."""
    n_shards: int
    lost: Tuple[int, ...] = ()
    completion_s: Optional[Sequence[float]] = None

    def late(self, deadline_s: Optional[float]) -> Tuple[int, ...]:
        if self.completion_s is None or deadline_s is None:
            return ()
        if len(self.completion_s) != self.n_shards:
            raise ValueError(
                f"completion_s has {len(self.completion_s)} entries for "
                f"{self.n_shards} shards")
        return tuple(i for i, t in enumerate(self.completion_s)
                     if t > deadline_s)


@dataclasses.dataclass
class FailurePolicy:
    """How a run responds to failure, end to end.

    ``sigma``/``deadline_s`` govern the reduce-side verdict
    (``elastic_estimate``); ``retry``/``on_exhausted`` govern the
    prefetch-side read path (``bootstrap_streaming``'s ``ResilientStore``);
    ``checkpoint`` names where a restart would restore from.
    """
    sigma: float = 0.05
    deadline_s: Optional[float] = None
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    on_exhausted: str = "raise"      # "raise" -> checkpoint restart path;
    #                                  "degrade" -> mask the lost split
    checkpoint: Optional[CheckpointManager] = None

    def decide(self, meets_bound: bool) -> str:
        return CONTINUE if meets_bound else RESTART


@dataclasses.dataclass(frozen=True)
class LagPolicy:
    """How a standing live session responds to ingest pathology.

    The live analogue of ``FailurePolicy``: instead of dead shards the
    hazards are a *gap* in the sequence (a batch that never shows up), a
    *late* batch (arrives after the watermark already passed it), and a
    *backlog* (arrivals outpace folding).  The responses mirror EARL's
    §3.4 stance — never wait unboundedly, degrade honestly:

    * ``max_lag_batches`` bounds the reorder buffer.  Once the newest
      delivered sequence number runs this far ahead of the fold point, the
      missing batches are declared lost, their row extent is masked out of
      ``p_eff``, and the watermark advances (the CI widens instead of the
      session stalling).
    * ``late`` decides what to do with a batch that arrives below the
      watermark after being declared lost: ``"fold"`` folds it into its
      pane if that pane is still live in the ring, ``"drop"`` counts and
      discards it.
    * ``shed_backlog``/``p_shed`` is the BlinkDB move: when the observed
      backlog at fold time exceeds ``shed_backlog`` batches, the session
      Poisson-subsamples each backlog batch (row survival probability
      ``p_shed``, seeded by ``shed_seed`` + sequence number) instead of
      falling further behind, and reports the widened CI via
      ``correct(p_eff)``.  ``None`` disables shedding.
    """
    max_lag_batches: int = 16
    late: str = "drop"               # "drop" | "fold"
    shed_backlog: Optional[int] = None
    p_shed: float = 0.5
    shed_seed: int = 0x5EED

    def __post_init__(self):
        if self.max_lag_batches < 1:
            raise ValueError(f"max_lag_batches must be >= 1, "
                             f"got {self.max_lag_batches}")
        if self.late not in ("drop", "fold"):
            raise ValueError(f"late must be 'drop' or 'fold', "
                             f"got {self.late!r}")
        if self.shed_backlog is not None and self.shed_backlog < 0:
            raise ValueError(f"shed_backlog must be >= 0, "
                             f"got {self.shed_backlog}")
        if not 0.0 < self.p_shed <= 1.0:
            raise ValueError(f"p_shed must be in (0, 1], got {self.p_shed}")


@dataclasses.dataclass
class ElasticReport:
    """Outcome of one degraded reduce."""
    report: ShardLossReport
    lost: Tuple[int, ...]            # shards that died mid-run
    late: Tuple[int, ...]            # shards past the deadline
    decision: str                    # CONTINUE or RESTART
    can_restart: bool                # a CheckpointManager is configured


def elastic_estimate(earl, values: jax.Array, key: jax.Array,
                     events: ShardEvents,
                     policy: FailurePolicy) -> ElasticReport:
    """Degraded mesh estimate under mid-run shard loss and lateness.

    Every failed-or-late shard is folded into one ``failure_mask`` and the
    jitted mesh step runs ONCE with it: the fused backend multiplies its
    implicit weight tiles by each shard's mask slice (interior holes
    included), so a dead shard contributes a zero partial psum and no
    surviving shard's work is recomputed.  The result is bitwise identical
    to ``earl.estimate_with_loss_mask`` under the same mask — the
    dedicated ``valid_mask`` oracle.
    """
    late = events.late(policy.deadline_s)
    dead = tuple(sorted(set(events.lost) | set(late)))
    x = _as_2d(values)
    mask = failure_mask(x.shape[0], events.n_shards, dead)
    p = float(mask.mean())
    res = earl.estimate_with_loss_mask(x, mask, key, p=p)
    ok = res.cv <= policy.sigma
    decision = policy.decide(ok)
    rep = ShardLossReport(
        result=res.estimate, cv=res.cv,
        ci_lo=res.report.ci_lo, ci_hi=res.report.ci_hi,
        shards_total=events.n_shards, shards_lost=len(dead),
        p_surviving=p, meets_bound=ok,
        recommendation=("serve approximate result (within bound); "
                        "defer node recovery" if ok else
                        "error bound exceeded: trigger checkpoint restart "
                        "of lost shards"),
    )
    return ElasticReport(report=rep, lost=tuple(sorted(events.lost)),
                         late=late, decision=decision,
                         can_restart=policy.checkpoint is not None)
