"""Deterministic fault injection and resilient counted reads.

The paper's §3.4 fault-tolerance story is only testable if failures are
*reproducible*: a flaky test that sometimes loses a shard proves nothing.
This module provides both halves of the harness:

* ``FaultyStore`` — a ``ShardedStore`` wrapper that injects a declared (or
  seeded, via ``FaultyStore.seeded``) plan of faults at read time:
  transient ``IOError``\\ s, latency spikes (stragglers), short reads, and
  corrupted batches.  Faults are keyed by ``(split, attempt)``, so a rerun
  with the same plan injects the identical failure sequence — and a
  *transient* fault clears after its declared number of attempts, while a
  ``permanent`` one models a shard that is simply gone.

* ``ResilientStore`` — the defensive read path the streaming driver's
  prefetch thread uses: every split read is validated (expected row count
  + crc32 against ``split_checksum``, which wrappers delegate to the
  PRISTINE underlying store, so corruption cannot forge it) and retried
  under a bounded ``RetryPolicy`` with exponential backoff; a read that
  overruns ``timeout`` counts as a deadline miss (the straggler signal)
  and is retried in the hope a replica answers faster.  When the budget is
  exhausted the policy decides: ``on_exhausted="raise"`` kills the run
  (the checkpoint-restart path picks it up), ``"degrade"`` marks the split
  LOST — its rows are zeroed and masked out downstream, the EARL §3.4
  move: survivors stay a uniform sample, the CI widens honestly via
  ``correct(p)``.

All observed faults/retries accumulate in a ``FaultCounters`` that the
streaming driver surfaces in its ``StreamReport``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import errno
import os
import time
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.store import ShardedStore

FAULT_KINDS = ("io", "latency", "short", "corrupt")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault: ``kind`` on ``split`` for its first ``attempts``
    reads (``permanent=True`` = never clears — a lost shard)."""
    split: int
    kind: str                 # "io" | "latency" | "short" | "corrupt"
    attempts: int = 1
    latency_s: float = 0.05
    permanent: bool = False

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with exponential backoff and a per-read deadline.

    ``max_attempts`` total read attempts per split; the k-th retry sleeps
    ``base_delay * 2**(k-1)`` seconds first; a successful read slower than
    ``timeout`` seconds counts as a deadline miss and is retried (a
    straggler is a temporarily-failed shard) — except on the final
    attempt, where valid-but-late data is accepted rather than discarded.
    """
    max_attempts: int = 3
    base_delay: float = 0.01
    timeout: Optional[float] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")

    def delay(self, failures: int) -> float:
        return float(self.base_delay) * (2.0 ** max(failures - 1, 0))


@dataclasses.dataclass
class FaultCounters:
    """Observed fault/retry totals (surfaced in ``StreamReport``)."""
    io_errors: int = 0
    short_reads: int = 0
    checksum_failures: int = 0
    deadline_misses: int = 0
    retries: int = 0
    backoff_s: float = 0.0
    splits_lost: int = 0
    duplicates: int = 0
    reordered: int = 0

    @property
    def total_faults(self) -> int:
        return (self.io_errors + self.short_reads +
                self.checksum_failures + self.deadline_misses)


class FaultExhaustedError(IOError):
    """A split failed every attempt the ``RetryPolicy`` allowed."""

    def __init__(self, split: int, attempts: int, last: str):
        self.split = split
        self.attempts = attempts
        super().__init__(
            f"split {split} failed all {attempts} read attempts "
            f"(last failure: {last})")


class FaultyStore(ShardedStore):
    """``ShardedStore`` with a deterministic fault plan injected at read
    time.  Shares the inner store's ``ReadStats`` (every injected retry is
    a real counted read) and delegates ``split_checksum`` to the pristine
    inner store, so corrupted/short reads are *detectable*."""

    def __init__(self, inner: ShardedStore, faults: Sequence[Fault] = ()):
        super().__init__(inner.splits)
        self.inner = inner
        self.stats = inner.stats
        self.faults = tuple(faults)
        for f in self.faults:
            if not (0 <= f.split < len(self.splits)):
                raise ValueError(f"fault names split {f.split}, but the "
                                 f"store has {len(self.splits)} splits")
        self._attempts = [0] * len(self.splits)
        self.injected = FaultCounters()

    @classmethod
    def seeded(cls, inner: ShardedStore, seed: int,
               p_io: float = 0.0, p_latency: float = 0.0,
               p_short: float = 0.0, p_corrupt: float = 0.0,
               latency_s: float = 0.05,
               attempts: int = 1) -> "FaultyStore":
        """Draw a reproducible fault plan: each split independently gets at
        most one transient fault, chosen by a ``default_rng(seed)`` — the
        same seed always yields the same plan."""
        rng = np.random.default_rng(seed)
        plan: List[Fault] = []
        probs = (("io", p_io), ("latency", p_latency),
                 ("short", p_short), ("corrupt", p_corrupt))
        for s in range(len(inner.splits)):
            u = float(rng.random())
            acc = 0.0
            for kind, p in probs:
                acc += p
                if u < acc:
                    plan.append(Fault(split=s, kind=kind, attempts=attempts,
                                      latency_s=latency_s))
                    break
        return cls(inner, plan)

    def _active_fault(self, i: int, attempt: int) -> Optional[Fault]:
        for f in self.faults:
            if f.split == i and (f.permanent or attempt < f.attempts):
                return f
        return None

    def split_checksum(self, i: int) -> int:
        return self.inner.split_checksum(i)

    def read_split(self, i: int) -> np.ndarray:
        attempt = self._attempts[i]
        self._attempts[i] += 1
        fault = self._active_fault(i, attempt)
        data = self.inner.read_split(i)
        if fault is None:
            return data
        if fault.kind == "io":
            self.injected.io_errors += 1
            raise IOError(f"injected IOError on split {i} "
                          f"(attempt {attempt})")
        if fault.kind == "latency":
            self.injected.deadline_misses += 1
            time.sleep(fault.latency_s)
            return data
        if fault.kind == "short":
            self.injected.short_reads += 1
            return data[:max(len(data) - max(1, len(data) // 3), 0)]
        # corrupt: flip a deterministic subset of values on a COPY
        self.injected.checksum_failures += 1
        bad = np.array(data, copy=True)
        flat = bad.reshape(-1)
        flat[::max(1, flat.size // 7)] = flat[::max(1, flat.size // 7)] + 1.0
        return bad

    # -- delivery-order faults (live-ingest path) ----------------------
    def delivery_plan(self, seed: int, p_duplicate: float = 0.0,
                      max_reorder: int = 0) -> List[int]:
        """A seeded, perturbed delivery ORDER over this store's splits.

        Read faults above corrupt *what* a split returns; a live ingest
        channel additionally corrupts *when and how often* a batch shows
        up.  The plan is a list of split indices in delivery order where

        * each split may be displaced backward by at most ``max_reorder``
          positions (stable sort on ``i + U{0..max_reorder}``, so the
          displacement bound is exact — a watermark with lateness bound
          ``max_reorder`` never has to skip a batch that still shows up),
        * each split is independently re-delivered with probability
          ``p_duplicate`` a few slots after its first delivery.

        Every split appears at least once — these are delivery faults, not
        data loss.  The same ``seed`` always yields the same plan;
        ``injected.duplicates`` / ``injected.reordered`` record what the
        plan contains so ingest tests can assert exactly-once folding
        against known injection counts.
        """
        if not 0.0 <= p_duplicate <= 1.0:
            raise ValueError(f"p_duplicate must be in [0, 1], "
                             f"got {p_duplicate}")
        if max_reorder < 0:
            raise ValueError(f"max_reorder must be >= 0, got {max_reorder}")
        rng = np.random.default_rng(seed)
        n = len(self.splits)
        keys = np.arange(n) + rng.integers(0, max_reorder + 1, size=n)
        order = list(np.argsort(keys, kind="stable"))
        self.injected.reordered += int(
            sum(1 for pos, s in enumerate(order) if s != pos))
        echoes = []                      # (insert_after_pos, split)
        for pos, s in enumerate(order):
            if float(rng.random()) < p_duplicate:
                # echo the batch a couple of slots after its delivery
                echoes.append((pos + 1 + int(rng.integers(0, 3)), int(s)))
                self.injected.duplicates += 1
        plan = [int(s) for s in order]
        for at, s in sorted(echoes, reverse=True):
            plan.insert(min(at, len(plan)), s)
        return plan

    def iter_delivery(self, seed: int, p_duplicate: float = 0.0,
                      max_reorder: int = 0):
        """Yield ``(split_index, data)`` in the perturbed delivery order of
        ``delivery_plan`` — the faulty channel a live session drinks from.
        Reads go through ``read_split`` so per-split read faults compose
        with delivery faults."""
        for s in self.delivery_plan(seed, p_duplicate, max_reorder):
            yield s, self.read_split(s)


# -- disk faults (durable-log path) ---------------------------------------
# The read-time injectors above corrupt what a SPLIT returns; a durable
# segment log additionally fails at the FILE layer.  These three injectors
# produce, deterministically, the exact on-disk images the recovery
# scanner (live/durable_log.py) must survive.  They damage files — the
# counters accrue where the damage is *observed*: torn tails count as
# ``short_reads``, flipped bits as ``checksum_failures`` (caught by the
# per-record CRC), ENOSPC as ``io_errors``, and a batch degraded to an
# invalid split as ``splits_lost``.

def torn_write(path: str, keep_bytes: int) -> None:
    """Truncate ``path`` to its first ``keep_bytes`` bytes — the on-disk
    image of a producer killed mid-write (or an OS crash dropping the
    un-fsynced tail of a sealed segment)."""
    size = os.path.getsize(path)
    if not 0 <= keep_bytes <= size:
        raise ValueError(f"keep_bytes must be in [0, {size}], "
                         f"got {keep_bytes}")
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)


def bit_flip(path: str, offset: int, mask: int = 0x01) -> None:
    """XOR one byte of ``path`` with ``mask`` — silent media corruption,
    caught by the segment format's per-record CRC32 framing."""
    if not mask & 0xFF:
        raise ValueError(f"mask must flip at least one bit, got {mask:#x}")
    size = os.path.getsize(path)
    if not 0 <= offset < size:
        raise ValueError(f"offset must be in [0, {size}), got {offset}")
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)[0]
        f.seek(offset)
        f.write(bytes([b ^ (mask & 0xFF)]))


@contextlib.contextmanager
def enospc_after(nbytes: int):
    """Within this context the 'disk' accepts ``nbytes`` more segment
    bytes, then every further write raises ``ENOSPC`` — mid-record if the
    budget runs out there.  Patches the single write seam all segment
    bytes funnel through (``live.segment._write``), so the failure mode
    is exactly a real full disk: a partial staging file (which the
    writer unlinks — the sealed log stays readable) and a loud OSError.
    """
    from repro.live import segment as _segment

    if nbytes < 0:
        raise ValueError(f"nbytes must be >= 0, got {nbytes}")
    budget = {"left": int(nbytes)}
    orig = _segment._write

    def _failing(f, data):
        take = min(len(data), budget["left"])
        if take:
            orig(f, data[:take])
            budget["left"] -= take
        if take < len(data):
            raise OSError(errno.ENOSPC,
                          "No space left on device (injected)")

    _segment._write = _failing
    try:
        yield budget
    finally:
        _segment._write = orig


class ResilientStore(ShardedStore):
    """Retry/verify wrapper: every split read is validated against the
    pristine checksum and expected row count, retried under ``retry``, and
    — if the budget is exhausted — either raised (``on_exhausted="raise"``)
    or degraded to a LOST split whose rows are zeroed and recorded in
    ``lost_splits`` for downstream masking (``on_exhausted="degrade"``).
    """

    def __init__(self, store: ShardedStore, retry: RetryPolicy,
                 counters: Optional[FaultCounters] = None,
                 on_exhausted: str = "raise"):
        if on_exhausted not in ("raise", "degrade"):
            raise ValueError(f"on_exhausted must be 'raise' or 'degrade', "
                             f"got {on_exhausted!r}")
        super().__init__(store.splits)
        self.store = store
        self.stats = store.stats
        self.retry = retry
        self.counters = counters if counters is not None else FaultCounters()
        self.on_exhausted = on_exhausted
        self.lost_splits: List[int] = []

    def split_checksum(self, i: int) -> int:
        return self.store.split_checksum(i)

    def invalid_row_ranges(self) -> List[Tuple[int, int]]:
        """Global row ranges of splits lost so far (for chunk masking)."""
        return [(int(self.offsets[s]), int(self.offsets[s + 1]))
                for s in sorted(self.lost_splits)]

    def _validate(self, i: int, data: np.ndarray) -> Optional[str]:
        if len(data) != self.split_sizes[i]:
            self.counters.short_reads += 1
            return f"short read ({len(data)}/{self.split_sizes[i]} rows)"
        crc = zlib.crc32(np.ascontiguousarray(data).tobytes())
        if crc != self.store.split_checksum(i):
            self.counters.checksum_failures += 1
            return "checksum mismatch"
        return None

    def read_split(self, i: int) -> np.ndarray:
        policy = self.retry
        failures = 0
        last = "unknown"
        for attempt in range(policy.max_attempts):
            final = attempt == policy.max_attempts - 1
            t0 = time.perf_counter()
            try:
                data = self.store.read_split(i)
            except (IOError, OSError) as exc:
                self.counters.io_errors += 1
                last = f"{type(exc).__name__}: {exc}"
                data = None
            if data is not None:
                elapsed = time.perf_counter() - t0
                bad = self._validate(i, data)
                if bad is None:
                    slow = (policy.timeout is not None
                            and elapsed > policy.timeout)
                    if slow:
                        self.counters.deadline_misses += 1
                        last = (f"deadline miss "
                                f"({elapsed:.3f}s > {policy.timeout}s)")
                    if not slow or final:
                        # valid data: accept (even late data on the final
                        # attempt — slow beats lost)
                        return data
                else:
                    last = bad
            if not final:
                failures += 1
                self.counters.retries += 1
                d = policy.delay(failures)
                self.counters.backoff_s += d
                time.sleep(d)
        if self.on_exhausted == "degrade":
            # EARL §3.4: the shard is LOST — zero its rows, mask them out
            # downstream, widen the CI via correct(p).  Survivors remain a
            # uniform sample because the store interleaves at ingest.
            self.lost_splits.append(i)
            self.counters.splits_lost += 1
            head = self.splits[i]
            return np.zeros((self.split_sizes[i],) + head.shape[1:],
                            head.dtype)
        raise FaultExhaustedError(i, policy.max_attempts, last)
