"""Fault-tolerance substrate (paper §3.4 + classical mechanisms).

EARL's §3.4 insight: a failed data shard turns an exact job into a sampled
one — instead of restarting, re-weight the survivors (correct(·, p)) and
report the result WITH a bootstrap error bound; recover only if the bound
misses the target.  Combined here with the classical substrate: checkpoint
restart (checkpoint/), elastic re-meshing, and deadline-based straggler
mitigation (a straggler is just a temporarily-failed shard).
"""
from repro.ft.recovery import (ShardLossReport, estimate_with_failures,
                               failure_mask)
from repro.ft.elastic import elastic_restore, mesh_for_devices
from repro.ft.straggler import DeadlineReducer, StragglerReport

__all__ = ["ShardLossReport", "estimate_with_failures", "failure_mask",
           "elastic_restore", "mesh_for_devices", "DeadlineReducer",
           "StragglerReport"]
