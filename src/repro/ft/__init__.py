"""Fault-tolerance substrate (paper §3.4 + classical mechanisms).

EARL's §3.4 insight: a failed data shard turns an exact job into a sampled
one — instead of restarting, re-weight the survivors (correct(·, p)) and
report the result WITH a bootstrap error bound; recover only if the bound
misses the target.  Combined here with the classical substrate: checkpoint
restart (checkpoint/), elastic re-meshing, deadline-based straggler
mitigation (a straggler is just a temporarily-failed shard), deterministic
fault injection (inject.py) and the unified FailurePolicy (policy.py) that
recovery/straggler/elastic all route through.
"""
from repro.ft.recovery import (ShardLossReport, estimate_with_failures,
                               failure_mask)
from repro.ft.elastic import elastic_restore, mesh_for_devices
from repro.ft.straggler import DeadlineReducer, StragglerReport
from repro.ft.inject import (Fault, FaultCounters, FaultExhaustedError,
                             FaultyStore, ResilientStore, RetryPolicy,
                             bit_flip, enospc_after, torn_write)
from repro.ft.policy import (CONTINUE, RESTART, ElasticReport,
                             FailurePolicy, LagPolicy, ShardEvents,
                             elastic_estimate)

__all__ = ["ShardLossReport", "estimate_with_failures", "failure_mask",
           "elastic_restore", "mesh_for_devices", "DeadlineReducer",
           "StragglerReport", "Fault", "FaultCounters",
           "FaultExhaustedError", "FaultyStore", "ResilientStore",
           "RetryPolicy", "bit_flip", "enospc_after", "torn_write",
           "CONTINUE", "RESTART", "ElasticReport",
           "FailurePolicy", "LagPolicy", "ShardEvents", "elastic_estimate"]
