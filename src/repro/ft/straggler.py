"""Deadline-based straggler mitigation via EARL early termination.

A straggler is a shard whose partial result misses the reduce deadline.
Classical systems wait or re-execute; EARL's early-termination view says:
the on-time shards are a uniform sample — emit their statistic with a
bootstrap bound, and only wait/restart if the bound misses sigma.  This is
the paper's fault-tolerance argument applied to *slowness* instead of
*death* (the two are indistinguishable at a deadline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.distributed import DistributedEarl
from repro.ft.recovery import ShardLossReport, estimate_with_failures


@dataclasses.dataclass
class StragglerReport:
    on_time: int
    late: int
    deadline_s: float
    report: ShardLossReport


class DeadlineReducer:
    """Simulated deadline reduce over per-shard completion times."""

    def __init__(self, earl: DistributedEarl, n_shards: int,
                 sigma: float = 0.05):
        self.earl = earl
        self.n_shards = n_shards
        self.sigma = sigma

    def reduce(self, values: jax.Array, completion_s: Sequence[float],
               deadline_s: float, key: jax.Array) -> StragglerReport:
        from repro.ft.policy import (FailurePolicy, ShardEvents,
                                     elastic_estimate)
        er = elastic_estimate(
            self.earl, values, key,
            ShardEvents(n_shards=self.n_shards,
                        completion_s=tuple(completion_s)),
            FailurePolicy(sigma=self.sigma, deadline_s=deadline_s))
        return StragglerReport(on_time=self.n_shards - len(er.late),
                               late=len(er.late), deadline_s=deadline_s,
                               report=er.report)
