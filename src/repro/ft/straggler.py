"""Deadline-based straggler mitigation via EARL early termination.

A straggler is a shard whose partial result misses the reduce deadline.
Classical systems wait or re-execute; EARL's early-termination view says:
the on-time shards are a uniform sample — emit their statistic with a
bootstrap bound, and only wait/restart if the bound misses sigma.  This is
the paper's fault-tolerance argument applied to *slowness* instead of
*death* (the two are indistinguishable at a deadline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.distributed import DistributedEarl
from repro.ft.recovery import ShardLossReport, estimate_with_failures


@dataclasses.dataclass
class StragglerReport:
    on_time: int
    late: int
    deadline_s: float
    report: ShardLossReport


class DeadlineReducer:
    """Simulated deadline reduce over per-shard completion times."""

    def __init__(self, earl: DistributedEarl, n_shards: int,
                 sigma: float = 0.05):
        self.earl = earl
        self.n_shards = n_shards
        self.sigma = sigma

    def reduce(self, values: jax.Array, completion_s: Sequence[float],
               deadline_s: float, key: jax.Array) -> StragglerReport:
        late = [i for i, t in enumerate(completion_s) if t > deadline_s]
        rep = estimate_with_failures(self.earl, values, late,
                                     self.n_shards, self.sigma, key)
        return StragglerReport(on_time=self.n_shards - len(late),
                               late=len(late), deadline_s=deadline_s,
                               report=rep)
