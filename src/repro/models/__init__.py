"""Model zoo: composable decoder/enc-dec LMs for the assigned architectures."""
from repro.models.config import (SHAPES, SMOKE_SHAPES, ModelConfig,
                                 ShapeConfig, shape_is_supported)
from repro.models.decoder import (decode_step, embed, forward_hidden,
                                  init_params, init_serve_cache,
                                  logits_from_hidden, loss_fn,
                                  per_example_loss, prefill)
from repro.models.partitioning import (batch_axes, cache_axes, logical_axes,
                                       param_axes)

__all__ = [
    "SHAPES", "SMOKE_SHAPES", "ModelConfig", "ShapeConfig",
    "shape_is_supported",
    "decode_step", "embed", "forward_hidden", "init_params",
    "init_serve_cache", "logits_from_hidden", "loss_fn", "per_example_loss",
    "prefill",
    "batch_axes", "cache_axes", "logical_axes", "param_axes",
]
