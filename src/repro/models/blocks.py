"""Block-level dispatch: init / apply / cache-init for every block kind."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, Any]

_ATTN_SELF = ("full", "swa", "local", "global", "xattn", "enc", "dec")


def _kind_window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window if kind in ("swa", "local") else 0


def _kind_causal(kind: str) -> bool:
    return kind != "enc"


def init_block(key: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    ks = L._split(key, 4)
    d = cfg.d_model
    pd = L._pdtype(cfg)
    zero = jnp.zeros((d,), pd)
    if kind in _ATTN_SELF:
        p: Params = {"attn_norm": zero, "attn": L.init_attention(ks[0], cfg)}
        if kind in ("xattn", "dec"):
            p["x_norm"] = zero
            p["xattn"] = L.init_cross_attention(ks[1], cfg)
        if cfg.d_ff:
            p["mlp_norm"] = zero
            p["mlp"] = (L.init_moe(ks[2], cfg) if cfg.num_experts
                        else L.init_mlp(ks[2], cfg))
        return p
    if kind == "rglru":
        p = {"norm": zero, "cell": L.init_rglru(ks[0], cfg)}
        if cfg.d_ff:
            p["mlp_norm"] = zero
            p["mlp"] = L.init_mlp(ks[1], cfg)
        return p
    if kind in ("mlstm", "slstm"):
        init = L.init_mlstm if kind == "mlstm" else L.init_slstm
        p = {"norm": zero, "cell": init(ks[0], cfg)}
        if cfg.d_ff:
            p["mlp_norm"] = zero
            p["mlp"] = L.init_mlp(ks[1], cfg)
        return p
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int) -> Optional[Dict[str, Any]]:
    if kind in _ATTN_SELF:
        c: Dict[str, Any] = {
            "attn": L.init_attn_cache(cfg, batch, cache_len,
                                      _kind_window(cfg, kind))}
        if kind in ("xattn", "dec"):
            aux_len = cfg.vision_tokens if kind == "xattn" else cfg.enc_seq
            cd = jnp.dtype(cfg.compute_dtype)
            c["xattn"] = {
                "k": jnp.zeros((batch, cfg.n_kv_heads, aux_len,
                                cfg.head_dim_), cd),
                "v": jnp.zeros((batch, cfg.n_kv_heads, aux_len,
                                cfg.head_dim_), cd),
            }
        return c
    if kind == "rglru":
        return {"cell": L.init_rglru_cache(cfg, batch)}
    if kind == "mlstm":
        return {"cell": L.init_mlstm_cache(cfg, batch)}
    if kind == "slstm":
        return {"cell": L.init_slstm_cache(cfg, batch)}
    raise ValueError(kind)


def apply_block(cfg: ModelConfig, kind: str, p: Params, x: jax.Array, *,
                positions: jax.Array, cache: Optional[Dict[str, Any]],
                aux: Optional[jax.Array], mode: str,
                cache_len: Optional[int] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """mode: train | prefill | decode.  Returns (x, new_cache)."""
    new_cache: Dict[str, Any] = {}
    if kind in _ATTN_SELF:
        h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        attn_out, kv = L.self_attention(
            cfg, p["attn"], h, window=_kind_window(cfg, kind),
            positions=positions, causal=_kind_causal(kind),
            cache=None if cache is None else cache["attn"], mode=mode,
            cache_len=cache_len,
        )
        x = x + attn_out
        if kv is not None:
            new_cache["attn"] = kv
        if kind in ("xattn", "dec"):
            h = L.rms_norm(x, p["x_norm"], cfg.norm_eps)
            xo, xc = L.cross_attention(
                cfg, p["xattn"], h, aux,
                cache=None if cache is None else cache["xattn"], mode=mode)
            x = x + xo
            if xc is not None:
                new_cache["xattn"] = xc
        if cfg.d_ff:
            h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
            if cfg.num_experts:
                moe = (L.moe_ffn_shard_map if cfg.moe_impl == "shard_map"
                       else L.moe_ffn)
                ff = moe(cfg, p["mlp"], h)
            else:
                ff = L.mlp(cfg, p["mlp"], h)
            x = x + ff
        return x, (new_cache or None)

    # recurrent kinds
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    cell_cache = None if cache is None else cache["cell"]
    if kind == "rglru":
        out, cc = L.rglru_block(cfg, p["cell"], h, cache=cell_cache,
                                mode=mode)
    elif kind == "mlstm":
        out, cc = L.mlstm_block(cfg, p["cell"], h, cache=cell_cache,
                                mode=mode)
    else:
        out, cc = L.slstm_block(cfg, p["cell"], h, cache=cell_cache,
                                mode=mode)
    x = x + out
    if cc is not None:
        new_cache["cell"] = cc
    if cfg.d_ff:
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(cfg, p["mlp"], h)
    return x, (new_cache or None)
