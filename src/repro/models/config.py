"""Model configuration for the assigned architecture pool.

A model is a cyclic ``layer_pattern`` of block kinds repeated to
``n_layers`` (the repeating group is the lax.scan body, so compile time is
~independent of depth).  Block kinds:

  full    causal self-attention (no window)
  swa     causal sliding-window self-attention
  local   alias of swa (gemma/recurrentgemma naming)
  global  alias of full (gemma3's 5:1 local:global pattern)
  xattn   causal self-attention + gated cross-attention to aux tokens (VLM)
  rglru   RG-LRU recurrent block w/ temporal conv (RecurrentGemma)
  mlstm   xLSTM matrix-memory block (chunkwise-parallel linear attention)
  slstm   xLSTM scalar-memory block (sequential scan)

Encoder-decoder models (whisper) add an encoder stack of bidirectional
blocks plus cross-attention in every decoder block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ATTN_KINDS = ("full", "swa", "local", "global", "xattn", "enc", "dec")
RECURRENT_KINDS = ("rglru", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("full",)
    window: int = 0                   # SWA window (rows), 0 = disabled
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense FFN in parallel w/ MoE
    #: "gspmd"     — global routing, GSPMD partitions the scatter/gather
    #: "shard_map" — group-local routing per data shard, TP-sharded expert
    #:               weights, ONE activation-sized psum per layer (§Perf H2)
    moe_impl: str = "gspmd"

    # VLM / enc-dec auxiliaries (modality frontends are stubs: input_specs
    # provides precomputed embeddings at d_model)
    vision_tokens: int = 0
    enc_layers: int = 0
    enc_seq: int = 0

    # recurrent blocks
    rnn_width: int = 0                # RG-LRU lru width (0 -> d_model)
    conv_width: int = 4
    mlstm_chunk: int = 256

    # embeddings / output
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 2048    # lcm(model_axis=16, lane=128)
    norm_eps: float = 1e-6

    # numerics & runtime
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    #: dtype of projection-matmul OUTPUTS (and hence of the TP partial-sum
    #: all-reduces GSPMD fuses to them).  "float32" = conservative baseline;
    #: "compute" = bf16 reductions (halves TP collective traffic — §Perf H1)
    matmul_out_dtype: str = "float32"
    adam_dtype: str = "float32"
    remat: bool = True
    attention_backend: str = "blockwise"
    attn_block_q: int = 512
    attn_block_k: int = 1024
    loss_chunk: int = 1024            # tokens per vocab-logit chunk (0=off)
    scan_layers: bool = True

    # which serve shapes are legal (long_500k skipped for pure full attn)
    supports_long_context: bool = False

    # --- derived -------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return self.vocab + (-self.vocab) % m

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def rem_pattern(self) -> Tuple[str, ...]:
        rem = self.n_layers % self.pattern_len
        return self.layer_pattern[:rem]

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def num_params(self) -> int:
        """Total parameter count (analytic; used for MODEL_FLOPS)."""
        d, dh = self.d_model, self.head_dim_
        hq, hkv, f = self.n_heads, self.n_kv_heads, self.d_ff
        per_kind = {}
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        mlp = 3 * d * f if f else 0
        moe = (d * self.num_experts
               + self.num_experts * 3 * d * f) if self.num_experts else 0
        if self.num_experts:
            mlp = moe + (3 * d * f if self.dense_residual else 0)
        rglru = (d * 3 * self.rnn_width_ + self.conv_width * self.rnn_width_
                 + 2 * self.rnn_width_ + self.rnn_width_ * d)
        lstm = (4 * d * hq * dh + 4 * hq * dh * dh + 3 * d * hq * dh)
        norms = 2 * d
        per_kind.update(full=attn + mlp + norms, swa=attn + mlp + norms,
                        local=attn + mlp + norms, global_=attn + mlp + norms,
                        xattn=2 * attn + mlp + norms + d,
                        rglru=rglru + mlp + norms,
                        mlstm=lstm + norms, slstm=lstm + mlp + norms,
                        enc=attn + mlp + norms, dec=2 * attn + mlp + norms)
        total = 0
        for i in range(self.n_layers):
            kind = self.layer_pattern[i % self.pattern_len]
            total += per_kind[kind if kind != "global" else "global_"]
        if self.is_encdec:
            total += self.enc_layers * per_kind["enc"]
        total += self.padded_vocab * d      # embedding
        if not self.tie_embeddings:
            total += d * self.padded_vocab
        total += d                          # final norm
        return total

    def num_active_params(self) -> int:
        """Per-token active params (MoE: top_k of num_experts)."""
        if not self.num_experts:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        inactive = (self.num_experts - self.top_k) * 3 * d * f
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.layer_pattern[i % self.pattern_len] in
            ("full", "swa", "local", "global"))
        return self.num_params() - inactive * n_moe_layers

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, "GQA group must divide"
        assert self.d_model % self.n_heads == 0 or self.head_dim, \
            "head_dim underivable"
        if self.num_experts:
            assert self.top_k <= self.num_experts
        for k in self.layer_pattern:
            assert k in ATTN_KINDS + RECURRENT_KINDS, k


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 64, 2),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 96, 2),
    "decode_32k": ShapeConfig("decode_32k", "decode", 96, 2),
    "long_500k": ShapeConfig("long_500k", "decode", 128, 1),
}


def shape_is_supported(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if supported, else a skip reason (recorded in DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k-token cache is "
                "assignment-sanctioned skip (DESIGN.md §6)")
    return None
