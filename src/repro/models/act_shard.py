"""Activation sharding hints (with_sharding_constraint) behind a context.

Without hints, GSPMD is free to satisfy an FSDP-sharded ("embed" over
data) weight by computing contracting-dim partial sums and ALL-REDUCING
full activations every layer — orders of magnitude more traffic than
all-gathering the (much smaller) weights.  Pinning the activation batch
axis at block boundaries forces the weight-gather strategy.

The mapping (logical axis -> ((mesh_axis, size), ...)) is installed by the
launcher (dryrun/train) for the duration of tracing; with no context the
hints are no-ops, so smoke tests and CPU examples are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

Mapping = Dict[str, Tuple[Tuple[str, int], ...]]

_MAP: contextvars.ContextVar[Optional[Mapping]] = contextvars.ContextVar(
    "activation_sharding_map", default=None)
_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding_mesh", default=None)


@contextlib.contextmanager
def activation_sharding(mapping: Mapping, mesh=None):
    token = _MAP.set(dict(mapping))
    token_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _MAP.reset(token)
        _MESH.reset(token_m)


def current_mapping() -> Optional[Mapping]:
    return _MAP.get()


def current_mesh():
    return _MESH.get()


def mapping_from_mesh(mesh, rules) -> Mapping:
    """Build the hint mapping from a mesh + rule table (launch/sharding)."""
    out: Mapping = {}
    for logical, targets in rules.items():
        if targets is None:
            continue
        if isinstance(targets, str):
            targets = (targets,)
        pairs = tuple((t, mesh.shape[t]) for t in targets
                      if t in mesh.shape)
        if pairs:
            out[logical] = pairs
    return out


def hint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation ``x``'s dims to the context's mesh axes.

    Divisibility-checked like launch/sharding.resolve_spec; no-op without
    an installed context."""
    m = _MAP.get()
    if not m:
        return x
    parts = []
    used = set()
    for dim, ax in zip(x.shape, axes):
        pairs = m.get(ax) if ax is not None else None
        if not pairs:
            parts.append(None)
            continue
        sel = []
        prod = 1
        for name, size in pairs:
            if name in used:
                continue
            if dim % (prod * size) == 0:
                sel.append(name)
                prod *= size
        if not sel:
            parts.append(None)
        else:
            parts.append(sel[0] if len(sel) == 1 else tuple(sel))
            used.update(sel)
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(x, P(*parts))
