"""Logical partitioning axes for params / caches / batches (t5x-style).

Every leaf is identified by its dict key (names are unique across block
kinds by construction) and mapped to a tuple of *logical* axis names for
its trailing dims; leading scan-stack dims get the "layers" axis.  The
launch/sharding.py resolver turns logical axes into mesh PartitionSpecs
with divisibility-aware fallback.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

LogicalAxes = Tuple[Optional[str], ...]

PARAM_AXES: Dict[str, LogicalAxes] = {
    "embedding": ("vocab", "embed"),
    "out_proj": ("embed", "vocab"),
    "final_norm": ("embed",),
    "attn_norm": ("embed",),
    "mlp_norm": ("embed",),
    "x_norm": ("embed",),
    "norm": ("embed",),
    # attention
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "gate": (),
    # mlp
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    # moe
    "router": ("embed", "expert"),
    "we_gate": ("expert", "embed", "mlp"),
    "we_up": ("expert", "embed", "mlp"),
    "we_down": ("expert", "mlp", "embed"),
    # rg-lru
    "w_x": ("embed", "rnn"),
    "w_y": ("embed", "rnn"),
    "conv": (None, "rnn"),
    "w_a": ("rnn", "rnn2"),
    "w_i": ("rnn", "rnn2"),
    "lam": ("rnn",),
    "w_out": ("rnn", "embed"),
    # xlstm
    "wi": ("embed", "heads"),
    "wf": ("embed", "heads"),
    "wx": ("embed", None, "heads", "head_dim"),
    "r": ("heads", "head_dim", None, "head_dim2"),
}

CACHE_AXES: Dict[str, LogicalAxes] = {
    "k": ("batch", "kv_heads", "cache_seq", "head_dim"),
    "v": ("batch", "kv_heads", "cache_seq", "head_dim"),
    "slot_pos": ("cache_seq",),
    "mC": ("batch", "heads", "head_dim", "head_dim2"),
    "mn": ("batch", "heads", "head_dim"),
    "mm": ("batch", "heads"),
    "sc": ("batch", "heads", "head_dim"),
    "sn": ("batch", "heads", "head_dim"),
    "sh": ("batch", "heads", "head_dim"),
    "sm": ("batch", "heads", "head_dim"),
    "lru": ("batch", "rnn"),
    "conv_state": ("batch", None, "rnn"),
    "enc_out": ("batch", "aux_seq", "embed"),
}

BATCH_AXES: Dict[str, LogicalAxes] = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "aux": ("batch", "aux_seq", "embed"),
    "token": ("batch", "seq"),
    "pos": (),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    raise KeyError(f"no dict key in path {path}")


def logical_axes(tree: Any, table: Dict[str, LogicalAxes]) -> Any:
    """Map a pytree of arrays (or ShapeDtypeStructs) to logical-axis tuples,
    padding leading scan-stack dims with "layers"."""

    def one(path, leaf):
        name = _leaf_name(path)
        if name not in table:
            raise KeyError(f"no logical axes registered for leaf {name!r} "
                           f"at {jax.tree_util.keystr(path)}")
        axes = table[name]
        extra = len(leaf.shape) - len(axes)
        assert extra >= 0, (name, leaf.shape, axes)
        return ("layers",) * extra + tuple(axes)

    return jax.tree_util.tree_map_with_path(one, tree)


def param_axes(params: Any) -> Any:
    return logical_axes(params, PARAM_AXES)


def cache_axes(cache: Any) -> Any:
    return logical_axes(cache, CACHE_AXES)


def batch_axes(batch: Any) -> Any:
    return logical_axes(batch, BATCH_AXES)
