"""The language model: embeddings + scanned block groups + chunked CE loss,
with prefill/decode serving paths (KV / recurrent-state caches).

Depth is organized as ``n_groups`` repetitions of the cyclic layer pattern;
the group is the ``lax.scan`` body (params stacked on a leading axis), so
HLO size and compile time are ~independent of depth.  Remainder layers
(n_layers % pattern) are applied unstacked after the scan.

Encoder-decoder models (whisper) add an encoder stack whose output is the
``aux`` stream the decoder's cross-attention reads.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.act_shard import hint
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_group(key: jax.Array, cfg: ModelConfig, pattern) -> Params:
    ks = L._split(key, max(len(pattern), 1))
    return {str(i): init_block(ks[i], cfg, kind)
            for i, kind in enumerate(pattern)}


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = L._split(key, 6)
    pd = L._pdtype(cfg)
    d, vp = cfg.d_model, cfg.padded_vocab

    emb = jax.random.normal(ks[0], (vp, d)) * (d ** -0.5)
    # zero the padding rows so padded ids are inert
    row_ok = (jnp.arange(vp) < cfg.vocab)[:, None]
    params: Params = {"embedding": (emb * row_ok).astype(pd),
                      "final_norm": jnp.zeros((d,), pd)}
    if not cfg.tie_embeddings:
        params["out_proj"] = L.dense_init(ks[1], (d, vp), d, pd)

    if cfg.n_groups > 0:
        gkeys = jax.random.split(ks[2], cfg.n_groups)
        params["groups"] = jax.vmap(
            lambda k: _init_group(k, cfg, cfg.layer_pattern))(gkeys)
    if cfg.rem_pattern:
        params["rem"] = _init_group(ks[3], cfg, cfg.rem_pattern)

    if cfg.is_encdec:
        ekeys = jax.random.split(ks[4], cfg.enc_layers)
        params["encoder"] = {
            "groups": jax.vmap(
                lambda k: _init_group(k, cfg, ("enc",)))(ekeys),
            "final_norm": jnp.zeros((d,), pd),
        }
    return params


def init_serve_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    """Cache pytree matching the prefill output / decode input."""
    def group_cache(pattern):
        return {str(i): init_block_cache(cfg, kind, batch, cache_len)
                for i, kind in enumerate(pattern)}

    cache: Params = {}
    if cfg.n_groups > 0:
        gc = group_cache(cfg.layer_pattern)
        cache["groups"] = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (cfg.n_groups,) + (1,) * x.ndim), gc)
    if cfg.rem_pattern:
        cache["rem"] = group_cache(cfg.rem_pattern)
    if cfg.is_encdec:
        cache["enc_out"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model),
                                     jnp.dtype(cfg.compute_dtype))
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _group_fn(cfg, pattern, gp, x, *, positions, gcache, aux, mode,
              cache_len=None):
    ncs = {}
    for i, kind in enumerate(pattern):
        x = hint(x, ("batch", None, None))
        x, nc = apply_block(
            cfg, kind, gp[str(i)], x, positions=positions,
            cache=None if gcache is None else gcache[str(i)],
            aux=aux, mode=mode, cache_len=cache_len)
        ncs[str(i)] = nc
    return x, ncs


def _run_stack(cfg: ModelConfig, params: Params, x: jax.Array, *,
               positions, caches, aux, mode: str,
               cache_len: Optional[int] = None
               ) -> Tuple[jax.Array, Optional[Params]]:
    pattern = cfg.layer_pattern
    new_caches: Params = {}

    if cfg.n_groups > 0:
        if cfg.scan_layers:
            if mode == "train":
                def body(h, gp):
                    h, _ = _group_fn(cfg, pattern, gp, h,
                                     positions=positions, gcache=None,
                                     aux=aux, mode=mode)
                    return h, None
                if cfg.remat:
                    body = jax.checkpoint(body)
                x, _ = jax.lax.scan(body, x, params["groups"])
            elif mode == "prefill":
                def body(h, gp):
                    return _group_fn(cfg, pattern, gp, h,
                                     positions=positions, gcache=None,
                                     aux=aux, mode=mode,
                                     cache_len=cache_len)
                x, gc = jax.lax.scan(body, x, params["groups"])
                new_caches["groups"] = gc
            else:
                def body(h, inp):
                    gp, gc = inp
                    return _group_fn(cfg, pattern, gp, h,
                                     positions=positions, gcache=gc,
                                     aux=aux, mode=mode)
                x, gc = jax.lax.scan(body, x,
                                     (params["groups"], caches["groups"]))
                new_caches["groups"] = gc
        else:
            gcs = []
            for g in range(cfg.n_groups):
                gp = jax.tree_util.tree_map(lambda t: t[g], params["groups"])
                gc_in = (None if mode != "decode" else
                         jax.tree_util.tree_map(lambda t: t[g],
                                                caches["groups"]))
                x, gc = _group_fn(cfg, pattern, gp, x, positions=positions,
                                  gcache=gc_in, aux=aux, mode=mode)
                gcs.append(gc)
            if mode != "train":
                new_caches["groups"] = jax.tree_util.tree_map(
                    lambda *ts: jnp.stack(ts), *gcs)

    if cfg.rem_pattern:
        x, rc = _group_fn(
            cfg, cfg.rem_pattern, params["rem"], x, positions=positions,
            gcache=None if mode != "decode" else caches["rem"],
            aux=aux, mode=mode, cache_len=cache_len)
        if mode != "train":
            new_caches["rem"] = rc

    return x, (new_caches if mode != "train" else None)


def encode(cfg: ModelConfig, params: Params, audio_embeds: jax.Array
           ) -> jax.Array:
    """Whisper-style encoder over stub frontend embeddings (B, Ta, d)."""
    enc = params["encoder"]
    x = audio_embeds.astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(x.shape[1])

    def body(h, gp):
        h, _ = _group_fn(cfg, ("enc",), gp, h, positions=positions,
                         gcache=None, aux=None, mode="train")
        return h, None

    x, _ = jax.lax.scan(body, x, enc["groups"])
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def embed(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["embedding"].astype(jnp.dtype(cfg.compute_dtype))[tokens]
    return hint(x, ("batch", None, None))


def logits_from_hidden(cfg: ModelConfig, params: Params, h: jax.Array
                       ) -> jax.Array:
    """(…, d) -> (…, padded_vocab) fp32, padding columns at -inf."""
    w = (params["embedding"] if cfg.tie_embeddings
         else params["out_proj"].T)
    cd = jnp.dtype(cfg.compute_dtype)
    logits = L.einsum32("...d,vd->...v", h.astype(cd), w.astype(cd))
    pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0,
                         -1e30)
    return logits + pad_mask


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
                   aux: Optional[jax.Array] = None,
                   mode: str = "train",
                   caches: Optional[Params] = None,
                   positions: Optional[jax.Array] = None,
                   cache_len: Optional[int] = None
                   ) -> Tuple[jax.Array, Optional[Params]]:
    if cfg.is_encdec and mode != "decode":
        aux = encode(cfg, params, aux)
    elif cfg.is_encdec and mode == "decode":
        aux = caches["enc_out"]
    x = embed(cfg, params, tokens)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    x, new_caches = _run_stack(cfg, params, x, positions=positions,
                               caches=caches, aux=aux, mode=mode,
                               cache_len=cache_len)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if mode == "prefill" and cfg.is_encdec:
        new_caches["enc_out"] = aux
    return x, new_caches


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _chunked_ce(cfg: ModelConfig, params: Params, h: jax.Array,
                labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-token CE with the vocab-logit working set capped at
    (B, loss_chunk, padded_vocab).  Returns (ce (B,S), valid (B,S))."""
    b, s, d = h.shape
    c = cfg.loss_chunk if cfg.loss_chunk else s
    c = min(c, s)
    pad = (-s) % c
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // c
    hc = hp.reshape(b, nc, c, d).swapaxes(0, 1)        # (nc, B, c, d)
    lc = lp.reshape(b, nc, c).swapaxes(0, 1)

    def one(args):
        hi, li = args
        logits = logits_from_hidden(cfg, params, hi)   # (B, c, Vp) fp32
        logits = hint(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(li, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], -1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        return (logz - gold) * valid, valid

    ce, valid = jax.lax.map(one, (hc, lc))
    ce = ce.swapaxes(0, 1).reshape(b, s + pad)[:, :s]
    valid = valid.swapaxes(0, 1).reshape(b, s + pad)[:, :s]
    return ce, valid


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, _ = forward_hidden(cfg, params, batch["tokens"],
                          aux=batch.get("aux"), mode="train")
    ce, valid = _chunked_ce(cfg, params, h, batch["labels"])
    total = jnp.sum(ce)
    count = jnp.maximum(jnp.sum(valid), 1.0)
    loss = total / count
    return loss, {"loss": loss, "tokens": count}


def per_example_loss(cfg: ModelConfig, params: Params,
                     batch: Dict[str, jax.Array]) -> jax.Array:
    """(B,) mean loss per example — the earl_eval statistic."""
    h, _ = forward_hidden(cfg, params, batch["tokens"],
                          aux=batch.get("aux"), mode="train")
    ce, valid = _chunked_ce(cfg, params, h, batch["labels"])
    return jnp.sum(ce, -1) / jnp.maximum(jnp.sum(valid, -1), 1.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            aux: Optional[jax.Array] = None,
            cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, Params]:
    """Returns (last-token logits (B, Vp), cache).  ``cache_len`` reserves
    extra KV-cache capacity for subsequent decode steps."""
    h, caches = forward_hidden(cfg, params, tokens, aux=aux, mode="prefill",
                               cache_len=cache_len)
    logits = logits_from_hidden(cfg, params, h[:, -1])
    return logits, caches


def decode_step(cfg: ModelConfig, params: Params, caches: Params,
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Params]:
    """token: (B, 1) int32; pos: scalar int32 absolute position.

    Returns (logits (B, Vp), updated caches)."""
    positions = jnp.reshape(pos, (1,))
    h, new_caches = forward_hidden(cfg, params, token, mode="decode",
                                   caches=caches, positions=positions)
    if cfg.is_encdec:
        new_caches["enc_out"] = caches["enc_out"]
    logits = logits_from_hidden(cfg, params, h[:, 0])
    return logits, new_caches
