"""Model blocks: GQA attention (full/SWA/cross), SwiGLU MLP, sort-based
capacity-routed MoE, RG-LRU recurrence, xLSTM (mLSTM/sLSTM) cells.

Conventions
  * params are plain nested dicts of jnp arrays (param_dtype), cast to
    cfg.compute_dtype at use; norms/softmax/recurrences run in fp32.
  * every block fn returns ``(y, new_cache)``; cache=None in train mode.
  * sequence caches for SWA layers are ring buffers of size window —
    the KV memory win that makes long_500k feasible on windowed archs.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops
from repro.models.config import ModelConfig

Params = Dict[str, Any]
Cache = Optional[Dict[str, Any]]


# ---------------------------------------------------------------------------
# small pieces
# ---------------------------------------------------------------------------
def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis_size, dtype) -> jax.Array:
    std = in_axis_size ** -0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def _split(key, n):
    return list(jax.random.split(key, n))


def einsum32(spec, *args):
    """bf16-in, fp32-accumulate einsum (MXU semantics)."""
    return jnp.einsum(spec, *args, preferred_element_type=jnp.float32)


def mmc(cfg: ModelConfig, spec, *args):
    """Projection einsum whose OUTPUT dtype follows cfg.matmul_out_dtype.

    With "compute" (bf16), the TP partial-sum all-reduce that GSPMD fuses
    onto the dot output moves in bf16 — half the wire bytes of the fp32
    baseline (EXPERIMENTS.md §Perf, iteration H1).  MXU accumulation is
    fp32 either way."""
    if cfg.matmul_out_dtype == "compute":
        out_dt = _cdtype(cfg)
    else:
        out_dt = jnp.dtype(cfg.matmul_out_dtype)
    return jnp.einsum(spec, *args, preferred_element_type=out_dt)


# ---------------------------------------------------------------------------
# self attention (full / swa / local / global) with KV cache
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    pd = _pdtype(cfg)
    ks = _split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq, dh), d, pd),
        "wk": dense_init(ks[1], (d, hkv, dh), d, pd),
        "wv": dense_init(ks[2], (d, hkv, dh), d, pd),
        "wo": dense_init(ks[3], (hq, dh, d), hq * dh, pd),
    }


def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int,
                    window: int) -> Dict[str, jax.Array]:
    """Ring-buffer KV cache.  For windowed layers the buffer is the window
    (ring); for full layers it is the whole context."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    L = min(cache_len, window) if window else cache_len
    cd = _cdtype(cfg)
    return {
        "k": jnp.zeros((batch, hkv, L, dh), cd),
        "v": jnp.zeros((batch, hkv, L, dh), cd),
        "slot_pos": jnp.full((L,), -1, jnp.int32),   # absolute pos per slot
    }


def self_attention(cfg: ModelConfig, p: Params, x: jax.Array, *,
                   window: int, positions: jax.Array,
                   cache: Cache = None, causal: bool = True,
                   mode: str = "train",
                   cache_len: int | None = None) -> Tuple[jax.Array, Cache]:
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cd = _cdtype(cfg)
    xc = x.astype(cd)

    q = mmc(cfg, "bsd,dhk->bshk", xc, p["wq"].astype(cd)).astype(cd)
    k = mmc(cfg, "bsd,dhk->bshk", xc, p["wk"].astype(cd)).astype(cd)
    v = mmc(cfg, "bsd,dhk->bshk", xc, p["wv"].astype(cd)).astype(cd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q * (dh ** -0.5)
    qh = q.transpose(0, 2, 1, 3)                      # (B, Hq, S, Dh)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    if mode != "decode":
        out = fa_ops.flash_attention(
            qh, kh, vh, causal=causal, window=window or None, scale=1.0,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
            backend=cfg.attention_backend)
        y = out.transpose(0, 2, 1, 3)
        y = mmc(cfg, "bshk,hkd->bsd", y.astype(cd),
                p["wo"].astype(cd)).astype(x.dtype)
        if mode == "train":
            return y, None
        # prefill: materialize the KV cache (ring layout for SWA layers);
        # cache_len > s reserves room for subsequent decode steps
        assert positions.ndim == 1
        cl = cache_len if cache_len is not None else s
        L = min(window, cl) if window else cl
        idxs = jnp.arange(max(s - L, 0), s)
        pos_abs = positions[idxs]
        slots = pos_abs % L if window else idxs
        kc = jnp.zeros((b, hkv, L, dh), cd).at[:, :, slots].set(
            kh[:, :, idxs].astype(cd))
        vc = jnp.zeros((b, hkv, L, dh), cd).at[:, :, slots].set(
            vh[:, :, idxs].astype(cd))
        slot_pos = jnp.full((L,), -1, jnp.int32).at[slots].set(pos_abs)
        return y, {"k": kc, "v": vc, "slot_pos": slot_pos}

    # ---- cached decode: s == 1, ring-buffer update ----------------------
    assert s == 1, "cached path is single-token decode"
    L = cache["k"].shape[2]
    group = hq // hkv
    pos = positions.reshape(-1)[0]                   # scalar absolute pos
    slot = (pos % L) if window else jnp.clip(pos, 0, L - 1)
    newk = jax.lax.dynamic_update_slice(
        cache["k"], kh.astype(cache["k"].dtype), (0, 0, slot, 0))
    newv = jax.lax.dynamic_update_slice(
        cache["v"], vh.astype(cache["v"].dtype), (0, 0, slot, 0))
    slot_pos = cache["slot_pos"].at[slot].set(pos)

    svalid = slot_pos >= 0
    if causal:
        svalid &= slot_pos <= pos
    if window:
        svalid &= slot_pos > pos - window
    qg = qh.reshape(b, hkv, group, 1, dh)            # GQA grouping
    scores = einsum32("bhgqk,bhsk->bhgqs", qg.astype(cd), newk.astype(cd))
    scores = jnp.where(svalid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    ctx = einsum32("bhgqs,bhsk->bhgqk", probs, newv.astype(jnp.float32))
    ctx = ctx.reshape(b, hq, 1, dh).transpose(0, 2, 1, 3)
    y = einsum32("bshk,hkd->bsd", ctx.astype(cd), p["wo"].astype(cd))
    return y.astype(x.dtype), {"k": newk, "v": newv, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# cross attention (VLM xattn layers, whisper decoder)
# ---------------------------------------------------------------------------
def init_cross_attention(key, cfg: ModelConfig) -> Params:
    p = init_attention(key, cfg)
    p["gate"] = jnp.zeros((), _pdtype(cfg))           # zero-init gated xattn
    return p


def cross_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                    aux: Optional[jax.Array], cache: Cache = None,
                    mode: str = "train") -> Tuple[jax.Array, Cache]:
    """x: (B, S, d) queries; aux: (B, Ta, d) keys/values (no rope).

    decode mode reads projected aux K/V from the cache (computed once at
    prefill); prefill emits that cache."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    q = mmc(cfg, "bsd,dhk->bshk", xc, p["wq"].astype(cd)).astype(cd)
    if mode == "decode":
        kh, vh = cache["k"], cache["v"]
    else:
        auxc = aux.astype(cd)
        kh = mmc(cfg, "btd,dhk->bthk", auxc, p["wk"].astype(cd)) \
            .astype(cd).transpose(0, 2, 1, 3)
        vh = mmc(cfg, "btd,dhk->bthk", auxc, p["wv"].astype(cd)) \
            .astype(cd).transpose(0, 2, 1, 3)
    qh = (q * (dh ** -0.5)).transpose(0, 2, 1, 3)
    out = fa_ops.flash_attention(
        qh, kh, vh, causal=False, window=None, scale=1.0,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        backend=("direct" if mode == "decode" else cfg.attention_backend))
    y = out.transpose(0, 2, 1, 3)
    y = mmc(cfg, "bshk,hkd->bsd", y.astype(cd), p["wo"].astype(cd))
    y = jnp.tanh(p["gate"].astype(jnp.float32)) * y.astype(jnp.float32)
    new_cache = {"k": kh, "v": vh} if mode != "train" else None
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    pd = _pdtype(cfg)
    ks = _split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), d, pd),
        "w_up": dense_init(ks[1], (d, f), d, pd),
        "w_down": dense_init(ks[2], (f, d), f, pd),
    }


def mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    g = mmc(cfg, "bsd,df->bsf", xc, p["w_gate"].astype(cd))
    u = mmc(cfg, "bsd,df->bsf", xc, p["w_up"].astype(cd))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(cd)
    y = mmc(cfg, "bsf,fd->bsd", h, p["w_down"].astype(cd))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: top-k routing, sort-based capacity dispatch (no (T,E,C) one-hot)
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = _pdtype(cfg)
    ks = _split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), d, pd),
        "we_gate": dense_init(ks[1], (e, d, f), d, pd),
        "we_up": dense_init(ks[2], (e, d, f), d, pd),
        "we_down": dense_init(ks[3], (e, f, d), f, pd),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg)
    return p


def _moe_route_compute(cfg: ModelConfig, p: Params, x: jax.Array
                       ) -> jax.Array:
    """Sort-based capacity routing + expert FFNs over the tokens of ``x``.

    Tokens are argsorted by expert id; each token-slot gets a rank within
    its expert and is dropped beyond capacity C = ceil(T·k·cf / E).  The
    dispatch/combine are gathers + scatter-adds (memory ops), not the
    (T,E,C) one-hot einsum whose FLOPs rival the experts themselves.

    Returns y in fp32, WITHOUT the dense residual (caller adds it).  Under
    shard_map the expert weights arrive f-sharded, so y is a partial sum
    the caller psums over the model axis.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    cap = int(math.ceil(t * k * cfg.capacity_factor / e))
    cd = _cdtype(cfg)

    xt = x.reshape(t, d)
    logits = einsum32("td,de->te", xt.astype(cd), p["router"].astype(cd))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (T, k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    flat_e = eidx.reshape(-1)                                # (T·k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    idx = jnp.arange(t * k)
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    rank = idx - seg_start
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)          # drop -> last

    buf = jnp.zeros((e * cap + 1, d), cd)
    buf = buf.at[slot].set(xt[st].astype(cd), mode="drop")
    expert_in = buf[:e * cap].reshape(e, cap, d)

    g_h = mmc(cfg, "ecd,edf->ecf", expert_in, p["we_gate"].astype(cd))
    u_h = mmc(cfg, "ecd,edf->ecf", expert_in, p["we_up"].astype(cd))
    h = (jax.nn.silu(g_h.astype(jnp.float32)) * u_h.astype(jnp.float32)
         ).astype(cd)
    out = mmc(cfg, "ecf,efd->ecd", h, p["we_down"].astype(cd))

    outf = jnp.concatenate([out.reshape(e * cap, d).astype(jnp.float32),
                            jnp.zeros((1, d), jnp.float32)], 0)
    contrib = outf[slot] * (sg * keep)[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[st].add(contrib)
    return y.reshape(b, s, d)


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """GSPMD-global MoE: one routing problem over all tokens; the XLA
    partitioner handles the dispatch scatter (baseline; §Perf H2 shows the
    collective cost this induces at 256 chips)."""
    y = _moe_route_compute(cfg, p, x)
    if cfg.dense_residual:
        y = y + mlp(cfg, p["dense"], x).astype(jnp.float32)
    return y.astype(x.dtype)


def moe_ffn_shard_map(cfg: ModelConfig, p: Params, x: jax.Array
                      ) -> jax.Array:
    """Group-local MoE (GShard groups = data shards) with TP-sharded
    expert weights — §Perf iteration H2.

    shard_map over the full mesh: tokens stay on their data shard (local
    routing, capacity per group), every shard holds all experts' weights
    f-sliced over "model"; the ONLY collective is one fp32 psum of the
    (local tokens, d) output over the model axis per layer — activation-
    sized, vs the token all-gathers GSPMD emits for global routing.

    Falls back to the GSPMD path when no mesh context is installed (CPU
    smoke tests) or the mesh lacks a model axis.
    """
    from repro.models.act_shard import current_mapping, current_mesh
    mesh = current_mesh()
    mapping = current_mapping()
    if mesh is None or mapping is None or "mlp" not in mapping:
        return moe_ffn(cfg, p, x)

    batch_axes = tuple(name for name, _ in mapping.get("batch", ()))
    model_axes = tuple(name for name, _ in mapping["mlp"])
    batch_ways = math.prod(mesh.shape[a] for a in batch_axes) \
        if batch_axes else 1
    if not model_axes or x.shape[0] % batch_ways != 0 \
            or cfg.d_ff % math.prod(mesh.shape[a] for a in model_axes):
        return moe_ffn(cfg, p, x)

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_compat
    shard_map, sm_kw = shard_map_compat()

    def body(p_loc, x_loc):
        y = _moe_route_compute(cfg, p_loc, x_loc)
        if cfg.dense_residual:
            y = y + _mlp_partial(cfg, p_loc["dense"], x_loc)
        y = jax.lax.psum(y, model_axes)
        return y.astype(x.dtype)

    p_specs = {
        "router": P(),
        "we_gate": P(None, None, model_axes),
        "we_up": P(None, None, model_axes),
        "we_down": P(None, model_axes, None),
    }
    if cfg.dense_residual:
        p_specs["dense"] = {
            "w_gate": P(None, model_axes),
            "w_up": P(None, model_axes),
            "w_down": P(model_axes, None),
        }
    x_spec = P(batch_axes if batch_axes else None, None, None)
    fn = shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                   out_specs=x_spec, **sm_kw)
    return fn({k: p[k] for k in p_specs}, x)


def _mlp_partial(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """SwiGLU on an f-sharded weight slice; returns fp32 partial sums
    (caller psums over the model axis)."""
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    g = mmc(cfg, "bsd,df->bsf", xc, p["w_gate"].astype(cd))
    u = mmc(cfg, "bsd,df->bsf", xc, p["w_up"].astype(cd))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(cd)
    return mmc(cfg, "bsf,fd->bsd", h, p["w_down"].astype(cd)) \
        .astype(jnp.float32)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------
_LRU_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> Params:
    d, r, cw = cfg.d_model, cfg.rnn_width_, cfg.conv_width
    pd = _pdtype(cfg)
    ks = _split(key, 7)
    return {
        "w_x": dense_init(ks[0], (d, r), d, pd),
        "w_y": dense_init(ks[1], (d, r), d, pd),        # gelu gate branch
        "conv": dense_init(ks[2], (cw, r), cw, pd),
        "w_a": dense_init(ks[3], (r, r), r, pd),
        "w_i": dense_init(ks[4], (r, r), r, pd),
        "lam": (jax.random.uniform(ks[5], (r,), minval=0.7, maxval=0.95)
                .astype(pd)),
        "w_out": dense_init(ks[6], (r, d), r, pd),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    r, cw = cfg.rnn_width_, cfg.conv_width
    return {
        "lru": jnp.zeros((batch, r), jnp.float32),
        "conv_state": jnp.zeros((batch, cw - 1, r), _cdtype(cfg)),
    }


def _causal_conv(u: jax.Array, kern: jax.Array,
                 state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u: (B,S,r), kern: (cw,r)."""
    cw = kern.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * kern[i][None, None, :]
              for i in range(cw))
    new_state = up[:, -(cw - 1):] if cw > 1 else None
    return out, new_state


def rglru_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
                cache: Cache = None, mode: str = "train"
                ) -> Tuple[jax.Array, Cache]:
    b, s, d = x.shape
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    u = mmc(cfg, "bsd,dr->bsr", xc, p["w_x"].astype(cd)).astype(cd)
    gate_branch = mmc(cfg, "bsd,dr->bsr", xc, p["w_y"].astype(cd))

    conv_state = cache["conv_state"] if mode == "decode" else None
    u_raw = u
    u, new_conv = _causal_conv(u, p["conv"].astype(cd), conv_state)
    if mode == "prefill":
        cw = cfg.conv_width
        new_conv = jnp.pad(u_raw, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):] \
            if cw > 1 else None

    uf = u.astype(jnp.float32)
    rt = jax.nn.sigmoid(einsum32("bsr,rq->bsq", u, p["w_a"].astype(cd)))
    it = jax.nn.sigmoid(einsum32("bsr,rq->bsq", u, p["w_i"].astype(cd)))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rt
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (it * uf)

    if mode == "decode":
        h = a[:, 0] * cache["lru"] + gated[:, 0]
        new_h = h
        h = h[:, None, :]
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_h = h[:, -1]

    y = jax.nn.gelu(gate_branch.astype(jnp.float32)) * h
    y = mmc(cfg, "bsr,rd->bsd", y.astype(cd), p["w_out"].astype(cd))
    new_cache = (None if mode == "train"
                 else {"lru": new_h, "conv_state": new_conv})
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scan)
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig) -> Params:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim_
    pd = _pdtype(cfg)
    ks = _split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h, dh), d, pd),
        "wk": dense_init(ks[1], (d, h, dh), d, pd),
        "wv": dense_init(ks[2], (d, h, dh), d, pd),
        "wi": dense_init(ks[3], (d, h), d, pd),
        "wf": dense_init(ks[4], (d, h), d, pd) ,
        "wo": dense_init(ks[5], (h, dh, d), h * dh, pd),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    h, dh = cfg.n_heads, cfg.head_dim_
    return {
        "mC": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "mn": jnp.zeros((batch, h, dh), jnp.float32),
        "mm": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_chunk(q, k, v, ig, lf, carry):
    """One chunk of the stabilized mLSTM recurrence.

    q,k,v: (B,H,c,dh) fp32; ig: (B,H,c) input gate pre-act;
    lf: (B,H,c) log forget gate;  carry: (C (B,H,dh,dh), n (B,H,dh), m (B,H)).
    """
    C, nvec, m = carry
    F = jnp.cumsum(lf, axis=-1)                       # (B,H,c)
    logw = ig - F                                     # i[s] - F[s]
    m_loc = jax.lax.cummax(logw, axis=2)
    m_new = jnp.maximum(m[..., None] , m_loc) + F     # running stabilizer/t
    # inter-chunk: scale carried state
    inter_scale = jnp.exp(m[..., None] + F - m_new)   # (B,H,c)
    h_inter = jnp.einsum("bhck,bhkl->bhcl", q, C) * inter_scale[..., None]
    n_inter = jnp.einsum("bhck,bhk->bhc", q, nvec) * inter_scale
    # intra-chunk quadratic
    s_qk = jnp.einsum("bhck,bhsk->bhcs", q, k)
    decay = (F[..., :, None] - F[..., None, :] + ig[..., None, :]
             - m_new[..., :, None])
    tri = jnp.tril(jnp.ones(decay.shape[-2:], bool))
    D = jnp.where(tri, jnp.exp(decay), 0.0)
    w = s_qk * D
    h_intra = jnp.einsum("bhcs,bhsl->bhcl", w, v)
    n_intra = jnp.sum(w, axis=-1)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra),
                        jnp.exp(-m_new))
    h = (h_inter + h_intra) / denom[..., None]
    # end-of-chunk carry update
    Fe = F[..., -1]                                   # (B,H)
    m_carry = jnp.maximum(m + Fe, jnp.max(logw, -1) + Fe)
    c_scale = jnp.exp(m + Fe - m_carry)
    kv_w = jnp.exp(Fe[..., None] - F + ig - m_carry[..., None])
    C_new = (C * c_scale[..., None, None]
             + jnp.einsum("bhsk,bhsl,bhs->bhkl", k, v, kv_w))
    n_new = nvec * c_scale[..., None] + jnp.einsum("bhsk,bhs->bhk", k, kv_w)
    return h, (C_new, n_new, m_carry)


def mlstm_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
                cache: Cache = None, mode: str = "train"
                ) -> Tuple[jax.Array, Cache]:
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim_
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    q = mmc(cfg, "bsd,dhk->bhsk", xc,
            p["wq"].astype(cd)).astype(jnp.float32) * (dh ** -0.5)
    k = mmc(cfg, "bsd,dhk->bhsk", xc,
            p["wk"].astype(cd)).astype(jnp.float32) * (dh ** -0.5)
    v = mmc(cfg, "bsd,dhk->bhsk", xc,
            p["wv"].astype(cd)).astype(jnp.float32)
    ig = einsum32("bsd,dh->bhs", xc, p["wi"].astype(cd))
    lf = -jax.nn.softplus(-einsum32("bsd,dh->bhs", xc, p["wf"].astype(cd)))

    if mode == "decode":
        carry = (cache["mC"], cache["mn"], cache["mm"])
        hout, (C, nvec, m) = _mlstm_chunk(q, k, v, ig, lf, carry)
        y = mmc(cfg, "bhsk,hkd->bsd", hout.astype(cd), p["wo"].astype(cd))
        return y.astype(x.dtype), {"mC": C, "mn": nvec, "mm": m}

    c = min(cfg.mlstm_chunk, s)
    pad = (-s) % c
    def padc(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 3))
    qp, kp, vp, igp, lfp = map(padc, (q, k, v, ig, lf))
    nchunk = (s + pad) // c

    def body(carry, inputs):
        qi, ki, vi, igi, lfi = inputs
        hout, carry = _mlstm_chunk(qi, ki, vi, igi, lfi, carry)
        return carry, hout

    def chunks(t):
        return jnp.moveaxis(
            t.reshape(t.shape[0], t.shape[1], nchunk, c, *t.shape[3:]), 2, 0)

    carry0 = (jnp.zeros((b, h_, dh, dh), jnp.float32),
              jnp.zeros((b, h_, dh), jnp.float32),
              jnp.full((b, h_), -1e30, jnp.float32))
    carry, hs = jax.lax.scan(body, carry0,
                             tuple(map(chunks, (qp, kp, vp, igp, lfp))))
    hout = jnp.moveaxis(hs, 0, 2).reshape(b, h_, s + pad, dh)[:, :, :s]
    y = mmc(cfg, "bhsk,hkd->bsd", hout.astype(cd), p["wo"].astype(cd))
    new_cache = None
    if mode == "prefill":
        # NOTE: with padding the carry includes pad steps; exact only when
        # c divides s (true for the assigned shapes; asserted here).
        assert pad == 0, "prefill length must be a multiple of mlstm_chunk"
        new_cache = {"mC": carry[0], "mn": carry[1], "mm": carry[2]}
    return y.astype(x.dtype), new_cache


def init_slstm(key, cfg: ModelConfig) -> Params:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim_
    pd = _pdtype(cfg)
    ks = _split(key, 3)
    return {
        "wx": dense_init(ks[0], (d, 4, h, dh), d, pd),      # z, i, f, o
        "r": dense_init(ks[1], (h, dh, 4, dh), dh, pd),
        "wo": dense_init(ks[2], (h, dh, d), h * dh, pd),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    h, dh = cfg.n_heads, cfg.head_dim_
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"sc": z, "sn": z, "sh": z,
            "sm": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def _slstm_step(p_r, state, gx):
    """gx: (B, 4, H, dh) input projections for one step."""
    c, n, hprev, m = state
    rec = jnp.einsum("bhk,hkgl->bghl", hprev, p_r)
    g = gx.astype(jnp.float32) + rec
    z = jnp.tanh(g[:, 0])
    i_t = g[:, 1]
    f_t = g[:, 2]
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(f_t + m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_t + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
                cache: Cache = None, mode: str = "train"
                ) -> Tuple[jax.Array, Cache]:
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim_
    cd = _cdtype(cfg)
    gx = einsum32("bsd,dghk->bsghk", x.astype(cd), p["wx"].astype(cd))
    rmat = p["r"].astype(jnp.float32)

    if mode == "decode":
        state = (cache["sc"], cache["sn"], cache["sh"], cache["sm"])
        state, hnew = _slstm_step(rmat, state, gx[:, 0])
        y = einsum32("bhk,hkd->bd", hnew.astype(cd),
                     p["wo"].astype(cd))[:, None]
        c, n, hh, m = state
        return y.astype(x.dtype), {"sc": c, "sn": n, "sh": hh, "sm": m}

    def body(state, gxi):
        return _slstm_step(rmat, state, gxi)

    z = jnp.zeros((b, h_, dh), jnp.float32)
    state0 = (z, z, z, jnp.full((b, h_, dh), -1e30, jnp.float32))
    state, hs = jax.lax.scan(body, state0, jnp.moveaxis(gx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                        # (B,S,H,dh)
    y = mmc(cfg, "bshk,hkd->bsd", hs.astype(cd), p["wo"].astype(cd))
    new_cache = None
    if mode == "prefill":
        c, n, hh, m = state
        new_cache = {"sc": c, "sn": n, "sh": hh, "sm": m}
    return y.astype(x.dtype), new_cache
