"""AdamW with fully-sharded states.

States mirror the parameter pytree (the sharding resolver reuses the param
logical axes), with a configurable state dtype — 480B-class MoE models
(arctic) hold m/v in bf16 to fit a single pod (see configs/arctic_480b.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    state_dtype: str = "float32"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Params
    v: Params
    step: jax.Array


def adamw_init(params: Params, cfg: AdamWConfig) -> OptState:
    sd = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    return OptState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Params, grads: Params, state: OptState,
                 cfg: AdamWConfig) -> Tuple[Params, OptState, dict]:
    """One AdamW step with global-norm clipping and decoupled weight decay."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(sd), v_new.astype(sd)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}


def opt_state_axes(params_axes: Any) -> Any:
    """Logical axes for OptState given the params' axes (m/v mirror them)."""
    return OptState(m=params_axes, v=params_axes, step=())
