"""EARL-adaptive gradient accumulation (beyond-paper application of C1).

Microbatch gradients g_1..g_M are an iid sample of the full-batch gradient.
EARL's question — "is the sample accurate enough to stop early?" — applies
verbatim: bootstrap the per-microbatch gradient *norms* (a cheap scalar
proxy), and stop accumulating when the coefficient of variation of the
mean-gradient estimate drops below sigma.  On well-conditioned batches this
saves 30-60% of accumulation compute; on noisy batches it degrades to the
full schedule.

This is a host-side control decision (like the paper's mapper↔reducer
feedback): the jitted step computes per-microbatch norms; the EARL check
runs between steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import poisson_weights


@dataclasses.dataclass
class AccumDecision:
    stop: bool
    cv: float
    microbatches_used: int
    mean_loss: float = float("nan")


def gradient_cv(norms: np.ndarray, B: int = 32, seed: int = 0) -> float:
    """Bootstrap c_v of the mean gradient-norm estimate from per-microbatch
    norms (scalar proxy for the gradient's sampling error)."""
    n = len(norms)
    if n < 2:
        return float("inf")
    w = np.asarray(poisson_weights(jax.random.PRNGKey(seed), B, n))
    boots = (w @ norms) / np.maximum(w.sum(axis=1), 1e-9)
    return float(accuracy.coefficient_of_variation(jnp.asarray(boots)))


def earl_accumulate_gradients(
        grad_fn: Callable[[Any, Any], Tuple[Any, jax.Array]],
        params: Any, microbatches: List[Any], sigma: float = 0.02,
        min_micro: int = 2) -> Tuple[Any, AccumDecision]:
    """grad_fn(params, mb) -> (grads pytree, grad_norm scalar).

    Accumulates microbatch gradients; after each one, bootstraps the norm
    history and stops early when cv <= sigma (the remaining microbatches
    are skipped — EARL's early termination applied to the optimizer)."""
    acc = None
    norms: List[float] = []
    losses: List[float] = []
    used = 0
    for i, mb in enumerate(microbatches):
        out = grad_fn(params, mb)
        grads, gnorm = out[0], out[1]
        if len(out) > 2:
            losses.append(float(out[2]))
        acc = grads if acc is None else jax.tree_util.tree_map(
            jnp.add, acc, grads)
        norms.append(float(gnorm))
        used += 1
        if used >= min_micro:
            cv = gradient_cv(np.asarray(norms), seed=used)
            if cv <= sigma:
                break
    mean_grads = jax.tree_util.tree_map(lambda g: g / used, acc)
    final_cv = gradient_cv(np.asarray(norms), seed=0)
    return mean_grads, AccumDecision(
        stop=used < len(microbatches), cv=final_cv, microbatches_used=used,
        mean_loss=float(np.mean(losses)) if losses else float("nan"))
