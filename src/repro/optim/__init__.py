"""Optimizer substrate: fully-sharded AdamW, bf16 gradient compression with
error feedback, and EARL-adaptive gradient accumulation."""
from repro.optim.adamw import (AdamWConfig, OptState, adamw_init,
                               adamw_update, opt_state_axes)
from repro.optim.compression import (compress_decompress,
                                     error_feedback_compress)
from repro.optim.adaptive_accum import (AccumDecision,
                                        earl_accumulate_gradients)

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "opt_state_axes", "compress_decompress", "error_feedback_compress",
    "AccumDecision", "earl_accumulate_gradients",
]
