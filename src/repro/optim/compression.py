"""Gradient compression for the cross-pod (DCN) all-reduce.

At 2+ pods the "pod" axis all-reduce crosses data-center network links an
order of magnitude slower than ICI; compressing gradients to bf16 with
error feedback (residual carried into the next step) halves that traffic
with no convergence penalty in practice.  The compression is applied
inside train_step before the psum that GSPMD maps onto the pod axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def compress_decompress(grads: Params, dtype=jnp.bfloat16) -> Params:
    """Quantize-dequantize (models the lossy wire format)."""
    return jax.tree_util.tree_map(
        lambda g: g.astype(dtype).astype(g.dtype), grads)


def error_feedback_compress(grads: Params, residual: Params,
                            dtype=jnp.bfloat16) -> Tuple[Params, Params]:
    """1-bit-style error feedback at bf16 granularity.

    sent = Q(g + r);  r' = (g + r) - sent.  Returns (sent, new_residual).
    """
    def one(g, r):
        total = g.astype(jnp.float32) + r.astype(jnp.float32)
        sent = total.astype(dtype)
        new_r = total - sent.astype(jnp.float32)
        return sent.astype(g.dtype), new_r.astype(r.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    sent = treedef.unflatten([p[0] for p in pairs])
    new_res = treedef.unflatten([p[1] for p in pairs])
    return sent, new_res


def init_residual(params: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
