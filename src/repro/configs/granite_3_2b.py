"""granite-3-2b [dense]: GQA full attention.
40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,                      # padded to 51200 (vocab_pad_multiple)
    layer_pattern=("full",),
    rope_theta=10_000.0,
    supports_long_context=False,
)
