"""arctic-480b [moe]: 128 experts top-2 with a parallel dense residual FFN.
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base; hf]

At 480B params the optimizer must be fully sharded AND held in bf16 to fit
a 256-chip v5e pod (see EXPERIMENTS.md §Dry-run memory notes)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    layer_pattern=("full",),
    num_experts=128,
    top_k=2,
    dense_residual=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",           # 480B: fp32 states cannot fit one pod
    adam_dtype="bfloat16",
    supports_long_context=False,
)
