"""xlstm-350m [ssm]: alternating sLSTM + mLSTM blocks, no separate FFN.
24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                           # per spec: cell-internal projections only
    vocab=50304,
    layer_pattern=("slstm", "mlstm"),
    mlstm_chunk=256,
    supports_long_context=True,       # O(1)/token recurrent state
)
