"""stablelm-3b [dense]: full-attention MHA-style GQA (kv == heads).
32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    layer_pattern=("full",),
    rope_theta=10_000.0,
    supports_long_context=False,      # pure full attention -> long_500k skip
)
