"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    layer_pattern=("swa",),
    window=4096,
    num_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
    supports_long_context=True,
)
