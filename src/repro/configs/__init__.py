"""Config registry: ``get_config(arch_id, smoke=False)`` + input specs.

Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); ``smoke`` variants are runnable-on-CPU reductions of the same
family (same pattern, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import (SHAPES, SMOKE_SHAPES, ModelConfig,
                                 ShapeConfig, shape_is_supported)

ARCH_IDS = (
    "h2o-danube-3-4b",
    "stablelm-3b",
    "gemma3-27b",
    "granite-3-2b",
    "mixtral-8x22b",
    "arctic-480b",
    "xlstm-350m",
    "llama-3.2-vision-90b",
    "recurrentgemma-2b",
    "whisper-small",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def smoke_of(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: 2 pattern repeats + remainder shape kept."""
    kv = (cfg.n_kv_heads if cfg.n_kv_heads in (1,) else
          (4 if cfg.n_kv_heads == cfg.n_heads else 2))
    rem = min(len(cfg.rem_pattern), 1)
    return dataclasses.replace(
        cfg,
        n_layers=2 * cfg.pattern_len + rem,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=509,                       # deliberately non-multiple (padding)
        vocab_pad_multiple=128,
        window=16 if cfg.window else 0,
        num_experts=4 if cfg.num_experts else 0,
        top_k=2 if cfg.num_experts else 0,
        vision_tokens=8 if cfg.vision_tokens else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        rnn_width=64 if cfg.rnn_width else 0,
        mlstm_chunk=16,
        attn_block_q=16,
        attn_block_k=16,
        loss_chunk=16,
        param_dtype="float32",
        compute_dtype="float32",
        adam_dtype="float32",
    )


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg: ModelConfig = importlib.import_module(_MODULES[arch_id]).CONFIG
    cfg.validate()
    return smoke_of(cfg) if smoke else cfg


def get_shape(shape_id: str, smoke: bool = False) -> ShapeConfig:
    table = SMOKE_SHAPES if smoke else SHAPES
    return table[shape_id]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; never allocates)
# ---------------------------------------------------------------------------
def _aux_spec(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.vision_tokens, cfg.d_model),
                                    cd)
    if cfg.is_encdec:
        return jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), cd)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every input of the (train|prefill|decode) step."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        aux = _aux_spec(cfg, b)
        if aux is not None:
            specs["aux"] = aux
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        aux = _aux_spec(cfg, b)
        if aux is not None:
            specs["aux"] = aux
        return specs
    if shape.kind == "decode":
        from repro.models import decoder
        cache = jax.eval_shape(
            lambda: decoder.init_serve_cache(cfg, b, s))
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "cache": cache,
        }
    raise ValueError(shape.kind)


__all__ = ["ARCH_IDS", "get_config", "get_shape", "input_specs", "smoke_of",
           "SHAPES", "SMOKE_SHAPES", "shape_is_supported"]
