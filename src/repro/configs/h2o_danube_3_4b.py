"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
[arXiv:2401.16818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    layer_pattern=("swa",),
    window=4096,                      # mistral-style SWA
    rope_theta=10_000.0,
    supports_long_context=True,       # SWA caps attention cost
)
