"""recurrentgemma-2b [hybrid]: RG-LRU recurrence + local attention, 1:2
attention:recurrent pattern (Griffin).
26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,                      # 8 groups of (rglru,rglru,local) + 2
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    rnn_width=2560,
    conv_width=4,
    rope_theta=10_000.0,
    supports_long_context=True,
)
