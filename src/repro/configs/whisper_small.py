"""whisper-small [audio]: encoder-decoder backbone; the conv audio frontend
is a STUB (input_specs provides precomputed frame embeddings at d_model).
12L enc + 12L dec, d_model=768 12H (kv=12) d_ff=3072 vocab=51865
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                      # decoder depth (12L per spec)
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    layer_pattern=("dec",),           # causal self + cross to encoder
    enc_layers=12,
    enc_seq=1500,                     # 30 s of audio at 50 Hz frames
    rope_theta=10_000.0,
    supports_long_context=False,
)
