"""The paper's own workload: EARL analytics jobs (mean / median / K-Means)
over a synthetic sharded store — the configuration behind benchmarks/fig*.

Not a neural architecture; this is the "paper's own config" entry of the
assignment (EARL is pure infrastructure evaluated on analytics jobs)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class AnalyticsConfig:
    name: str = "earl-analytics"
    N: int = 2_000_000             # population rows
    split_size: int = 65_536       # HDFS-split analogue
    sigma: float = 0.05            # paper §6: 5% normalized error
    tau: float = 0.01              # error-stability threshold
    p_pilot: float = 0.01          # paper §3.2: p = 0.01 pilot
    l: int = 5                     # paper §3.2: l = 5 nested subsamples
    kmeans_k: int = 5
    kmeans_iters: int = 8
    engine: str = "poisson"        # distributed default (DESIGN.md §7.1)


CONFIG = AnalyticsConfig()
