"""gemma3-27b [dense]: 5:1 local:global attention pattern, 128k context.
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,                      # 10 groups of (5 local + 1 global) + 2
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,                      # gemma3 local window
    rope_theta=1_000_000.0,
    supports_long_context=True,       # 5/6 layers windowed; global layers
                                      # are O(S) per decoded token
)
