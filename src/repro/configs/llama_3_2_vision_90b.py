"""llama-3.2-vision-90b [vlm]: decoder with gated cross-attention image
layers every 5th layer (20 of 100).  Vision frontend is a STUB: input_specs
provides precomputed patch embeddings at d_model.
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    layer_pattern=("full", "full", "full", "full", "xattn"),
    vision_tokens=1600,               # stub ViT patch-embedding count
    rope_theta=500_000.0,
    supports_long_context=False,
)
