"""Early-accurate distributed evaluation — EARL's flagship integration.

Estimating a model's loss over a huge eval corpus IS the paper's problem
("compute statistic f over data set S"): the statistic is the mean
per-example loss, a sampled example is one document, and the model forward
pass is the user's job j.  We wrap the jitted eval step in a Sampler whose
``take(a, b)`` *computes* the per-example losses of permutation rows
[a, b) — EarlSession (pilot → SSABE → expand-until-accurate, with
delta-maintained resamples) then works unchanged on top.

A full eval pass costs N forwards; EARL typically certifies σ-accuracy
after 1-5% of them (see benchmarks/fig5 for the analytics analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reduce_api import Mean
from repro.core.session import EarlSession, EarlyResult
from repro.data.pipeline import EvalSamplePipeline


class LossValuesSampler:
    """Adapter: EarlSession sampler whose rows are model losses.

    Lazily evaluates (and caches) per-example losses for permutation
    prefixes, in jitted minibatches of ``eval_batch``.
    """

    def __init__(self, eval_step: Callable, params: Any,
                 pipeline: EvalSamplePipeline, eval_batch: int = 16,
                 aux_fn: Optional[Callable[[int], Any]] = None):
        self.eval_step = eval_step
        self.params = params
        self.pipeline = pipeline
        self.eval_batch = eval_batch
        self.aux_fn = aux_fn
        self.N = pipeline.N
        self._losses = np.full((self.N,), np.nan, np.float32)
        self._have = 0
        self.forwards = 0           # model forwards spent (for the speedup)

    def _ensure(self, upto: int) -> None:
        upto = min(upto, self.N)
        while self._have < upto:
            a = self._have
            b = min(a + self.eval_batch, upto)
            tokens, labels = self.pipeline.take(a, b)
            batch = {"tokens": tokens, "labels": labels}
            if self.aux_fn is not None:
                batch["aux"] = self.aux_fn(b - a)
            losses = self.eval_step(self.params, batch)
            self._losses[a:b] = np.asarray(losses)
            self.forwards += b - a
            self._have = b

    def take(self, start: int, stop: int) -> jnp.ndarray:
        self._ensure(stop)
        return jnp.asarray(self._losses[start:stop])


@dataclasses.dataclass
class EarlEval:
    """Early-accurate eval-loss estimation for a model + eval corpus."""
    eval_step: Callable
    params: Any
    pipeline: EvalSamplePipeline
    sigma: float = 0.01
    tau: float = 0.02
    eval_batch: int = 16
    aux_fn: Optional[Callable[[int], Any]] = None

    def run(self, key: jax.Array) -> EarlyResult:
        sampler = LossValuesSampler(self.eval_step, self.params,
                                    self.pipeline, self.eval_batch,
                                    self.aux_fn)
        session = EarlSession(sampler, Mean(), sigma=self.sigma,
                              tau=self.tau)
        result = session.run(key)
        # attach the real cost (model forwards), the paper's speedup metric
        result.history.append({"model_forwards": sampler.forwards,
                               "full_pass_forwards": sampler.N})
        return result
