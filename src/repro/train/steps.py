"""Step builders: the jitted train / eval / prefill / decode programs.

These are what launch/dryrun.py lowers against the production mesh and what
the examples execute on CPU with smoke configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.config import ModelConfig
from repro.models.partitioning import param_axes
from repro.optim.adamw import (AdamWConfig, OptState, adamw_init,
                               adamw_update, opt_state_axes)

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Params
    opt: OptState


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     opt_cfg: AdamWConfig) -> TrainState:
    params = decoder.init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def train_state_axes(state_shapes: Any) -> Any:
    """Logical axes for a TrainState (m/v mirror params)."""
    p_axes = param_axes(state_shapes.params)
    return TrainState(params=p_axes, opt=opt_state_axes(p_axes))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def lfn(p):
            loss, metrics = decoder.loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            lfn, has_aux=True)(state.params)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    """(params, batch) -> per-example loss (B,) — the earl_eval statistic."""
    def eval_step(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return decoder.per_example_loss(cfg, params, batch)
    return eval_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params: Params, batch: Dict[str, jax.Array]):
        return decoder.prefill(cfg, params, batch["tokens"],
                               aux=batch.get("aux"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params: Params, cache: Params, token: jax.Array,
                    pos: jax.Array):
        return decoder.decode_step(cfg, params, cache, token, pos)
    return decode_step


def make_grad_step(cfg: ModelConfig):
    """(params, batch) -> (grads, grad_norm, loss) — EARL-adaptive accum."""
    from repro.optim.adamw import global_norm

    def grad_step(params: Params, batch: Dict[str, jax.Array]):
        def lfn(p):
            loss, _ = decoder.loss_fn(cfg, p, batch)
            return loss
        loss, grads = jax.value_and_grad(lfn)(params)
        return grads, global_norm(grads), loss

    return grad_step
