"""Training/serving loops with EARL integrated as a first-class feature."""
from repro.train.steps import (TrainState, make_decode_step, make_eval_step,
                               make_prefill_step, make_train_step,
                               train_state_axes)
from repro.train.earl_eval import EarlEval, LossValuesSampler

__all__ = ["TrainState", "make_decode_step", "make_eval_step",
           "make_prefill_step", "make_train_step", "train_state_axes",
           "EarlEval", "LossValuesSampler"]
