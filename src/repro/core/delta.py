"""Delta-maintained resampling (paper §4).

Inter-iteration (§4.1): when the sample grows s -> s' = s ∪ Δs, reuse the
B resamples instead of redrawing them.

* ``PoissonDelta``           — beyond-paper exact path (DESIGN.md §7.1):
  under Poisson(1) weights, old items' weights are independent of n, so
  extension = draw weights for Δs only and ``merge`` the per-resample
  states.  O(B·Δn), exact, jittable, shard-independent.

* ``MultinomialDeltaBootstrap`` — paper-faithful baseline: maintains item-
  level resamples; on extension the old-part size is drawn from
  Binomial(n', n/n') (Gaussian-approximated per Eq. 3 when n is large),
  items are deleted/added through the §4.1 two-layer *sketch* (memory
  layer of c·sqrt(n) random items over a "disk" layer), and we count the
  simulated disk accesses the sketch saves.  Host/NumPy on purpose — it is
  the baseline benchmarks/fig10 compares against.

Intra-iteration (§4.2): resamples share identical fractions; a shared-base
resample's partial state is computed once and merged into every resample
(Eq. 4 gives the work saved).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import (BootstrapResult, fused_resample_states,
                                  offset_seed, poisson_weights,
                                  seed_from_key, sharded_fused_states)
from repro.core.reduce_api import (Statistic, StatisticGroup, _as_2d,
                                   bind_params, split_params)


# ============================================================================
# Poisson delta maintenance (exact, jittable)
# ============================================================================
@dataclasses.dataclass
class PoissonDelta:
    stat: Statistic
    key: jax.Array
    states: Any          # pytree with leading B axis
    est_state: Any       # unweighted state over the whole sample
    B: int
    n: int
    step: int            # key-folding counter (one per extend)
    backend: Optional[str] = None   # None = jnp weights, "fused_rng" =
    #                                 matrix-free in-kernel RNG (O(B·d) peak)
    mesh: Any = None                # fused backend only: shard each Δs over
    data_axis: str = "data"         # this mesh axis and psum the states


def poisson_delta_init(stat: Statistic, B: int, dim: int, key: jax.Array,
                       backend: Optional[str] = None, mesh=None,
                       data_axis: str = "data") -> PoissonDelta:
    if backend not in (None, "fused_rng"):
        raise ValueError(f"unknown delta backend: {backend!r}")
    if mesh is not None and backend != "fused_rng":
        raise ValueError("mesh= requires backend='fused_rng' (sharded delta "
                         "maintenance psums fused states)")
    states = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))
    return PoissonDelta(stat=stat, key=key, states=states,
                        est_state=stat.init_state(dim), B=B, n=0, step=0,
                        backend=backend, mesh=mesh, data_axis=data_axis)


@partial(jax.jit, static_argnames=("stat", "B", "backend", "mesh",
                                   "data_axis"))
def _pd_extend_jit(states, est_state, key, step, x, params, stat, B,
                   backend, mesh=None, data_axis="data"):
    stat = bind_params(stat, params)   # traced array params (e.g. centroids)
    if backend == "fused_rng":
        # matrix-free: the Δs weight matrix never materializes; delta
        # states from in-kernel-RNG weights merge into the running states.
        # Streams are offset_seed(seed_from_key(key), step) — distinct per
        # extend by construction (see seed_from_key), safe at the int32
        # boundary.  With a mesh, each shard of Δs draws its own stream
        # (keyed (base, shard, step)) and the delta states psum before the
        # merge — extension traffic is O(B·d states), never O(B·Δn).
        if mesh is not None:
            delta_states = sharded_fused_states(
                stat, seed_from_key(key), x, B, mesh=mesh,
                data_axis=data_axis, step=step)
        else:
            delta_states = fused_resample_states(
                stat, offset_seed(seed_from_key(key), step), x, B)
        new_states = jax.vmap(stat.merge)(states, delta_states)
    else:
        w = poisson_weights(jax.random.fold_in(key, step), B, x.shape[0])
        new_states = jax.vmap(lambda s, wr: stat.update(s, x, wr))(states, w)
    new_est = stat.update(est_state, x)
    return new_states, new_est


def poisson_delta_extend(pd: PoissonDelta, new_values: jax.Array
                         ) -> PoissonDelta:
    """Exact inter-iteration maintenance: weights drawn for Δs only; the
    point estimate's state is maintained incrementally too (O(Δn))."""
    x = _as_2d(new_values)
    dn = x.shape[0]
    spec, params = split_params(pd.stat)
    states, est_state = _pd_extend_jit(pd.states, pd.est_state, pd.key,
                                       pd.step, x, params, spec, pd.B,
                                       pd.backend, pd.mesh, pd.data_axis)
    return dataclasses.replace(pd, states=states, est_state=est_state,
                               n=pd.n + dn, step=pd.step + 1)


def poisson_delta_result(pd: PoissonDelta, estimate: Any = None,
                         p: float = 1.0,
                         p_keys: Optional[np.ndarray] = None
                         ) -> BootstrapResult:
    """Finalize a delta run into a ``BootstrapResult``.

    ``p`` is the whole-table sampled fraction for ``correct``.  For a
    keyed statistic under STRATIFIED sampling, pass ``p_keys`` (per-key
    sampled fractions, length ``num_groups``) instead: each key's thetas
    and estimate are corrected by that key's own inclusion probability
    (``GroupedStatistic.correct_per_key``), and the fractions are surfaced
    on the resulting ``KeyedAccuracyReport.p_keys``."""
    num_groups = getattr(pd.stat, "num_groups", None)
    raw_thetas = jax.vmap(pd.stat.finalize)(pd.states)
    if estimate is None:
        estimate = pd.stat.finalize(pd.est_state)
    if p_keys is not None:
        if num_groups is None:
            raise ValueError("p_keys needs a keyed statistic "
                             "(GroupedStatistic)")
        thetas = pd.stat.correct_per_key(raw_thetas, p_keys, key_axis=1)
        estimate = pd.stat.correct_per_key(estimate, p_keys, key_axis=0)
    else:
        thetas = pd.stat.correct(raw_thetas, p)
        estimate = pd.stat.correct(estimate, p)
    return BootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.report_for(thetas, num_groups=num_groups,
                                   p_keys=p_keys),
        B=pd.B, n=pd.n,
    )


# ============================================================================
# Paper-faithful multinomial delta maintenance with sketches (§4.1)
# ============================================================================
class Sketch:
    """Two-layer memory/disk structure of §4.1.

    ``data`` lives on "disk"; ``c·sqrt(len(data))`` random items live in the
    memory layer.  Sequentially consuming memory items avoids disk access;
    exhausting the sketch triggers a (counted) disk refill.
    """

    def __init__(self, data: np.ndarray, c: float, rng: np.random.Generator):
        self.data = data
        self.c = c
        self.rng = rng
        self.disk_accesses = 0
        self._refill()

    def _refill(self) -> None:
        self.disk_accesses += 1           # one bulk disk read (commit+resample)
        k = min(len(self.data), max(1, int(self.c * math.sqrt(len(self.data)))))
        idx = self.rng.choice(len(self.data), size=k, replace=False)
        self.mem = self.data[idx]
        self.pos = 0

    def take(self, k: int) -> np.ndarray:
        out = []
        while k > 0:
            avail = len(self.mem) - self.pos
            if avail == 0:
                self._refill()
                avail = len(self.mem)
            t = min(k, avail)
            out.append(self.mem[self.pos:self.pos + t])
            self.pos += t
            k -= t
        return np.concatenate(out) if out else self.data[:0]


class MultinomialDeltaBootstrap:
    """Item-level faithful implementation of §4.1 (the fig10 baseline).

    Resamples are index arrays into the growing sample.  ``use_sketch``
    toggles the memory-layer optimization; ``use_gaussian`` toggles the
    Eq. 3 Gaussian approximation of the Eq. 2 binomial.
    """

    def __init__(self, stat: Statistic, B: int, seed: int = 0,
                 c: float = 4.0, use_sketch: bool = True,
                 use_gaussian: bool = True):
        if isinstance(stat, StatisticGroup):
            raise TypeError(
                "MultinomialDeltaBootstrap is the host/NumPy fig10 baseline"
                " and stacks scalar thetas — run StatisticGroup through the"
                " Poisson delta path (poisson_delta_init) instead")
        if getattr(stat, "num_groups", None) is not None:
            raise TypeError(
                "MultinomialDeltaBootstrap does not produce per-key reports"
                " — run GroupedStatistic through the Poisson delta path"
                " (poisson_delta_init) instead")
        self.stat = stat
        self.B = B
        self.rng = np.random.default_rng(seed)
        self.c = c
        self.use_sketch = use_sketch
        self.use_gaussian = use_gaussian
        self.sample = None                 # np.ndarray (n, d)
        self.resamples = None              # list of np index arrays
        self.disk_accesses = 0
        self.items_moved = 0               # total delete+add work performed

    @property
    def n(self) -> int:
        return 0 if self.sample is None else len(self.sample)

    def _old_part_size(self, n: int, n_new: int) -> int:
        """|b'_{i,s}| ~ Binomial(n', n/n')  (Eq. 2), Gaussian approx (Eq. 3)."""
        p = n / n_new
        if self.use_gaussian and n_new >= 64:
            k = int(round(self.rng.normal(n, math.sqrt(n * (1.0 - p)))))
        else:
            k = int(self.rng.binomial(n_new, p))
        return int(np.clip(k, 0, n_new))

    def extend(self, delta: np.ndarray) -> None:
        delta = np.asarray(delta)
        if delta.ndim == 1:
            delta = delta[:, None]
        if self.sample is None:
            # first iteration: Δs_1 against the empty set (paper §4.1)
            self.sample = delta
            n = len(delta)
            self.resamples = [self.rng.integers(0, n, size=n)
                              for _ in range(self.B)]
            return

        n = self.n
        n_new = n + len(delta)
        base = len(self.sample)
        self.sample = np.concatenate([self.sample, delta], axis=0)

        s_sketch = (Sketch(np.arange(n), self.c, self.rng)
                    if self.use_sketch else None)
        d_sketch = (Sketch(np.arange(base, n_new), self.c, self.rng)
                    if self.use_sketch else None)

        new_resamples = []
        for b in self.resamples:
            k = self._old_part_size(n, n_new)
            if k < n:                                   # random deletions
                keep = self.rng.permutation(n)[:k]
                b = b[keep]
                self.items_moved += n - k
            elif k > n:                                 # additions from s
                if s_sketch is not None:
                    add = s_sketch.take(k - n)
                else:
                    self.disk_accesses += k - n         # item-wise disk reads
                    add = self.rng.integers(0, n, size=k - n)
                b = np.concatenate([b, add])
                self.items_moved += k - n
            # additions from Δs
            m = n_new - k
            if d_sketch is not None:
                add_d = d_sketch.take(m)
            else:
                self.disk_accesses += m
                add_d = self.rng.integers(base, n_new, size=m)
            self.items_moved += m
            new_resamples.append(np.concatenate([b, add_d]))
        if s_sketch is not None:
            self.disk_accesses += s_sketch.disk_accesses
            self.disk_accesses += d_sketch.disk_accesses
        self.resamples = new_resamples

    def thetas(self) -> jnp.ndarray:
        outs = []
        for b in self.resamples:
            vals = jnp.asarray(self.sample[b])
            outs.append(self.stat(vals))
        return jnp.stack([jnp.asarray(o) for o in outs])

    def result(self, p: float = 1.0) -> BootstrapResult:
        thetas = self.stat.correct(self.thetas(), p)
        est = self.stat.correct(self.stat(jnp.asarray(self.sample)), p)
        return BootstrapResult(
            estimate=est, thetas=thetas,
            report=accuracy.report_for(thetas),
            B=self.B, n=self.n,
        )


# ============================================================================
# Intra-iteration optimization (§4.2)
# ============================================================================
def p_shared(n: int, y: float) -> float:
    """Eq. 4: P(X=y) = n! / ((n - y·n)! · n^{y·n}), in log space."""
    k = int(round(y * n))
    if k <= 0:
        return 1.0
    if k > n:
        return 0.0
    logp = (math.lgamma(n + 1) - math.lgamma(n - k + 1) - k * math.log(n))
    return min(1.0, math.exp(logp))


def work_saved(n: int, y: float) -> float:
    """Expected fraction of resample work saved: P(X=y)·y (paper §4.2)."""
    return p_shared(n, y) * y


def optimal_y(n: int, grid: int = 200) -> Tuple[float, float]:
    """argmax_y work_saved(n, y) by scan (paper: simple binary search)."""
    best_y, best_w = 0.0, 0.0
    for i in range(1, grid + 1):
        y = i / grid
        w = work_saved(n, y)
        if w > best_w:
            best_y, best_w = y, w
    return best_y, best_w


def shared_base_bootstrap(values: jax.Array, stat: Statistic, B: int,
                          key: jax.Array, y: Optional[float] = None,
                          p: float = 1.0) -> BootstrapResult:
    """Intra-iteration optimized bootstrap: a shared y·n sub-resample's state
    is computed once and merged into every resample's remainder state.

    Work: n·y (once) + B·n·(1−y)  vs  B·n  for the standard bootstrap.
    """
    x = _as_2d(values)
    n, dim = x.shape
    if y is None:
        y, _ = optimal_y(n)
    k = int(round(y * n))
    k_base, k_rest = k, n - k

    kb, kr = jax.random.split(key)
    base_idx = jax.random.randint(kb, (k_base,), 0, n)
    shared_state = stat.update(stat.init_state(dim), x[base_idx])

    rest_idx = jax.random.randint(kr, (B, max(k_rest, 1)), 0, n)

    def one(idx_row):
        st = stat.update(stat.init_state(dim), x[idx_row])
        return stat.finalize(stat.merge(shared_state, st)) if k_rest > 0 \
            else stat.finalize(shared_state)

    thetas = jax.vmap(one)(rest_idx)
    thetas = stat.correct(thetas, p)
    est = stat.correct(stat(values), p)
    return BootstrapResult(
        estimate=est, thetas=thetas,
        report=accuracy.report_for(thetas),
        B=B, n=n,
    )
