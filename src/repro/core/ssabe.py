"""SSABE — Sample Size And Bootstrap Estimation (paper §3.2).

Two phases, run on a *pilot* sample (p·N, p ≈ 0.01) in "local mode"
(single device, no mesh — the analogue of the paper's single-JVM pilot):

  Phase A: grow B over candidate values {2, ..., ceil(1/τ)} until the error
           estimate stabilizes: |c_v(B_i) − c_v(B_{i−1})| < τ.
  Phase B: split the pilot into l nested subsamples n_i = n/2^{l−i},
           compute c_v(n_i) with B̂ resamples (delta-maintained across the
           nested growth), least-squares fit the c_v(n) curve, invert for
           the n* that achieves the target σ.

The fitted family is c_v(n) = a·n^(−1/2) + c — the CLT decay the paper's
"best fitting curve" tracks; fit is linear least squares in 1/sqrt(n).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import (bootstrap_thetas, fused_resample_states,
                                  seed_from_key, sharded_fused_states,
                                  weights_for)
from repro.core.delta import poisson_delta_extend, poisson_delta_init, \
    poisson_delta_result
from repro.core.reduce_api import Statistic, _as_2d


def _cv_of(thetas, num_groups=None) -> float:
    """c_v of a theta distribution — for a StatisticGroup's tuple of
    per-member thetas this is the WORST member, so phase A/B converge only
    once every member of the group is stable (the group's AES contract).
    With ``num_groups`` (a GroupedStatistic's (B, G, ...) thetas) it is the
    WORST KEY, the per-key analogue of the same contract."""
    if isinstance(thetas, (tuple, list)):
        return max(float(accuracy.coefficient_of_variation(t))
                   for t in thetas)
    if num_groups is not None:
        return max(float(accuracy.coefficient_of_variation(thetas[:, g]))
                   for g in range(int(num_groups)))
    return float(accuracy.coefficient_of_variation(thetas))


@dataclasses.dataclass
class SSABEResult:
    B: int                      # estimated number of bootstraps
    n: int                      # estimated sample size for target sigma
    cv_history_B: List[Tuple[int, float]]   # phase A trace (B_i, cv_i)
    cv_history_n: List[Tuple[int, float]]   # phase B trace (n_i, cv_i)
    fit_a: float
    fit_c: float
    B_theory: int               # 0.5·eps0^-2 (paper §3)
    n_theory: int               # CLT prediction (for fig8)


def estimate_B(values: jax.Array, stat: Statistic, tau: float,
               key: jax.Array, engine: str = "poisson",
               B_min: int = 2, B_max: int | None = None,
               backend: str | None = None, mesh=None,
               data_axis: str = "data"
               ) -> Tuple[int, List[Tuple[int, float]]]:
    """Phase A.  Common random numbers: resample b is keyed by fold_in(key,b),
    so growing B reuses earlier resamples — c_v(B) is a stable nested
    sequence and the |Δc_v| < τ stop is meaningful (not MC noise).

    With ``backend="fused_rng"`` the nested-prefix property is even
    structural: implicit weights are keyed per (resample-tile, item-tile),
    so row b's weights are independent of B_max entirely."""
    if backend == "fused_rng" and engine != "poisson":
        raise ValueError("backend='fused_rng' requires the poisson engine "
                         "(in-kernel RNG draws iid Poisson(1) weights)")
    if mesh is not None and backend != "fused_rng":
        raise ValueError("mesh= requires backend='fused_rng' (same rule as "
                         "bootstrap/bootstrap_chunked/poisson_delta_init)")
    if B_max is None:
        B_max = max(B_min + 1, int(math.ceil(1.0 / tau)))
    x = _as_2d(values)
    n, dim = x.shape

    if backend == "fused_rng" and engine == "poisson":
        # matrix-free: thetas for all B_max resamples without the (B_max, n)
        # weight matrix (every built-in statistic has a
        # fused_poisson_states path — moments, KMeansStep, Quantile; custom
        # ones materialize the same implicit weights); prefixes of thetas
        # give nested B as before.  With a mesh the pilot shards over the
        # data axis and only the states psum.
        if mesh is not None:
            states = sharded_fused_states(stat, seed_from_key(key), x,
                                          B_max, mesh=mesh,
                                          data_axis=data_axis)
        else:
            states = fused_resample_states(stat, seed_from_key(key), x,
                                           B_max)
        thetas_full = jax.vmap(stat.finalize)(states)
    else:
        # draw the maximal weight matrix once; prefixes give nested B
        w_full = weights_for(engine, key, B_max, n)
        thetas_full = bootstrap_thetas(x, stat, w_full)

    # geometric candidate ladder: consecutive integers differ by O(1/B) by
    # construction (nested prefixes), which would stop at B≈3 for any tau;
    # doubling candidates make the |Δc_v| < τ test measure real convergence
    # of the bootstrap variance estimate (paper Fig 2a flattens near B≈30).
    candidates = []
    b = max(2, B_min)
    while b < B_max:
        candidates.append(b)
        b *= 2
    candidates.append(B_max)

    history: List[Tuple[int, float]] = []
    prev_cv = None
    chosen = B_max
    for B in candidates:
        cv = _cv_of(jax.tree_util.tree_map(lambda t: t[:B], thetas_full),
                    num_groups=getattr(stat, "num_groups", None))
        history.append((B, cv))
        if prev_cv is not None and abs(cv - prev_cv) < tau:
            chosen = B
            break
        prev_cv = cv
    return chosen, history


def fit_cv_curve(ns: np.ndarray, cvs: np.ndarray) -> Tuple[float, float]:
    """Least-squares fit  cv = a·n^(-1/2) + c ;  returns (a, c)."""
    A = np.stack([1.0 / np.sqrt(ns.astype(np.float64)),
                  np.ones_like(ns, dtype=np.float64)], axis=1)
    coef, *_ = np.linalg.lstsq(A, cvs.astype(np.float64), rcond=None)
    return float(coef[0]), float(coef[1])


def invert_cv_curve(a: float, c: float, sigma: float, n_cap: int) -> int:
    """Smallest n with a/sqrt(n) + c <= sigma (capped; paper falls back to
    the full data set when no n achieves sigma)."""
    if a <= 0:
        return 1 if c <= sigma else n_cap
    if c >= sigma:
        return n_cap
    n = (a / (sigma - c)) ** 2
    return int(min(max(1, math.ceil(n)), n_cap))


def estimate_n(values: jax.Array, stat: Statistic, sigma: float, B: int,
               key: jax.Array, l: int = 5, n_cap: int | None = None,
               backend: str | None = None, mesh=None,
               data_axis: str = "data"
               ) -> Tuple[int, List[Tuple[int, float]], float, float]:
    """Phase B with delta maintenance: the nested subsamples n_i = n/2^{l-i}
    are prefixes, so each step extends the Poisson-bootstrap states with the
    new half instead of recomputing (paper: "we perform delta maintenance")."""
    x = _as_2d(values)
    n, dim = x.shape
    if n_cap is None:
        n_cap = 1 << 62

    pd = poisson_delta_init(stat, B, dim, key, backend=backend, mesh=mesh,
                            data_axis=data_axis)
    history: List[Tuple[int, float]] = []
    prev = 0
    for i in range(1, l + 1):
        ni = max(2, n // (2 ** (l - i)))
        pd = poisson_delta_extend(pd, x[prev:ni])
        prev = ni
        res = poisson_delta_result(pd, estimate=stat(x[:ni]))
        history.append((ni, res.cv))

    ns = np.array([h[0] for h in history])
    cvs = np.array([h[1] for h in history])
    a, c = fit_cv_curve(ns, cvs)
    n_star = invert_cv_curve(a, c, sigma, n_cap)
    return n_star, history, a, c


def ssabe(pilot_values: jax.Array, stat: Statistic, sigma: float, tau: float,
          key: jax.Array, l: int = 5, N: int | None = None,
          engine: str = "poisson",
          backend: str | None = None, mesh=None,
          data_axis: str = "data") -> SSABEResult:
    """The full two-phase SSABE algorithm on a pilot sample.

    ``backend="fused_rng"`` routes both phases matrix-free (in-kernel
    Poisson weights) for every built-in statistic; ``mesh=`` additionally
    shards both phases over the data axis (states psum, weights never
    move)."""
    acc = accuracy
    kb, kn = jax.random.split(jax.random.fold_in(key, 0xEA))
    B_hat, hist_B = estimate_B(pilot_values, stat, tau, kb, engine=engine,
                               backend=backend, mesh=mesh,
                               data_axis=data_axis)
    n_cap = N if N is not None else int(1e12)
    n_hat, hist_n, a, c = estimate_n(pilot_values, stat, sigma, B_hat, kn,
                                     l=l, n_cap=n_cap, backend=backend,
                                     mesh=mesh, data_axis=data_axis)

    x = np.asarray(_as_2d(pilot_values))
    n_theory = acc.theoretical_sample_size(
        sigma, float(x.std()), float(x.mean()))
    return SSABEResult(
        B=B_hat, n=n_hat,
        cv_history_B=hist_B, cv_history_n=hist_n,
        fit_a=a, fit_c=c,
        B_theory=acc.theoretical_num_bootstraps(tau),
        n_theory=n_theory,
    )
