"""Incremental reduce API (paper §2.1, C5).

EARL extends Hadoop's reducer with  initialize() / update() / finalize() /
correct().  The TPU-native analogue is a ``Statistic`` over JAX pytree
*states* with one extra method the paper's combiner implies: ``merge``, the
associative combinator that makes a state ``psum``-able across mesh shards.

All built-in statistics are *weighted*: a bootstrap resample is represented
as a weight (count) vector over the sample (DESIGN.md §2), so ``update``
takes ``(values, weights)``.  ``weights=None`` means all-ones.

States are pytrees of arrays → they vmap over the B resample axis and psum
over the mesh for free.
"""
from __future__ import annotations

import copy
import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

State = Any
Result = Any

_EPS = 1e-12


class _ArrayParam(NamedTuple):
    """Hashable stand-in for an array attribute in a jit-static Statistic.

    ``split_params`` swaps array attributes (declared via
    ``Statistic.array_params``) for these markers so two Statistics with
    same-shaped parameters compare equal — the jit cache keys on structure
    while the array values travel as traced operands."""
    shape: Tuple[int, ...]
    dtype: str


def split_params(stat: "Statistic") -> Tuple["Statistic", dict]:
    """Split a Statistic into a (hashable, jit-static) spec and a dict of
    traced array parameters (the attributes named in ``stat.array_params``).

    The spec carries ``_ArrayParam(shape, dtype)`` markers in place of the
    arrays, so e.g. every ``KMeansStep(cent)`` of a Lloyd loop maps to ONE
    jit cache entry; ``bind_params`` re-attaches the (possibly traced)
    arrays inside the jitted function.  ``StatisticGroup`` splits
    member-wise and ``GroupedStatistic`` through its inner statistic, so a
    group wrapping a fresh same-shaped ``KMeansStep`` per Lloyd iteration
    still hits one cache entry."""
    if isinstance(stat, GroupedStatistic):
        ispec, iparams = split_params(stat.inner)
        if not iparams:
            return stat, {}
        return stat.with_inner(ispec), {"inner": iparams}
    if isinstance(stat, StatisticGroup):
        specs, params = [], {}
        for i, m in enumerate(stat.members):
            ms, mp = split_params(m)
            specs.append(ms)
            if mp:
                params[f"m{i}"] = mp
        if not params:
            return stat, {}
        return stat.with_members(tuple(specs)), params
    names = stat.array_params
    if not names:
        return stat, {}
    spec = copy.copy(stat)
    params = {}
    for name in names:
        v = getattr(stat, name)
        params[name] = v
        object.__setattr__(spec, name, _ArrayParam(
            tuple(jnp.shape(v)), jnp.result_type(v).name))
    return spec, params


def bind_params(stat: "Statistic", params: dict) -> "Statistic":
    """Inverse of ``split_params``: re-attach traced array parameters."""
    if not params:
        return stat
    if isinstance(stat, GroupedStatistic):
        return stat.with_inner(bind_params(stat.inner, params["inner"]))
    if isinstance(stat, StatisticGroup):
        members = list(stat.members)
        for k, mp in params.items():
            i = int(k[1:])
            members[i] = bind_params(members[i], mp)
        return stat.with_members(tuple(members))
    bound = copy.copy(stat)
    for name, v in params.items():
        object.__setattr__(bound, name, v)
    return bound


def _as_2d(values: jax.Array) -> jax.Array:
    values = jnp.asarray(values)
    if values.ndim == 1:
        return values[:, None]
    return values.reshape(values.shape[0], -1)


def _w(values: jax.Array, weights: Optional[jax.Array]) -> jax.Array:
    n = jnp.shape(values)[0]
    if weights is None:
        return jnp.ones((n,), dtype=jnp.float32)
    return jnp.asarray(weights, dtype=jnp.float32)


class Statistic:
    """Base class: the paper's reducer protocol on pytree states."""

    #: statistics whose state is a fixed set of weighted moments can be
    #: routed through the fused Pallas kernel (kernels/weighted_stats).
    moment_powers: Optional[Tuple[int, ...]] = None

    #: names of array-valued attributes that are *traced parameters* of the
    #: statistic (e.g. KMeansStep centroids).  The jit entry points split
    #: them out with ``split_params`` so they travel as traced operands
    #: instead of being closed over as compile-time constants — fresh
    #: instances with same-shaped parameters share one compilation.
    array_params: Tuple[str, ...] = ()

    #: whether ``merge`` is a true associative combinator over this
    #: statistic's states.  Every built-in is mergeable; custom statistics
    #: whose state is order-dependent (e.g. a reservoir keyed on arrival
    #: order) set this False and the chunked/sharded/streaming drivers —
    #: which all rely on merging partial states — reject them UP FRONT with
    #: an actionable ValueError instead of failing deep inside a trace.
    mergeable: bool = True

    # Structural hash/eq so jit caches keyed on a (static) Statistic hit
    # across instances: Mean() == Mean(); config'd stats compare by their
    # scalar attributes; ``split_params`` markers compare by (shape, dtype).
    # Raw array attributes NOT declared in ``array_params`` still compare by
    # identity — by-id is a cache miss for fresh instances, but weakening it
    # would let a compilation with stale baked-in constants be reused.
    def _static_key(self):
        items = []
        for k in sorted(self.__dict__):
            v = self.__dict__[k]
            if isinstance(v, Statistic):
                # nested statistics (GroupedStatistic.inner) compare
                # structurally — fresh GroupedStatistic(Mean(), G) instances
                # hit one jit cache entry like fresh Mean()s do.
                items.append((k, v._static_key()))
            elif isinstance(v, (int, float, str, bool, tuple, type(None))):
                items.append((k, v))
            else:
                items.append((k, id(v)))
        return (type(self), tuple(items))

    def __hash__(self):
        return hash(self._static_key())

    def __eq__(self, other):
        return (isinstance(other, Statistic)
                and self._static_key() == other._static_key())

    def init_state(self, dim: int) -> State:
        raise NotImplementedError

    def update(self, state: State, values: jax.Array,
               weights: Optional[jax.Array] = None) -> State:
        raise NotImplementedError

    def merge(self, a: State, b: State) -> State:
        """Associative combine — MUST satisfy merge(update(s0,x),update(s0,y))
        == update(update(s0,x),y) for the delta-maintenance paths (§4)."""
        return jax.tree_util.tree_map(jnp.add, a, b)

    def psum_state(self, state: State, axis_names) -> State:
        """Cross-device ``merge``: reduce a per-shard state over mesh axes.

        The default (every leaf is additive) matches ``merge``; statistics
        whose state carries non-additive configuration leaves (Quantile's
        lo/hi bin range) MUST override this, otherwise a psum would scale
        them by the shard count.  Used by the sharded fused bootstrap and
        core/distributed.py."""
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, axis_names), state)

    def finalize(self, state: State) -> Result:
        raise NotImplementedError

    def correct(self, result: Result, p: float) -> Result:
        """Rescale a sample-based result to the population (paper §2.1):
        p = fraction of data used.  Default: estimator is p-invariant."""
        del p
        return result

    def fused_poisson_states(self, seed, values: jax.Array, B: int,
                             n_valid=None,
                             valid_mask=None) -> Optional[State]:
        """Matrix-free hook for ``backend="fused_rng"``: B per-resample
        states under implicit in-kernel Poisson(1) weights, WITHOUT
        materializing the (B, n) weight matrix.

        Fused implementations exist for moment statistics (Mean/Sum/Count/
        Var/Std via kernels/weighted_stats) and KMeansStep (via
        kernels/kmeans_assign); the default ``None`` makes
        ``bootstrap.fused_resample_states`` fall back to materializing the
        same implicit weights.  ``values`` is already 2-D (n, d); ``seed``
        keys the counter-based PRNG tile discipline, so implementations
        must draw weights identical to
        ``weighted_stats.ops.implicit_weights(seed, B, n)``.

        ``valid_mask`` (traced (n,) f32 of exact 0.0/1.0) multiplies the
        implicit weight tiles — arbitrary interior validity holes (failed
        shards, dropped rows); a prefix-shaped mask reproduces the
        ``n_valid`` result bit for bit.
        """
        del seed, values, B, n_valid, valid_mask
        return None

    def accumulator_key(self) -> Optional[Tuple]:
        """Identity of this statistic's *accumulator* (state + update rule),
        or ``None`` if it can never be shared.

        ``StatisticGroup`` computes ONE state per distinct key: Mean/Var/Std
        all reduce to the same three weighted moments, and two Quantiles
        over the same bin range share one histogram sketch — so a
        (mean, var, median) group accumulates two states, not three, and
        each member ``finalize``s its own view of the shared state."""
        return None

    def tile_update(self, states: State, x_tile: jax.Array,
                    w_tile: jax.Array) -> State:
        """Advance B-leading per-resample ``states`` by one (n-tile, weight
        tile) block — the single-pass contract behind ``StatisticGroup``:
        the group draws each implicit Poisson(1) weight tile ONCE (shared
        ``weight_tile_blocks`` discipline) and hands the same (B, block_n)
        tile to every member's ``tile_update`` in turn, so k statistics pay
        one PRNG stream and one read of ``x_tile`` instead of k.

        ``x_tile`` is (block_n, d) with padding rows zeroed; ``w_tile`` is
        (B, block_n) with padding columns already masked to 0.  The default
        (a vmapped ``update`` over the weight rows — the per-tile callback
        fallback for custom statistics) is always correct and materializes
        nothing larger than the weight tile itself; built-ins override it
        with the same tile math as their fused kernels so a 1-member group
        is bit-identical to the dedicated fused path."""
        return jax.vmap(lambda s, wr: self.update(s, x_tile, wr))(
            states, w_tile)

    # convenience -----------------------------------------------------------
    def __call__(self, values: jax.Array,
                 weights: Optional[jax.Array] = None) -> Result:
        dim = _as_2d(values).shape[1]
        return self.finalize(self.update(self.init_state(dim), values, weights))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MomentState:
    w: jax.Array      # () total weight
    s1: jax.Array     # (d,) sum w*x
    s2: jax.Array     # (d,) sum w*x^2


class _MomentStatistic(Statistic):
    moment_powers = (0, 1, 2)

    def init_state(self, dim: int) -> MomentState:
        z = jnp.zeros((dim,), jnp.float32)
        return MomentState(w=jnp.zeros((), jnp.float32), s1=z, s2=z)

    def update(self, state: MomentState, values, weights=None) -> MomentState:
        x = _as_2d(values).astype(jnp.float32)
        w = _w(x, weights)
        return MomentState(
            w=state.w + jnp.sum(w),
            s1=state.s1 + w @ x,
            s2=state.s2 + w @ (x * x),
        )

    def from_moments(self, w, s1, s2) -> MomentState:
        return MomentState(w=w, s1=s1, s2=s2)

    def fused_poisson_states(self, seed, values, B, n_valid=None,
                             valid_mask=None):
        from repro.kernels.weighted_stats import ops as ws_ops
        w_tot, s1, s2 = ws_ops.fused_poisson_moments(seed, values, B,
                                                     n_valid=n_valid,
                                                     valid_mask=valid_mask)
        return jax.vmap(self.from_moments)(w_tot, s1, s2)

    def accumulator_key(self):
        # every moment statistic accumulates the identical (w, s1, s2)
        # state — one shared accumulator serves Mean+Var+Std+... at once.
        return ("moments",)

    def tile_update(self, states: MomentState, x_tile, w_tile) -> MomentState:
        """Same tile math as weighted_stats._fused_scan (dot accumulation,
        f32), so group moments are bit-identical to the fused kernel."""
        x = x_tile.astype(jnp.float32)
        return MomentState(
            w=states.w + jnp.sum(w_tile, axis=1),
            s1=states.s1 + jax.lax.dot(w_tile, x,
                                       preferred_element_type=jnp.float32),
            s2=states.s2 + jax.lax.dot(w_tile, x * x,
                                       preferred_element_type=jnp.float32),
        )


class Mean(_MomentStatistic):
    def finalize(self, state: MomentState):
        return state.s1 / (state.w + _EPS)


class Sum(_MomentStatistic):
    def finalize(self, state: MomentState):
        return state.s1

    def correct(self, result, p: float):
        return result / p


class Count(_MomentStatistic):
    def finalize(self, state: MomentState):
        return state.w

    def correct(self, result, p: float):
        return result / p


class Var(_MomentStatistic):
    def finalize(self, state: MomentState):
        m = state.s1 / (state.w + _EPS)
        return state.s2 / (state.w + _EPS) - m * m


class Std(Var):
    def finalize(self, state: MomentState):
        return jnp.sqrt(jnp.maximum(super().finalize(state), 0.0))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HistogramState:
    counts: jax.Array          # (d, nbins)
    lo: jax.Array              # (d,)
    hi: jax.Array              # (d,)


class Quantile(Statistic):
    """Mergeable weighted quantile via a fixed-range histogram sketch.

    The bin range must cover the data (set from a pilot scan with margin);
    values are clipped into range.  Accuracy ~ (hi-lo)/nbins per component.
    For in-memory bootstrap on the sample array the exact path
    ``exact(values, weights)`` is available (used when n is small).

    ``update`` accumulates via a flattened scatter-add (O(n·d) memory, one
    dispatch) — the historical one_hot+einsum formulation materialized an
    (n, d, nbins) tensor and is kept only as a test oracle
    (kernels/weighted_hist/ref.py).  ``backend="pallas"`` /
    ``"pallas_interpret"`` routes the histogram through the fused Pallas
    sketch kernel instead (tile-local one-hot in VMEM; use for large
    single-state updates — the scatter path is the one that vmaps over the
    bootstrap's B axis).
    """

    _BACKENDS = (None, "pallas", "pallas_interpret")

    def __init__(self, q: float, nbins: int = 2048,
                 lo: float = 0.0, hi: float = 1.0,
                 backend: Optional[str] = None,
                 block_bins: Optional[int] = None):
        if backend not in self._BACKENDS:
            raise ValueError(f"unknown quantile backend: {backend!r}")
        self.q = float(q)
        self.nbins = int(nbins)
        self.lo = float(lo)
        self.hi = float(hi)
        self.backend = backend
        #: Pallas output-axis tiling for the fused sketch (VMEM escape
        #: hatch when d·nbins — or G·nbins under GroupedStatistic — is too
        #: big to keep resident); a lowering knob, NOT part of the
        #: accumulator identity.
        self.block_bins = None if block_bins is None else int(block_bins)

    def with_range(self, lo: float, hi: float) -> "Quantile":
        """Re-range copy (pilot-scan margin added).  Preserves EVERY
        constructor knob — ``backend``, ``nbins``, ``block_bins`` — so a
        re-ranged quantile keeps its lowering config and two same-range
        re-ranged quantiles still share one accumulator slot in a
        StatisticGroup."""
        span = max(hi - lo, _EPS)
        return Quantile(self.q, self.nbins, lo - 0.01 * span,
                        hi + 0.01 * span, backend=self.backend,
                        block_bins=self.block_bins)

    def init_state(self, dim: int) -> HistogramState:
        return HistogramState(
            counts=jnp.zeros((dim, self.nbins), jnp.float32),
            lo=jnp.full((dim,), self.lo, jnp.float32),
            hi=jnp.full((dim,), self.hi, jnp.float32),
        )

    def update(self, state: HistogramState, values, weights=None):
        x = _as_2d(values).astype(jnp.float32)      # (n, d)
        w = _w(x, weights)                          # (n,)
        if self.backend in ("pallas", "pallas_interpret"):
            from repro.kernels.weighted_hist import ops as wh_ops
            delta = wh_ops.weighted_histogram(x, w, state.lo, state.hi,
                                              self.nbins,
                                              backend=self.backend)
        else:
            from repro.kernels.weighted_hist.ref import \
                weighted_hist_scatter_ref
            delta = weighted_hist_scatter_ref(x, w, state.lo, state.hi,
                                              self.nbins)
        return HistogramState(counts=state.counts + delta,
                              lo=state.lo, hi=state.hi)

    def merge(self, a: HistogramState, b: HistogramState) -> HistogramState:
        return HistogramState(counts=a.counts + b.counts, lo=a.lo, hi=a.hi)

    def psum_state(self, state: HistogramState, axis_names) -> HistogramState:
        """Only the counts are additive; lo/hi are replicated configuration
        (psum'ing them would multiply the bin range by the shard count and
        silently shift every quantile)."""
        return HistogramState(
            counts=jax.lax.psum(state.counts, axis_names),
            lo=state.lo, hi=state.hi)

    def fused_poisson_states(self, seed, values, B, n_valid=None,
                             valid_mask=None):
        """Matrix-free bootstrap sketch: B per-resample histogram states
        from in-kernel Poisson(1) weights (kernels/weighted_hist.
        fused_poisson_hist) — the last built-in statistic fallback is gone;
        Quantile/Median sessions stream through the Pallas sketch end to
        end.  ``backend="pallas"``/``"pallas_interpret"`` on the statistic
        routes the fused kernel too; the default picks the platform auto
        path (scan on CPU)."""
        from repro.kernels.weighted_hist import ops as wh_ops
        backend = self.backend if self.backend in (
            "pallas", "pallas_interpret") else None
        d = values.shape[1]
        counts = wh_ops.fused_poisson_hist(seed, values, self.lo, self.hi,
                                           self.nbins, B, backend=backend,
                                           n_valid=n_valid,
                                           valid_mask=valid_mask,
                                           block_bins=self.block_bins)
        return HistogramState(
            counts=counts,
            lo=jnp.full((B, d), self.lo, jnp.float32),
            hi=jnp.full((B, d), self.hi, jnp.float32))

    def accumulator_key(self):
        # Quantiles over the same bin range share ONE histogram sketch
        # regardless of q (q only enters finalize): a (p25, median, p99)
        # group accumulates a single (B, d, nbins) state.
        return ("hist", self.nbins, self.lo, self.hi)

    def tile_update(self, states: HistogramState, x_tile,
                    w_tile) -> HistogramState:
        """Same tile math as weighted_hist._fused_hist_scan (shared
        ``_bin_indices`` + scatter-add), so group sketches are bit-identical
        to the fused histogram path."""
        from repro.kernels.weighted_hist.ref import (_bin_indices,
                                                     finite_mass_mask)
        x = x_tile.astype(jnp.float32)                  # (bn, d)
        bn, d = x.shape
        B = w_tile.shape[0]
        lo = jnp.full((d,), self.lo, jnp.float32)
        hi = jnp.full((d,), self.hi, jnp.float32)
        idx = _bin_indices(x, lo[None, :], hi[None, :], self.nbins)
        flat = (idx + jnp.arange(d, dtype=jnp.int32)[None, :]
                * self.nbins).reshape(-1)               # (bn·d,)
        wm = (w_tile[:, :, None] * finite_mass_mask(x)[None, :, :]
              ).reshape(B, bn * d)
        counts = states.counts.reshape(B, d * self.nbins)
        counts = counts.at[:, flat].add(wm).reshape(B, d, self.nbins)
        return HistogramState(counts=counts, lo=states.lo, hi=states.hi)

    def finalize(self, state: HistogramState):
        cdf = jnp.cumsum(state.counts, axis=-1)
        total = cdf[..., -1:]
        cdf = cdf / (total + _EPS)
        # first bin where cdf >= q, linear position within range
        ge = cdf >= self.q
        idx = jnp.argmax(ge, axis=-1).astype(jnp.float32)
        centers = state.lo + (idx + 0.5) / self.nbins * (state.hi - state.lo)
        out = centers
        return out[0] if out.shape == (1,) else out

    @staticmethod
    def exact(values: jax.Array, weights: jax.Array, q: float) -> jax.Array:
        """Exact weighted quantile of 1-D values (oracle for tests)."""
        values = jnp.asarray(values).reshape(-1)
        order = jnp.argsort(values)
        v = values[order]
        w = jnp.asarray(weights, jnp.float32).reshape(-1)[order]
        cw = jnp.cumsum(w)
        t = q * cw[-1]
        i = jnp.searchsorted(cw, t)
        return v[jnp.clip(i, 0, v.shape[0] - 1)]


def Median(nbins: int = 2048, lo: float = 0.0, hi: float = 1.0,
           backend: Optional[str] = None,
           block_bins: Optional[int] = None) -> Quantile:
    """q=0.5 Quantile; forwards every constructor knob ``Quantile`` accepts
    (``backend`` was historically dropped here, silently downgrading Pallas
    users to the scatter path)."""
    return Quantile(0.5, nbins=nbins, lo=lo, hi=hi, backend=backend,
                    block_bins=block_bins)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KMeansState:
    sums: jax.Array     # (k, d) weighted point sums per cluster
    counts: jax.Array   # (k,) weighted counts
    inertia: jax.Array  # () weighted within-cluster SSE


class KMeansStep(Statistic):
    """One weighted Lloyd assignment pass against ``centroids``.

    finalize() -> new centroids; the EARL session / examples drive the outer
    Lloyd loop (paper §6.3 runs K-Means over the sample).  The bootstrap
    statistic of record is the (scalar) inertia, exposed via
    ``finalize_inertia`` — centroid c_v is also available via finalize().

    ``centroids`` is a *traced parameter* (``array_params``): the jit entry
    points carry it as an operand rather than a baked-in constant, so Lloyd
    loops that build a fresh ``KMeansStep`` per iteration compile once.

    ``backend`` picks the assignment lowering: None/"jnp" materializes the
    (n, k) distance/one-hot matrices; "scan"/"pallas"/"pallas_interpret"
    route through kernels/kmeans_assign (tiled — no (n, k) intermediate).
    The matrix-free bootstrap hook ``fused_poisson_states`` is implemented
    either way (kernels/kmeans_assign.fused_poisson_kmeans), so
    ``bootstrap(..., backend="fused_rng")`` over a KMeansStep never builds
    the (B, n) weight matrix.
    """

    array_params = ("centroids",)

    _BACKENDS = (None, "jnp", "scan", "pallas", "pallas_interpret")

    def __init__(self, centroids: jax.Array, backend: Optional[str] = None):
        if backend not in self._BACKENDS:
            raise ValueError(f"unknown kmeans backend: {backend!r}")
        self.centroids = jnp.asarray(centroids, jnp.float32)  # (k, d)
        self.backend = backend

    def init_state(self, dim: int) -> KMeansState:
        k, d = self.centroids.shape
        return KMeansState(
            sums=jnp.zeros((k, d), jnp.float32),
            counts=jnp.zeros((k,), jnp.float32),
            inertia=jnp.zeros((), jnp.float32),
        )

    def update(self, state: KMeansState, values, weights=None) -> KMeansState:
        x = _as_2d(values).astype(jnp.float32)               # (n, d)
        w = _w(x, weights)
        if self.backend in ("scan", "pallas", "pallas_interpret"):
            from repro.kernels.kmeans_assign import ops as ka_ops
            sums, counts, inertia = ka_ops.kmeans_assign(
                x, w, self.centroids, backend=self.backend)
            return KMeansState(sums=state.sums + sums,
                               counts=state.counts + counts,
                               inertia=state.inertia + inertia)
        d2 = (jnp.sum(x * x, -1, keepdims=True)
              - 2.0 * x @ self.centroids.T
              + jnp.sum(self.centroids * self.centroids, -1))  # (n, k)
        # f32 cancellation can push the expanded form slightly below zero
        # for points at/near a centroid — clamp so inertia stays >= 0.
        d2 = jnp.maximum(d2, 0.0)
        assign = jax.nn.one_hot(jnp.argmin(d2, -1), self.centroids.shape[0],
                                dtype=jnp.float32)             # (n, k)
        wa = assign * w[:, None]
        return KMeansState(
            sums=state.sums + wa.T @ x,
            counts=state.counts + jnp.sum(wa, 0),
            inertia=state.inertia + jnp.sum(w * jnp.min(d2, -1)),
        )

    def fused_poisson_states(self, seed, values, B, n_valid=None,
                             valid_mask=None):
        from repro.kernels.kmeans_assign import ops as ka_ops
        backend = self.backend if self.backend in (
            "scan", "pallas", "pallas_interpret") else None
        sums, counts, inertia = ka_ops.fused_poisson_kmeans(
            seed, values, self.centroids, B, n_valid=n_valid,
            valid_mask=valid_mask, backend=backend)
        return KMeansState(sums=sums, counts=counts, inertia=inertia)

    def tile_update(self, states: KMeansState, x_tile, w_tile) -> KMeansState:
        """Same tile math as kmeans_assign._fused_kmeans_scan (shared
        ``_assign_tile`` + one (B, bn) @ (bn, k·d) contraction), so a group
        member consumes the shared weight tile without any (n, k) or (B, n)
        intermediate."""
        from repro.kernels.kmeans_assign.kernel import _assign_tile
        x = x_tile.astype(jnp.float32)                  # (bn, d)
        bn, d = x.shape
        k = self.centroids.shape[0]
        B = w_tile.shape[0]
        assign, min_d2 = _assign_tile(x, self.centroids, k)   # (bn, k)
        y = (assign[:, :, None] * x[:, None, :]).reshape(bn, k * d)
        return KMeansState(
            sums=states.sums + (w_tile @ y).reshape(B, k, d),
            counts=states.counts + w_tile @ assign,
            inertia=states.inertia + w_tile @ min_d2,
        )

    def finalize(self, state: KMeansState):
        return state.sums / (state.counts[:, None] + _EPS)

    def finalize_inertia(self, state: KMeansState):
        return state.inertia / (jnp.sum(state.counts) + _EPS)


@partial(jax.jit, static_argnames=("iters", "backend"))
def _kmeans_fit_jit(x, cent0, weights, iters, backend):
    def body(cent, _):
        step = KMeansStep(cent, backend=backend)
        st = step.update(step.init_state(x.shape[1]), x, weights)
        return step.finalize(st), step.finalize_inertia(st)

    cent, inertias = jax.lax.scan(body, cent0, None, length=iters)
    return cent, inertias[-1]


def kmeans_fit(values: jax.Array, k: int, iters: int, key: jax.Array,
               weights: Optional[jax.Array] = None,
               init: Optional[jax.Array] = None,
               backend: Optional[str] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Weighted Lloyd's on in-memory values; returns (centroids, inertia).

    ``init`` (k, d) pins the starting centroids (benchmarks share one init
    across fits); default is k distinct random rows.  The whole Lloyd loop
    is one jitted scan with the centroids as carried state — repeat calls
    with same-shaped inputs reuse one compilation.  ``backend`` is
    forwarded to ``KMeansStep``.
    """
    x = _as_2d(values).astype(jnp.float32)
    if init is None:
        init_idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
        init = x[init_idx]
    elif init.shape[0] != k:
        raise ValueError(f"init has {init.shape[0]} centroids, expected "
                         f"k={k}")
    return _kmeans_fit_jit(x, jnp.asarray(init, jnp.float32), weights,
                           int(iters), backend)


class StatisticGroup(Statistic):
    """A first-class composite Statistic: k member statistics answered from
    ONE shared pass over the sample under ONE shared Poisson(1) resample
    stream (paper §2.1 sessions ask several questions of the same sample;
    BlinkDB's lesson is that the systems win is answering them off one
    shared sample pass).

    State is a tuple of *slot* states — one per distinct
    ``accumulator_key()`` (Mean+Var+Std share one MomentState; same-range
    Quantiles share one HistogramState; KMeansStep/custom statistics get
    their own slot) — and ``merge``/``psum_state`` compose slot-wise, so
    every driver (bootstrap, chunked, sharded, delta, SSABE, sessions)
    composes member-wise for free.  ``finalize``/``correct`` return a tuple
    with one entry per MEMBER (members indexing into shared slots).

    The matrix-free hot path ``fused_poisson_states`` routes through
    ``kernels/fused_multi``: each implicit weight tile is generated ONCE
    (same ``(seed, b-tile, n-tile)`` keying as every fused path, bit-equal
    to ``implicit_weights(seed, B, n)``) and feeds every slot's
    ``tile_update`` in a single pass over x — a k-statistic group pays ~1×
    the RNG and x traffic of a 1-statistic run instead of k×.  Shared
    weights are also a correctness upgrade: every member sees the SAME
    resamples, so joint / compared CIs are consistent rather than
    independently randomized.

    ``backend``: None = auto (Pallas multi-kernel on TPU when every slot is
    a moment/histogram accumulator, scan lowering elsewhere), "scan",
    "pallas", "pallas_interpret" (kernel-eligible groups only).
    """

    _BACKENDS = (None, "scan", "pallas", "pallas_interpret")

    def __init__(self, members, backend: Optional[str] = None):
        members = tuple(members)
        if not members:
            raise ValueError("StatisticGroup needs at least one member")
        for m in members:
            if isinstance(m, StatisticGroup):
                raise TypeError("StatisticGroup members cannot be groups "
                                "themselves — flatten the member list")
            if not isinstance(m, Statistic):
                raise TypeError(f"group member {m!r} is not a Statistic")
        if backend not in self._BACKENDS:
            raise ValueError(f"unknown group backend: {backend!r}")
        self.members = members
        self.backend = backend
        self.mergeable = all(m.mergeable for m in members)
        slots, keys, member_slot = [], {}, []
        for m in members:
            k = m.accumulator_key()
            if k is None:
                member_slot.append(len(slots))
                slots.append(m)
            elif k in keys:
                member_slot.append(keys[k])
            else:
                keys[k] = len(slots)
                member_slot.append(len(slots))
                slots.append(m)
        #: one representative Statistic per shared accumulator
        self.slots = tuple(slots)
        #: member i finalizes slot state ``self.member_slot[i]``
        self.member_slot = tuple(member_slot)

    def with_members(self, members) -> "StatisticGroup":
        """Rebuild the group around new member instances (same length) —
        used by split_params/bind_params to thread traced array params."""
        return StatisticGroup(members, backend=self.backend)

    # -- reducer protocol: slot-wise states, member-wise results ----------
    def init_state(self, dim: int) -> Tuple:
        return tuple(s.init_state(dim) for s in self.slots)

    def update(self, state, values, weights=None):
        return tuple(s.update(st, values, weights)
                     for s, st in zip(self.slots, state))

    def merge(self, a, b):
        return tuple(s.merge(ai, bi)
                     for s, ai, bi in zip(self.slots, a, b))

    def psum_state(self, state, axis_names):
        return tuple(s.psum_state(st, axis_names)
                     for s, st in zip(self.slots, state))

    def tile_update(self, states, x_tile, w_tile):
        """The group IS the shared-tile consumer: one weight tile in, every
        slot advanced — also what makes groups nest inside the chunked /
        sharded scan bodies unchanged."""
        return tuple(s.tile_update(st, x_tile, w_tile)
                     for s, st in zip(self.slots, states))

    def finalize(self, state) -> Tuple:
        return tuple(m.finalize(state[slot])
                     for m, slot in zip(self.members, self.member_slot))

    def correct(self, result, p: float) -> Tuple:
        return tuple(m.correct(r, p) for m, r in zip(self.members, result))

    def fused_poisson_states(self, seed, values, B, n_valid=None,
                             valid_mask=None):
        from repro.kernels.fused_multi import ops as fm_ops
        return fm_ops.fused_poisson_multi(self, seed, values, B,
                                          n_valid=n_valid,
                                          valid_mask=valid_mask,
                                          backend=self.backend)


def _tree_take(state, g, axis: int):
    """Slice index ``g`` off ``axis`` of every leaf (one key's view of a
    G-keyed state)."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.index_in_dim(a, g, axis, keepdims=False), state)


def _tree_stack(states, axis: int):
    """Inverse of ``_tree_take``: stack per-key states into a G axis."""
    return jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls, axis=axis), *states)


class GroupedStatistic(Statistic):
    """GROUP BY for the bootstrap: the inner statistic computed per key, in
    one pass, under ONE shared Poisson(1) resample stream.

    The key is the LAST column of ``values`` — small nonnegative integers
    ``0..num_groups-1`` stored as floats (exact below 2^24); the remaining
    columns are the inner statistic's data.  State is the inner state with
    a leading ``(G, ...)`` key axis on every leaf (MomentState → (G,·)
    moments, HistogramState → (G, d, nbins) counts, KMeansState likewise);
    ``merge``/``psum_state`` delegate leaf-wise to the inner statistic
    (both are shape-agnostic for every built-in), so keyed states stay
    mergeable and mesh psum composes per-key for free.

    The contract that makes per-key CIs trustworthy: under
    ``backend="fused_rng"`` each implicit weight tile is drawn ONCE (the
    same ``(seed, b-tile, n-tile)`` threefry discipline as every fused
    path) and routed into each key's accumulator by an exact 0/1 key mask
    multiply — so key g's thetas are BITWISE equal to running the inner
    statistic alone with ``valid_mask = (key == g)``, i.e. on that key's
    rows only, under the same seed.  Common random numbers across keys
    mean cross-key comparisons are consistent, the same argument that
    makes ``StatisticGroup`` members jointly comparable.

    ``finalize``/``correct`` return the inner result with a leading G axis
    (so bootstrap thetas are (B, G, ...)); drivers detect ``num_groups``
    and build a ``KeyedAccuracyReport`` — per-key AccuracyReports with the
    worst key gating the session's sigma stop.

    ``backend``: None = auto (grouped Pallas kernel on TPU for moment
    inners, grouped scan elsewhere), "scan", "pallas", "pallas_interpret"
    (moment inners only — the grouped histogram / k-means lowerings are
    scan-based; see ROADMAP's support matrix).
    """

    _BACKENDS = (None, "scan", "pallas", "pallas_interpret")

    def __init__(self, inner: Statistic, num_groups: int,
                 backend: Optional[str] = None):
        if isinstance(inner, GroupedStatistic):
            raise TypeError("GroupedStatistic cannot nest another "
                            "GroupedStatistic — use a single key column "
                            "with the product of the key spaces")
        if isinstance(inner, StatisticGroup):
            raise TypeError("GroupedStatistic over a StatisticGroup is not "
                            "supported — group the keyed statistics "
                            "instead: StatisticGroup([GroupedStatistic(m, "
                            "G) for m in members])")
        if not isinstance(inner, Statistic):
            raise TypeError(f"inner statistic {inner!r} is not a Statistic")
        if backend not in self._BACKENDS:
            raise ValueError(f"unknown grouped backend: {backend!r}")
        num_groups = int(num_groups)
        if num_groups < 1:
            raise ValueError(f"num_groups must be >= 1, got {num_groups}")
        self.inner = inner
        self.num_groups = num_groups
        self.backend = backend
        self.mergeable = bool(inner.mergeable)

    def with_inner(self, inner: Statistic) -> "GroupedStatistic":
        """Rebuild around a new inner instance — used by
        split_params/bind_params to thread traced array params (KMeansStep
        centroids) through the keyed wrapper."""
        return GroupedStatistic(inner, self.num_groups, backend=self.backend)

    @staticmethod
    def _split_key(values: jax.Array) -> Tuple[jax.Array, jax.Array]:
        x = _as_2d(values)
        if x.shape[1] < 2:
            raise ValueError("GroupedStatistic needs at least 2 columns: "
                             "data columns plus the key as the LAST column")
        return x[:, :-1], x[:, -1]

    # -- reducer protocol: every leaf gains a leading G axis --------------
    def init_state(self, dim: int) -> State:
        # ``dim`` counts the key column (drivers pass values.shape[1]);
        # the inner statistic sees one fewer.
        inner = self.inner
        return jax.vmap(lambda _: inner.init_state(dim - 1))(
            jnp.arange(self.num_groups))

    def update(self, state, values, weights=None):
        x, gid = self._split_key(values)
        w = _w(x, weights)
        # static per-key loop running the inner's EXACT update on
        # key-masked weights — identical ops on identical values as
        # updating each key alone (0/1 mask multiplies are exact).
        outs = [self.inner.update(_tree_take(state, g, 0), x,
                                  w * (gid == g).astype(jnp.float32))
                for g in range(self.num_groups)]
        return _tree_stack(outs, 0)

    def merge(self, a, b):
        return self.inner.merge(a, b)

    def psum_state(self, state, axis_names):
        return self.inner.psum_state(state, axis_names)

    def finalize(self, state) -> Result:
        outs = [self.inner.finalize(_tree_take(state, g, 0))
                for g in range(self.num_groups)]
        return _tree_stack(outs, 0)

    def correct(self, result, p: float) -> Result:
        return self.inner.correct(result, p)

    def correct_per_key(self, result, p_keys, key_axis: int = 0) -> Result:
        """Per-key sampling correction: key ``g``'s slice corrected by its
        OWN sampled fraction ``p_keys[g]`` instead of the whole-table p.

        Under stratified sampling the keys are drawn at different rates
        (shares ∝ requested allocation, not population frequency), so a
        scalar ``correct(p)`` systematically mis-scales count-like inners
        (Sum, Count) for every key whose stratum fraction differs from the
        table fraction.  ``key_axis`` names the G axis of ``result`` — 0
        for a finalized estimate ``(G, ...)``, 1 for bootstrap thetas
        ``(B, G, ...)``.  A key with ``p_keys[g] == 0`` was never sampled;
        its (all-zero) result is passed through uncorrected rather than
        divided to NaN.
        """
        if len(p_keys) != self.num_groups:
            raise ValueError(f"p_keys has {len(p_keys)} entries for "
                             f"{self.num_groups} keys")
        outs = []
        for g in range(self.num_groups):
            pg = float(p_keys[g])
            outs.append(self.inner.correct(
                _tree_take(result, g, key_axis), pg if pg > 0.0 else 1.0))
        return _tree_stack(outs, key_axis)

    def accumulator_key(self):
        return None

    def tile_update(self, states, x_tile, w_tile):
        """Grouped segment-reduction of one shared weight tile: the key
        column is split off ``x_tile`` and each key's slot advances by the
        inner statistic's EXACT tile math under ``w_tile * (key == g)`` —
        masks are exact 0/1 so ``(w·valid)·keymask ≡ w·(valid·keymask)``
        bit for bit, which is what keeps every grouped fused path bitwise
        equal to the per-key oracle.  ``states`` leaves are (B, G, ...)."""
        x = x_tile[:, :-1]
        gid = x_tile[:, -1]
        outs = []
        for g in range(self.num_groups):
            m = (gid == g).astype(jnp.float32)
            outs.append(self.inner.tile_update(
                _tree_take(states, g, 1), x, w_tile * m[None, :]))
        return _tree_stack(outs, 1)

    def fused_poisson_states(self, seed, values, B, n_valid=None,
                             valid_mask=None):
        """Matrix-free keyed bootstrap: ONE implicit Poisson(1) stream,
        segment-reduced per key inside the kernels — no (B, n) weight
        matrix and no (n, G) one-hot ever materializes.  Dispatches to the
        grouped weighted_stats / weighted_hist / kmeans_assign lowerings
        for built-in inners; custom inners run the generic grouped tile
        scan (kernels/fused_multi)."""
        x, gid = self._split_key(values)
        G = self.num_groups
        inner = self.inner
        if isinstance(inner, _MomentStatistic):
            from repro.kernels.weighted_stats import ops as ws_ops
            w_tot, s1, s2 = ws_ops.fused_poisson_moments(
                seed, x, B, backend=self.backend, n_valid=n_valid,
                valid_mask=valid_mask, group_ids=gid, num_groups=G)
            return jax.vmap(jax.vmap(inner.from_moments))(w_tot, s1, s2)
        if isinstance(inner, Quantile):
            from repro.kernels.weighted_hist import ops as wh_ops
            counts = wh_ops.fused_poisson_hist(
                seed, x, inner.lo, inner.hi, inner.nbins, B,
                backend=self.backend, n_valid=n_valid,
                valid_mask=valid_mask, group_ids=gid, num_groups=G)
            d = x.shape[1]
            return HistogramState(
                counts=counts,
                lo=jnp.full((B, G, d), inner.lo, jnp.float32),
                hi=jnp.full((B, G, d), inner.hi, jnp.float32))
        if isinstance(inner, KMeansStep):
            from repro.kernels.kmeans_assign import ops as ka_ops
            sums, counts, inertia = ka_ops.fused_poisson_kmeans(
                seed, x, inner.centroids, B, backend=self.backend,
                n_valid=n_valid, valid_mask=valid_mask, group_ids=gid,
                num_groups=G)
            return KMeansState(sums=sums, counts=counts, inertia=inertia)
        # custom inner: generic grouped tile scan over the shared stream
        # (GroupedStatistic.tile_update does the key segmentation).
        from repro.kernels.fused_multi import ops as fm_ops
        return fm_ops.fused_poisson_tiled(self, seed, values, B,
                                          n_valid=n_valid,
                                          valid_mask=valid_mask)


class Window:
    """A windowed view of a mergeable statistic over a live row stream.

    Rows are partitioned into fixed-width *panes* of ``slide`` rows; pane
    ``p`` covers global rows ``[p*slide, (p+1)*slide)``.  A window of
    ``size`` rows is always a whole number of panes (``size % slide ==
    0``), so a live session can keep ONE mergeable sub-state per pane in a
    ring and answer any window by re-merging the ``size // slide`` newest
    panes — eviction is dropping a pane and re-merging the survivors,
    never subtraction (which no fused state supports and which would be
    numerically unsound anyway) and never re-reading the log.  Device
    memory is O(panes · state), independent of stream length.

    The wrapped statistic must be ``mergeable`` (Quantile/Median qualify —
    histogram counts add; KMeansStep sums/counts add; StatisticGroup /
    GroupedStatistic inherit from their members).
    """

    def __init__(self, stat: Statistic, size: int, slide: int):
        if not isinstance(stat, Statistic):
            raise TypeError(f"{stat!r} is not a Statistic")
        if not getattr(stat, "mergeable", False):
            raise ValueError(
                f"{type(stat).__name__} is not mergeable; windowed folding "
                f"re-merges per-pane states and needs an associative merge")
        size, slide = int(size), int(slide)
        if slide < 1:
            raise ValueError(f"slide must be >= 1, got {slide}")
        if size < slide:
            raise ValueError(f"size ({size}) must be >= slide ({slide})")
        if size % slide != 0:
            raise ValueError(f"size ({size}) must be a multiple of the "
                             f"slide ({slide}) so a window is a whole "
                             f"number of panes")
        self.stat = stat
        self.size = size
        self.slide = slide

    @property
    def panes(self) -> int:
        """Panes per window — the ring's steady-state occupancy bound."""
        return self.size // self.slide

    def pane_of(self, row: int) -> int:
        return int(row) // self.slide

    def pane_rows(self, pane: int) -> Tuple[int, int]:
        return pane * self.slide, (pane + 1) * self.slide

    def _static_key(self):
        return (type(self).__name__, self.size, self.slide,
                self.stat._static_key())

    def __repr__(self):
        return (f"{type(self).__name__}({self.stat!r}, size={self.size}, "
                f"slide={self.slide})")


class TumblingWindow(Window):
    """Non-overlapping windows: one pane per window, reset every ``size``
    rows.  ``TumblingWindow(stat, s)`` ≡ ``SlidingWindow(stat, s, s)``."""

    def __init__(self, stat: Statistic, size: int):
        super().__init__(stat, size, size)


class SlidingWindow(Window):
    """Overlapping windows of ``size`` rows advancing by ``slide`` rows;
    the ring holds ``size // slide`` panes and a window report re-merges
    them."""

    def __init__(self, stat: Statistic, size: int, slide: int):
        super().__init__(stat, size, slide)


class MeanLoss(Mean):
    """Alias used by train/earl_eval: the statistic is the per-example loss."""
