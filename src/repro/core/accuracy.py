"""Accuracy measures for early-result estimation (paper §3).

The paper's headline error measure is the coefficient of variation
``c_v = std / |mean|`` computed over the bootstrap result distribution.
The machinery is measure-agnostic (paper: "Our approach is independent of
the error measure"), so we also expose variance, standard error, relative
CI half-width and percentile CIs over the same distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _flatten_thetas(thetas: jax.Array) -> jax.Array:
    """(B, ...) -> (B, K) flat view of the bootstrap result distribution."""
    thetas = jnp.asarray(thetas)
    if thetas.ndim == 1:
        return thetas[:, None]
    return thetas.reshape(thetas.shape[0], -1)


def coefficient_of_variation(thetas: jax.Array) -> jax.Array:
    """c_v of a bootstrap result distribution ``thetas`` with leading axis B.

    Scalar statistics: classic std/|mean|.  Vector statistics (e.g. k-means
    centroids): scale-invariant aggregate  sqrt(mean_k var_k) / rms_k(mean_k),
    which reduces to the scalar definition for K=1.
    """
    t = _flatten_thetas(thetas)
    mean = jnp.mean(t, axis=0)
    var = jnp.var(t, axis=0, ddof=1) if t.shape[0] > 1 else jnp.zeros_like(mean)
    num = jnp.sqrt(jnp.mean(var))
    den = jnp.sqrt(jnp.mean(mean * mean))
    return num / (den + _EPS)


def standard_error(thetas: jax.Array) -> jax.Array:
    t = _flatten_thetas(thetas)
    if t.shape[0] <= 1:
        return jnp.zeros(())
    return jnp.sqrt(jnp.mean(jnp.var(t, axis=0, ddof=1)))


def relative_halfwidth(thetas: jax.Array, z: float = 1.96) -> jax.Array:
    """z·SE / |mean| — the relative CI half-width at confidence z."""
    t = _flatten_thetas(thetas)
    mean = jnp.sqrt(jnp.mean(jnp.mean(t, axis=0) ** 2))
    return z * standard_error(thetas) / (mean + _EPS)


def percentile_ci(thetas: jax.Array, alpha: float = 0.05
                  ) -> Tuple[jax.Array, jax.Array]:
    """Efron percentile bootstrap CI (per flattened component)."""
    t = _flatten_thetas(thetas)
    lo = jnp.percentile(t, 100.0 * (alpha / 2.0), axis=0)
    hi = jnp.percentile(t, 100.0 * (1.0 - alpha / 2.0), axis=0)
    return lo, hi


@dataclasses.dataclass(frozen=True)
class AccuracyReport:
    """Everything the AES stage (paper §3.1) derives from one bootstrap run."""
    cv: float
    se: float
    rel_halfwidth: float
    ci_lo: jax.Array
    ci_hi: jax.Array
    boot_mean: jax.Array

    @staticmethod
    def from_thetas(thetas: jax.Array, alpha: float = 0.05) -> "AccuracyReport":
        lo, hi = percentile_ci(thetas, alpha)
        return AccuracyReport(
            cv=float(coefficient_of_variation(thetas)),
            se=float(standard_error(thetas)),
            rel_halfwidth=float(relative_halfwidth(thetas)),
            ci_lo=lo,
            ci_hi=hi,
            boot_mean=jnp.mean(_flatten_thetas(thetas), axis=0),
        )


@dataclasses.dataclass(frozen=True)
class GroupAccuracyReport:
    """Per-member AccuracyReports for a ``StatisticGroup`` bootstrap run.

    The scalar gates (cv / se / rel_halfwidth) expose the WORST member, so
    every driver's existing ``report.cv <= sigma`` stop condition reads
    "stop when ALL members meet the target" without changing a line; the
    per-member reports stay available on ``members``.  Because the group
    shares one Poisson weight stream, the member CIs here are JOINT —
    computed from the same resamples, so comparisons across members are
    consistent rather than independently randomized."""
    members: Tuple["AccuracyReport", ...]

    @property
    def cv(self) -> float:
        return max(m.cv for m in self.members)

    @property
    def se(self) -> float:
        return max(m.se for m in self.members)

    @property
    def rel_halfwidth(self) -> float:
        return max(m.rel_halfwidth for m in self.members)

    @property
    def ci_lo(self):
        return tuple(m.ci_lo for m in self.members)

    @property
    def ci_hi(self):
        return tuple(m.ci_hi for m in self.members)

    @property
    def boot_mean(self):
        return tuple(m.boot_mean for m in self.members)

    @property
    def cvs(self) -> Tuple[float, ...]:
        return tuple(m.cv for m in self.members)


@dataclasses.dataclass(frozen=True)
class KeyedAccuracyReport(GroupAccuracyReport):
    """Per-KEY AccuracyReports for a ``GroupedStatistic`` bootstrap run
    (one entry per group key, in key order 0..G-1).

    Inherits the worst-member scalar gates from ``GroupAccuracyReport`` —
    here worst-KEY: ``report.cv <= sigma`` reads "stop when EVERY key
    meets the target", which is the BlinkDB-style per-key guarantee (a
    rare key's wide CI cannot hide behind a heavy hitter's tight one).
    All keys share one Poisson weight stream (common random numbers), so
    cross-key comparisons of these CIs are consistent.

    ``p_keys`` (when the driver ran under stratified sampling) records the
    PER-KEY sampled fractions the thetas were corrected with — key g's
    reports reflect ``inner.correct(·, p_keys[g])`` rather than one
    whole-table p, so a rare stratum's Sum/Count is scaled by its own
    inclusion probability (see ``GroupedStatistic.correct_per_key``)."""
    p_keys: "Tuple[float, ...] | None" = None

    @property
    def worst_key(self) -> int:
        """The key whose cv gates the stop — where more rows are needed."""
        cvs = self.cvs
        return max(range(len(cvs)), key=lambda g: cvs[g])


def report_for(thetas, alpha: float = 0.05, num_groups=None, p_keys=None):
    """AccuracyReport for a (B, ...) theta array, a GroupAccuracyReport
    for the tuple of per-member thetas a StatisticGroup produces, or — when
    ``num_groups`` is set (drivers read it off ``stat.num_groups`` for a
    GroupedStatistic) — a KeyedAccuracyReport splitting the (B, G, ...)
    thetas into per-key reports along axis 1.  ``p_keys`` is carried onto
    the keyed report for introspection (the thetas must already be
    per-key corrected)."""
    if isinstance(thetas, (tuple, list)):
        return GroupAccuracyReport(tuple(
            AccuracyReport.from_thetas(t, alpha) for t in thetas))
    if num_groups is not None:
        thetas = jnp.asarray(thetas)
        return KeyedAccuracyReport(tuple(
            AccuracyReport.from_thetas(thetas[:, g], alpha)
            for g in range(int(num_groups))),
            p_keys=None if p_keys is None
            else tuple(float(p) for p in p_keys))
    return AccuracyReport.from_thetas(thetas, alpha)


def theoretical_num_bootstraps(eps0: float) -> int:
    """Paper §3: theory suggests B = 0.5 * eps0^-2 [Efron '87]."""
    return int(round(0.5 * eps0 ** (-2)))


def theoretical_sample_size(sigma: float, pilot_std: float, pilot_mean: float
                            ) -> int:
    """CLT-based n for the *mean*: c_v(mean over n) = (s/|mu|)/sqrt(n) <= sigma.

    Used as the 'theoretical prediction' line in benchmarks/fig8 — the paper
    shows SSABE's empirical estimate beats this in both directions.
    """
    rel = pilot_std / (abs(pilot_mean) + _EPS)
    return max(1, int(jnp.ceil((rel / sigma) ** 2)))
