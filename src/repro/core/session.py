"""EarlSession — the end-to-end early-accurate-result driver (paper Fig. 1).

Pipeline: pilot sample → SSABE (B̂, n̂) → main job on n̂ with B̂ resamples →
AES check c_v ≤ σ → if not, expand the sample (Δs, delta-maintained) and
repeat → correct() the final result with p = n/N.

Fallback (paper §3.1): if SSABE predicts B·n ≥ N, early estimation cannot
beat the exact job — run the statistic over the full data set instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ssabe as ssabe_mod
from repro.core.bootstrap import BootstrapResult, seed_from_key
from repro.core.delta import (PoissonDelta, poisson_delta_extend,
                              poisson_delta_init, poisson_delta_result)
from repro.core.reduce_api import Statistic, _as_2d, split_params
from repro.core.streaming import run_fingerprint


@dataclasses.dataclass
class EarlyResult:
    result: Any                 # corrected estimate (tuple for groups)
    cv: float                   # achieved error (worst member for groups)
    ci_lo: Any
    ci_hi: Any
    n_used: int
    N: int
    fraction: float             # p = n/N
    B: int
    iterations: int
    fell_back: bool             # True => exact full-data computation
    history: List[dict]
    wall_time_s: float
    ssabe: Optional[ssabe_mod.SSABEResult]
    #: StatisticGroup runs: one AccuracyReport per member, all derived from
    #: the SAME shared resamples (joint CIs); None otherwise / on fallback.
    reports: Optional[tuple] = None


class EarlSession:
    """Drives early approximation of ``stat`` over a Sampler.

    ``sampler`` must provide:
      - ``N``: total population size
      - ``take(start, stop) -> array``: rows [start, stop) of a fixed uniform
        random permutation of the population (so prefixes are uniform
        without-replacement samples and expansion is a prefix-extend).
    """

    def __init__(self, sampler, stat: Statistic, sigma: float = 0.05,
                 tau: float = 0.01, p_pilot: float = 0.01,
                 growth: float = 2.0, max_fraction: float = 1.0,
                 min_pilot: int = 64, max_pilot: int = 8192, l: int = 5,
                 backend: Optional[str] = None, mesh=None,
                 data_axis: str = "data", checkpoint=None,
                 checkpoint_every: int = 1):
        self.sampler = sampler
        self.stat = stat
        self.sigma = float(sigma)
        self.tau = float(tau)
        self.p_pilot = float(p_pilot)
        self.growth = float(growth)
        self.max_fraction = float(max_fraction)
        self.min_pilot = int(min_pilot)
        #: None = materialized jnp weights; "fused_rng" = matrix-free
        #: in-kernel RNG for SSABE and the delta-maintained main loop.
        #: ``mesh`` (fused backend only) shards SSABE and every delta
        #: extension over ``data_axis``: per-shard in-kernel weight streams,
        #: psum'd states, no weight traffic (paper's distributed resampling).
        self.backend = backend
        self.mesh = mesh
        self.data_axis = data_axis
        if mesh is not None and backend != "fused_rng":
            raise ValueError("mesh= requires backend='fused_rng'")
        # the pilot only needs to be large enough for a stable c_v(n) fit
        # (paper §3.2: "the initial n is picked to be small ... estimation
        # can be performed on a single machine"); capping it keeps the
        # local-mode phase O(1) as N grows.
        self.max_pilot = int(max_pilot)
        self.l = int(l)
        #: ``checkpoint`` (a CheckpointManager or a root path) snapshots
        #: the delta-maintained carry after every ``checkpoint_every``-th
        #: expansion round; ``run(key, resume=True)`` restores the latest
        #: snapshot and continues — since the loop's only RNG lives in the
        #: PoissonDelta (base key + per-extend step counter) and
        #: ``sampler.take`` is a fixed permutation, the resumed run is
        #: bitwise equal to the uninterrupted one.
        self.checkpoint = checkpoint
        self.checkpoint_every = int(checkpoint_every)
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")

    # ------------------------------------------------------------------ #
    def _p_keys(self, n_have: int) -> Optional[np.ndarray]:
        """Per-key sampled fractions when the sampler stratifies a keyed
        statistic; None otherwise (scalar whole-table p applies).

        A ``StratifiedSampler`` prefix is uniform WITHIN each key but
        deliberately non-uniform ACROSS keys, so the whole-table p = n/N
        describes no single key — every correction must use that key's own
        ``stratum_counts(n) / stratum_sizes``."""
        if getattr(self.stat, "num_groups", None) is None:
            return None
        counts = getattr(self.sampler, "stratum_counts", None)
        sizes = getattr(self.sampler, "stratum_sizes", None)
        if counts is None or sizes is None:
            return None
        have = np.asarray(counts(n_have), dtype=np.float64)
        total = np.asarray(sizes, dtype=np.float64)
        return have / np.maximum(total, 1.0)

    # ------------------------------------------------------------------ #
    def _full_job(self, t0: float, history) -> EarlyResult:
        N = self.sampler.N
        values = self.sampler.take(0, N)
        res = self.stat(values)
        # groups always get per-member reports, even on the exact-job
        # fallback (degenerate: cv 0, CI collapsed onto the exact answer),
        # so consumers can iterate EarlyResult.reports unconditionally.
        reports = None
        if isinstance(res, tuple):
            from repro.core.accuracy import AccuracyReport
            reports = tuple(
                AccuracyReport(cv=0.0, se=0.0, rel_halfwidth=0.0,
                               ci_lo=r, ci_hi=r, boot_mean=r)
                for r in res)
        elif getattr(self.stat, "num_groups", None) is not None:
            # keyed runs get the same guarantee per KEY: a GroupedStatistic
            # result is a (G, ...) array, one degenerate report per key.
            from repro.core.accuracy import AccuracyReport
            reports = tuple(
                AccuracyReport(cv=0.0, se=0.0, rel_halfwidth=0.0,
                               ci_lo=res[g], ci_hi=res[g], boot_mean=res[g])
                for g in range(int(self.stat.num_groups)))
        return EarlyResult(
            result=res, cv=0.0, ci_lo=res, ci_hi=res, n_used=N, N=N,
            fraction=1.0, B=1, iterations=len(history), fell_back=True,
            history=history, wall_time_s=time.perf_counter() - t0,
            ssabe=None, reports=reports)

    def run(self, key: jax.Array, resume: bool = False) -> EarlyResult:
        t0 = time.perf_counter()
        N = self.sampler.N
        history: List[dict] = []

        mgr = self.checkpoint
        if isinstance(mgr, str):
            from repro.checkpoint.manager import CheckpointManager
            mgr = CheckpointManager(mgr, async_save=True)
        if resume and mgr is None:
            raise ValueError("resume=True needs checkpoint= (where would "
                             "the cursor come from?)")

        # ---- pilot + SSABE (local mode) --------------------------------
        n_pilot = min(N, self.max_pilot,
                      max(self.min_pilot, int(self.p_pilot * N)))
        pilot = self.sampler.take(0, n_pilot)
        est = ssabe_mod.ssabe(pilot, self.stat, self.sigma, self.tau,
                              jax.random.fold_in(key, 1), l=self.l, N=N,
                              backend=self.backend, mesh=self.mesh,
                              data_axis=self.data_axis)
        B, n_target = est.B, max(est.n, n_pilot)

        # ---- fallback check (paper §3.1) -------------------------------
        if B * n_target >= N or n_target >= self.max_fraction * N:
            return self._full_job(t0, history)

        # ---- main loop with delta-maintained resamples ------------------
        dim = _as_2d(pilot).shape[1]
        pd = poisson_delta_init(self.stat, B, dim,
                                jax.random.fold_in(key, 2),
                                backend=self.backend, mesh=self.mesh,
                                data_axis=self.data_axis)
        spec, params = split_params(self.stat)
        fp = run_fingerprint(spec, params, int(B),
                             int(seed_from_key(pd.key)), N, dim)
        n_have = 0
        iterations = 0
        if resume:
            # pilot + SSABE were just recomputed deterministically from the
            # same key, so B/n_target/est match the original run; only the
            # delta-maintained carry and the cursor come from disk.
            cur = mgr.meta().get("cursor")
            if cur is None or cur.get("kind") != "session":
                raise ValueError(
                    f"checkpoint under {mgr.root} has no EarlSession "
                    "cursor — not an EarlSession checkpoint")
            if cur["fingerprint"] != fp:
                raise ValueError(
                    "checkpoint fingerprint mismatch: the snapshot was "
                    "taken under a different (statistic, B, key, sampler) "
                    "— resuming it would silently produce a different "
                    f"estimator (checkpoint {cur['fingerprint'][:12]}…, "
                    f"run {fp[:12]}…)")
            template = jax.eval_shape(lambda: (pd.states, pd.est_state))
            (states, est_state), _ = mgr.restore(template)
            pd = dataclasses.replace(pd, states=states, est_state=est_state,
                                     n=int(cur["n_have"]),
                                     step=int(cur["step"]))
            n_have = int(cur["n_have"])
            iterations = int(cur["iterations"])
            n_target = int(cur["n_target_next"])
            history = [dict(e, member_cvs=tuple(e["member_cvs"]))
                       if "member_cvs" in e else dict(e)
                       for e in cur["history"]]
            # the snapshot may already satisfy the gate (the run was killed
            # between the save and the return): re-derive the result from
            # the restored carry and re-check before extending further.
            p = n_have / N
            res = poisson_delta_result(pd, p=p, p_keys=self._p_keys(n_have))
            if res.cv <= self.sigma or n_have >= self.max_fraction * N:
                return EarlyResult(
                    result=res.estimate, cv=res.cv,
                    ci_lo=res.report.ci_lo, ci_hi=res.report.ci_hi,
                    n_used=n_have, N=N, fraction=p, B=B,
                    iterations=iterations, fell_back=False,
                    history=history,
                    wall_time_s=time.perf_counter() - t0, ssabe=est,
                    reports=getattr(res.report, "members", None))
        while True:
            iterations += 1
            n_goal = min(int(n_target), N)
            delta = self.sampler.take(n_have, n_goal)
            pd = poisson_delta_extend(pd, delta)
            n_have = n_goal
            p = n_have / N
            # the point estimate is delta-maintained in pd.est_state (each
            # extend folds Δs in, O(Δn)); recomputing stat(take(0, n_have))
            # here would re-read the whole prefix every round, O(n).
            res: BootstrapResult = poisson_delta_result(
                pd, p=p, p_keys=self._p_keys(n_have))
            # for a StatisticGroup, res.cv is the WORST member's c_v
            # (GroupAccuracyReport), so the sigma gate below stops only
            # when ALL members meet the target; the per-member trace is
            # recorded so sessions can see who the straggler was.
            entry = dict(iteration=iterations, n=n_have, B=int(B),
                         cv=float(res.cv), t=time.perf_counter() - t0)
            member_reports = getattr(res.report, "members", None)
            if member_reports is not None:
                entry["member_cvs"] = tuple(float(r.cv)
                                            for r in member_reports)
            history.append(entry)
            if mgr is not None and iterations % self.checkpoint_every == 0:
                # the cursor rides meta.json, so history must be JSON-plain
                mgr.save(iterations, (pd.states, pd.est_state),
                         extra={"cursor": dict(
                             kind="session", fingerprint=fp,
                             n_have=int(n_have), step=int(pd.step),
                             iterations=int(iterations),
                             n_target_next=int(min(
                                 N, int(n_have * self.growth))),
                             history=[
                                 {**e, "member_cvs": list(e["member_cvs"])}
                                 if "member_cvs" in e else e
                                 for e in history])})
            if res.cv <= self.sigma or n_have >= self.max_fraction * N:
                if mgr is not None:
                    mgr.wait()          # durable before we report success
                return EarlyResult(
                    result=res.estimate, cv=res.cv,
                    ci_lo=res.report.ci_lo, ci_hi=res.report.ci_hi,
                    n_used=n_have, N=N, fraction=p, B=B,
                    iterations=iterations, fell_back=False,
                    history=history,
                    wall_time_s=time.perf_counter() - t0, ssabe=est,
                    reports=member_reports)
            if n_have >= N:
                if mgr is not None:
                    mgr.wait()
                return self._full_job(t0, history)
            n_target = min(N, int(n_have * self.growth))
