"""EARL core — the paper's primary contribution in JAX.

Early Accurate Result Library (Laptev, Zeng, Zaniolo; PVLDB 2012):
bootstrap-based online accuracy estimation over incrementally grown uniform
samples, with SSABE parameter estimation and delta-maintained resampling.
See DESIGN.md for the Hadoop→TPU adaptation map.
"""
from repro.core.accuracy import (AccuracyReport, GroupAccuracyReport,
                                 KeyedAccuracyReport,
                                 coefficient_of_variation, percentile_ci,
                                 relative_halfwidth, report_for,
                                 standard_error,
                                 theoretical_num_bootstraps,
                                 theoretical_sample_size)
from repro.core.bootstrap import (BootstrapResult, bootstrap,
                                  bootstrap_chunked, bootstrap_thetas,
                                  multinomial_counts, poisson_weights,
                                  sharded_fused_states, weights_for)
from repro.core.delta import (MultinomialDeltaBootstrap, PoissonDelta,
                              Sketch, optimal_y, p_shared,
                              poisson_delta_extend, poisson_delta_init,
                              poisson_delta_result, shared_base_bootstrap,
                              work_saved)
from repro.core.distributed import (DistributedEarl, build_bootstrap_step,
                                    shard_values)
from repro.core.reduce_api import (Count, GroupedStatistic, KMeansState,
                                   KMeansStep, Mean, MeanLoss, Median,
                                   MomentState, Quantile, SlidingWindow,
                                   Statistic, StatisticGroup, Std, Sum,
                                   TumblingWindow, Var, Window, kmeans_fit)
from repro.core.session import EarlSession, EarlyResult
from repro.core.ssabe import SSABEResult, ssabe
from repro.core.streaming import (StreamingBootstrapResult, StreamReport,
                                  bootstrap_streaming)

__all__ = [
    "AccuracyReport", "GroupAccuracyReport", "KeyedAccuracyReport",
    "coefficient_of_variation",
    "percentile_ci", "relative_halfwidth", "report_for", "standard_error",
    "theoretical_num_bootstraps", "theoretical_sample_size",
    "BootstrapResult", "bootstrap", "bootstrap_chunked", "bootstrap_thetas",
    "multinomial_counts", "poisson_weights", "sharded_fused_states",
    "weights_for",
    "MultinomialDeltaBootstrap", "PoissonDelta", "Sketch", "optimal_y",
    "p_shared", "poisson_delta_extend", "poisson_delta_init",
    "poisson_delta_result", "shared_base_bootstrap", "work_saved",
    "DistributedEarl", "build_bootstrap_step", "shard_values",
    "Count", "GroupedStatistic", "KMeansState", "KMeansStep", "Mean",
    "MeanLoss", "Median", "MomentState", "Quantile", "SlidingWindow",
    "Statistic", "StatisticGroup", "Std", "Sum", "TumblingWindow", "Var",
    "Window", "kmeans_fit",
    "EarlSession", "EarlyResult", "SSABEResult", "ssabe",
    "StreamingBootstrapResult", "StreamReport", "bootstrap_streaming",
]
