"""Distributed EARL: the bootstrap over mesh-sharded data (DESIGN.md §2).

MapReduce mapping:
  mapper  -> per-shard state update under shard-local Poisson weights
  combine -> Statistic.merge (associative)
  reducer -> psum of states across the 'data' (and 'pod') mesh axes,
             finalize replicated.

Shard independence is exactly why the Poisson engine is the distributed
default: weights for items on shard d depend only on (key, d, item), never
on other shards — no global multinomial coordination (DESIGN.md §7.1).

``distributed_bootstrap`` builds a jitted shard_map program for a given mesh;
``distributed_earl_estimate`` wraps it in the expand-until-accurate loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import accuracy
from repro.core.bootstrap import (BootstrapResult, fused_resample_states,
                                  offset_seed, seed_from_key)
from repro.core.reduce_api import Statistic, _as_2d


def _poisson_for_shard(key: jax.Array, shard_id: jax.Array, B: int,
                       n_local: int) -> jax.Array:
    k = jax.random.fold_in(key, shard_id)
    return jax.random.poisson(k, 1.0, (B, n_local)).astype(jnp.float32)


def build_bootstrap_step(mesh: Mesh, stat: Statistic, B: int,
                         data_axes: Sequence[str] = ("data",),
                         donate: bool = True,
                         backend: Optional[str] = None):
    """Returns jitted fn (values_sharded, mask_sharded, key) -> (thetas, est).

    values: (n_global, d) sharded over ``data_axes`` on dim 0.
    mask:   (n_global,) 1.0 for real rows, 0.0 for padding — enables
            ragged global samples (n not divisible by the data axis) and
            ft/ shard-loss reweighting (zero a lost shard's mask).

    ``backend="fused_rng"`` generates each shard's Poisson(1) weights
    inside the fused kernels (stream keyed by (seed_from_key(key), shard)
    via ``offset_seed``) instead of materializing the (B, n_local) matrix;
    the shard's mask slice multiplies the implicit weight tiles
    (``valid_mask``), so ARBITRARY masks work — interior holes from ft/
    failed-shard loss included — and a prefix mask (what
    ``pad_to_shards`` produces) reproduces the historical n_valid-based
    masking bit for bit.

    Cross-shard reduction goes through ``Statistic.psum_state`` (NOT a raw
    tree-psum: Quantile's HistogramState carries non-additive lo/hi leaves
    that a blind psum would scale by the shard count).
    """
    if backend not in (None, "fused_rng"):
        raise ValueError(f"unknown distributed backend: {backend!r}")
    data_axes = tuple(data_axes)
    axis_sizes = [mesh.shape[a] for a in data_axes]
    nshards = 1
    for s in axis_sizes:
        nshards *= s

    def shard_fn(values, mask, key):
        # flat shard index across the (pod, data) axes
        idx = jnp.zeros((), jnp.int32)
        for a in data_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        n_local, dim = values.shape
        if backend == "fused_rng":
            states = fused_resample_states(
                stat, offset_seed(seed_from_key(key), idx), values, B,
                valid_mask=mask)
        else:
            w = _poisson_for_shard(key, idx, B, n_local) * mask[None, :]

            def upd(w_row):
                return stat.update(stat.init_state(dim), values, w_row)

            states = jax.vmap(upd)(w)                   # B-leading pytree
        states = stat.psum_state(states, data_axes)
        thetas = jax.vmap(stat.finalize)(states)

        est_state = stat.update(stat.init_state(dim), values, mask)
        est_state = stat.psum_state(est_state, data_axes)
        estimate = stat.finalize(est_state)
        return thetas, estimate

    from repro.compat import shard_map_compat
    shard_map, sm_kw = shard_map_compat()
    in_specs = (P(data_axes, None), P(data_axes), P())
    out_specs = (P(), P())
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, **sm_kw)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


def pad_to_shards(values: jax.Array, nshards: int):
    """Pad rows to a multiple of nshards; returns (padded, mask)."""
    x = _as_2d(values)
    n = x.shape[0]
    pad = (-n) % nshards
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    mask = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad))
    return xp, mask


def shard_values(mesh: Mesh, values: jax.Array,
                 data_axes: Sequence[str] = ("data",)):
    """Place (pad, shard) values over the data axes of the mesh."""
    data_axes = tuple(data_axes)
    nshards = 1
    for a in data_axes:
        nshards *= mesh.shape[a]
    xp, mask = pad_to_shards(values, nshards)
    xs = jax.device_put(xp, NamedSharding(mesh, P(data_axes, None)))
    ms = jax.device_put(mask, NamedSharding(mesh, P(data_axes)))
    return xs, ms


@dataclasses.dataclass
class DistributedEarl:
    """Mesh-wide EARL estimator with growing global samples.

    Used by train/earl_eval.py and the ft/ recovery path.  The sample is a
    global sharded array; expansion re-places a longer prefix (in a real
    multi-host deployment each host feeds only its local rows — the
    placement API is identical).
    """
    mesh: Mesh
    stat: Statistic
    B: int
    sigma: float = 0.05
    data_axes: Sequence[str] = ("data",)
    backend: Optional[str] = None   # "fused_rng" = in-kernel shard weights

    def __post_init__(self):
        self._step = build_bootstrap_step(self.mesh, self.stat, self.B,
                                          self.data_axes, donate=False,
                                          backend=self.backend)

    def estimate(self, values: jax.Array, key: jax.Array,
                 p: float = 1.0) -> BootstrapResult:
        xs, ms = shard_values(self.mesh, values, self.data_axes)
        thetas, est = self._step(xs, ms, key)
        thetas = self.stat.correct(thetas, p)
        est = self.stat.correct(est, p)
        return BootstrapResult(
            estimate=est, thetas=thetas,
            report=accuracy.report_for(
                thetas, num_groups=getattr(self.stat, "num_groups", None)),
            B=self.B, n=int(_as_2d(values).shape[0]))

    def estimate_with_loss_mask(self, values: jax.Array, mask: jax.Array,
                                key: jax.Array, p: float = 1.0
                                ) -> BootstrapResult:
        """ft/ path: ``mask`` already encodes lost shards (zeros).

        Works on every backend: the fused backend multiplies its implicit
        weight tiles by the mask slice (interior holes included), the
        default backend multiplies the materialized matrix — same
        estimator either way."""
        xs = jax.device_put(_as_2d(values),
                            NamedSharding(self.mesh,
                                          P(tuple(self.data_axes), None)))
        ms = jax.device_put(mask,
                            NamedSharding(self.mesh,
                                          P(tuple(self.data_axes))))
        thetas, est = self._step(xs, ms, key)
        thetas = self.stat.correct(thetas, p)
        est = self.stat.correct(est, p)
        n_eff = int(jnp.sum(mask))
        return BootstrapResult(
            estimate=est, thetas=thetas,
            report=accuracy.report_for(
                thetas, num_groups=getattr(self.stat, "num_groups", None)),
            B=self.B, n=n_eff)

    def estimate_elastic(self, values: jax.Array, key: jax.Array,
                         events, policy):
        """Mid-run degradation: shards in ``events`` that died or missed
        the deadline feed masked partial psums (their ``valid_mask`` slice
        is zero — survivors' work is NOT recomputed), the CI widens via
        ``correct(p_surviving)``, and ``policy`` turns ``meets_bound`` into
        continue-approximate vs checkpoint-restart.

        ``events`` is an ``ft.ShardEvents``, ``policy`` an
        ``ft.FailurePolicy``; returns an ``ft.ElasticReport``.  (Lazy
        import: ft/ sits above core/ in the layer order.)"""
        from repro.ft.policy import elastic_estimate
        return elastic_estimate(self, values, key, events, policy)
