"""Bootstrap engines (paper §3, DESIGN.md §2).

A resample-with-replacement is represented by a *weight vector* over the
sample, so ``f(resample)`` is a weighted statistic and the B-resample loop
vectorizes over a dense (B, n) weight matrix — MXU work instead of gathers.

Two engines:

* ``multinomial`` — paper-faithful: the B rows are exact multinomial
  counts Multinomial(n; 1/n,...,1/n), i.e. classic Efron bootstrap.
* ``poisson``     — distributed default (beyond-paper, DESIGN.md §7.1):
  iid Poisson(1) weights per (item, resample).  Same first two moments,
  shard-independent, and makes inter-iteration delta maintenance exact.

Both route moment statistics through kernels/weighted_stats when asked.

Backends (``backend=`` on ``bootstrap``/``bootstrap_chunked``):

* ``None``        — materialized weights (jnp oracle); ``use_kernel`` may
  additionally route the contraction through the weighted_stats kernel.
* ``"fused_rng"`` — matrix-free (poisson engine only): weights are
  generated inside the contraction from a counter-based PRNG, so the (B, n)
  weight matrix never exists.  Statistics opt in via
  ``Statistic.fused_poisson_states``: moment statistics (Mean/Sum/Count/
  Var/Std) route through kernels/weighted_stats.fused_poisson_moments
  (peak O(B·d)), ``KMeansStep`` through
  kernels/kmeans_assign.fused_poisson_kmeans (peak O(B·k·d), and no (n, k)
  distance/one-hot intermediate either); statistics without a fused path
  (e.g. Quantile) fall back to materializing the same implicit weights per
  chunk.  The PRNG seed derives deterministically from ``key``, so the
  fold-in discipline (delta maintenance, common random numbers) carries
  over unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import accuracy
from repro.core.reduce_api import (Statistic, _as_2d, bind_params,
                                   split_params)


@dataclasses.dataclass
class BootstrapResult:
    estimate: jax.Array        # f on the full sample (unweighted), corrected
    thetas: jax.Array          # (B, ...) bootstrap result distribution
    report: accuracy.AccuracyReport
    B: int
    n: int

    @property
    def cv(self) -> float:
        return self.report.cv


# ----------------------------------------------------------------------------
# weight generation
# ----------------------------------------------------------------------------
def seed_from_key(key: jax.Array) -> jax.Array:
    """Deterministic int32 seed for the counter-based in-kernel PRNG.

    Multi-stream callers (chunked bootstrap, delta maintenance) derive ONE
    base seed per run and offset it by the chunk/step counter via
    ``offset_seed`` — streams within a run are distinct *by construction*
    (no 31-bit birthday bound), while different keys still give independent
    runs."""
    return jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max,
                              dtype=jnp.int32)


_SEED_MOD = int(jnp.iinfo(jnp.int32).max)      # 2^31 - 1


def offset_seed(base_seed, i):
    """The i-th derived stream seed: (base + i) mod (2^31 − 1), computed
    without int32 overflow.

    ``base_seed`` comes from ``seed_from_key`` (∈ [0, 2^31−1)); a plain
    ``base + i`` wraps past ``iinfo(int32).max`` for large chunk/step
    counters (or a base drawn near the boundary), silently flipping the
    seed negative.  Both branches stay inside [0, 2^31−1)."""
    base = jnp.asarray(base_seed, jnp.int32)
    off = jnp.asarray(i, jnp.int32) % _SEED_MOD
    room = _SEED_MOD - off
    return jnp.where(base >= room, base - room, base + off)


def fused_resample_states(stat: Statistic, seed, x2: jax.Array, B: int,
                          n_valid=None):
    """B-leading pytree of per-resample states for ``x2`` under implicit
    in-kernel Poisson(1) weights (the matrix-free hot path).

    Statistics with a fused path (``Statistic.fused_poisson_states``:
    moment statistics and KMeansStep) never see a (B, n) matrix; other
    statistics fall back to materializing the same implicit weights.  The
    result is a *delta* state: ``merge`` it into running states
    (delta/chunked) or ``finalize`` it directly (one-shot bootstrap).
    """
    states = stat.fused_poisson_states(seed, x2, B, n_valid=n_valid)
    if states is not None:
        return states
    from repro.kernels.weighted_stats import ops as ws_ops
    w = ws_ops.implicit_weights(seed, B, x2.shape[0])
    if n_valid is not None:
        w = w * (jnp.arange(x2.shape[0]) < n_valid).astype(w.dtype)[None, :]
    dim = x2.shape[1]
    return jax.vmap(lambda wr: stat.update(stat.init_state(dim), x2, wr))(w)


def multinomial_counts(key: jax.Array, B: int, n: int,
                       resample_size: Optional[int] = None) -> jax.Array:
    """Exact multinomial bootstrap counts, shape (B, n) int32.

    Drawn as n' categorical draws per resample, histogrammed as ONE
    flattened (B·m,) scatter-add into the (B, n) zeros buffer — a single
    XLA scatter dispatch instead of B vmapped ones.
    """
    m = n if resample_size is None else int(resample_size)
    idx = jax.random.randint(key, (B, m), 0, n)            # (B, m) draws
    rows = jnp.broadcast_to(jnp.arange(B, dtype=idx.dtype)[:, None],
                            idx.shape)
    # 2-D scatter indices (not a flattened B·n offset, which would overflow
    # int32 once B·n >= 2^31): still one XLA scatter dispatch.
    return jnp.zeros((B, n), jnp.int32).at[rows, idx].add(1)


def poisson_weights(key: jax.Array, B: int, n: int,
                    dtype=jnp.float32) -> jax.Array:
    """Poisson(1) bootstrap weights, shape (B, n)."""
    return jax.random.poisson(key, 1.0, (B, n)).astype(dtype)


def weights_for(engine: str, key: jax.Array, B: int, n: int) -> jax.Array:
    if engine == "multinomial":
        return multinomial_counts(key, B, n).astype(jnp.float32)
    if engine == "poisson":
        return poisson_weights(key, B, n)
    raise ValueError(f"unknown bootstrap engine: {engine!r}")


# ----------------------------------------------------------------------------
# the resample loop
# ----------------------------------------------------------------------------
def bootstrap_thetas(values: jax.Array, stat: Statistic,
                     weights: jax.Array, use_kernel: bool = False
                     ) -> jax.Array:
    """Apply ``stat`` under every weight row.  Returns (B, ...) results."""
    x2 = _as_2d(values)
    dim = x2.shape[1]

    if use_kernel and stat.moment_powers is not None:
        # fused Pallas path: one (B,n)@(n,d) pass for all moments at once.
        from repro.kernels.weighted_stats import ops as ws_ops
        w_tot, s1, s2 = ws_ops.weighted_moments(weights, x2)
        states = jax.vmap(stat.from_moments)(w_tot, s1, s2)
        return jax.vmap(stat.finalize)(states)

    def one(w_row):
        return stat.finalize(stat.update(stat.init_state(dim), values, w_row))

    return jax.vmap(one)(weights)


def _fused_thetas(values: jax.Array, stat: Statistic, B: int,
                  key: jax.Array) -> jax.Array:
    """Matrix-free resample loop: moments via in-kernel RNG, (B, n) never
    built.  Falls back to materializing the same implicit weights for
    statistics without a moment decomposition."""
    states = fused_resample_states(stat, seed_from_key(key), _as_2d(values),
                                   B)
    return jax.vmap(stat.finalize)(states)


@partial(jax.jit,
         static_argnames=("stat", "B", "engine", "use_kernel", "backend"))
def _bootstrap_jit(values, key, params, stat, B, engine, use_kernel,
                   backend):
    # ``stat`` is the hashable spec; its array parameters (e.g. KMeansStep
    # centroids) arrive traced in ``params`` so Lloyd-style loops that pass
    # a fresh same-shaped Statistic per call hit this cache entry.
    stat = bind_params(stat, params)
    n = values.shape[0]
    if backend == "fused_rng":
        thetas = _fused_thetas(values, stat, B, key)
    else:
        w = weights_for(engine, key, B, n)
        thetas = bootstrap_thetas(values, stat, w, use_kernel=use_kernel)
    estimate = stat(values)
    return thetas, estimate


def bootstrap(values: jax.Array, stat: Statistic, B: int, key: jax.Array,
              engine: str = "poisson", p: float = 1.0,
              use_kernel: bool = False, alpha: float = 0.05,
              backend: Optional[str] = None) -> BootstrapResult:
    """One full bootstrap pass: B resamples, result distribution, accuracy.

    ``p`` is the fraction of the population the sample represents — passed to
    ``stat.correct`` (paper §2.1) on both the estimate and the thetas.
    ``backend="fused_rng"`` runs the matrix-free pipeline (module docstring).
    """
    if not isinstance(stat, Statistic):
        raise TypeError("stat must be a reduce_api.Statistic")
    if backend not in (None, "fused_rng"):
        raise ValueError(f"unknown bootstrap backend: {backend!r}")
    if backend == "fused_rng" and engine != "poisson":
        raise ValueError("backend='fused_rng' requires the poisson engine "
                         "(in-kernel RNG draws iid Poisson(1) weights)")
    spec, params = split_params(stat)
    thetas, estimate = _bootstrap_jit(values, key, params, spec, int(B),
                                      engine, bool(use_kernel), backend)
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(estimate, p)
    return BootstrapResult(
        estimate=estimate,
        thetas=thetas,
        report=accuracy.AccuracyReport.from_thetas(thetas, alpha=alpha),
        B=int(B),
        n=int(values.shape[0]),
    )


# ----------------------------------------------------------------------------
# streaming / chunked variant (large samples that don't fit a (B,n) matrix)
# ----------------------------------------------------------------------------
def bootstrap_chunked(values: jax.Array, stat: Statistic, B: int,
                      key: jax.Array, chunk: int = 65536,
                      engine: str = "poisson", p: float = 1.0,
                      backend: Optional[str] = None) -> BootstrapResult:
    """Scan over chunks of the sample, merging per-resample states.

    Only valid for mergeable statistics (all built-ins).  Poisson weights are
    drawn per chunk with a folded key, so the full (B, n) matrix never
    materializes — peak memory is (B, chunk), or O(B·d) / O(B·k·d) with
    ``backend="fused_rng"`` for statistics with a fused path (moment
    statistics, KMeansStep — see ``Statistic.fused_poisson_states``; the
    per-chunk weight matrix never materializes either).  Chunk seeds derive
    as ``offset_seed(base, i)`` so long streams can't wrap int32.
    """
    if engine != "poisson":
        raise ValueError("chunked bootstrap requires the poisson engine "
                         "(multinomial couples all chunks; see DESIGN.md §7)")
    if backend not in (None, "fused_rng"):
        raise ValueError(f"unknown bootstrap backend: {backend!r}")
    x = _as_2d(values)
    n, dim = x.shape
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nchunks = xp.shape[0] // chunk
    xc = xp.reshape(nchunks, chunk, dim)

    init = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))
    base_seed = seed_from_key(key)      # one base; chunks offset by counter

    def body(states, inp):
        i, xi = inp
        n_valid = jnp.minimum(chunk, n - i * chunk)   # suffix of last chunk
        if backend == "fused_rng":
            delta = fused_resample_states(stat, offset_seed(base_seed, i),
                                          xi, B, n_valid=n_valid)
            return jax.vmap(stat.merge)(states, delta), None
        vi = (jnp.arange(chunk) < n_valid).astype(jnp.float32)
        w = poisson_weights(jax.random.fold_in(key, i), B, chunk) \
            * vi[None, :]
        new = jax.vmap(lambda s, wr: stat.update(s, xi, wr))(states, w)
        return new, None

    states, _ = jax.lax.scan(body, init,
                             (jnp.arange(nchunks), xc))
    thetas = jax.vmap(stat.finalize)(states)
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(stat(values), p)
    return BootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.AccuracyReport.from_thetas(thetas),
        B=int(B), n=int(n),
    )
