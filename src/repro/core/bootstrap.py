"""Bootstrap engines (paper §3, DESIGN.md §2).

A resample-with-replacement is represented by a *weight vector* over the
sample, so ``f(resample)`` is a weighted statistic and the B-resample loop
vectorizes over a dense (B, n) weight matrix — MXU work instead of gathers.

Two engines:

* ``multinomial`` — paper-faithful: the B rows are exact multinomial
  counts Multinomial(n; 1/n,...,1/n), i.e. classic Efron bootstrap.
* ``poisson``     — distributed default (beyond-paper, DESIGN.md §7.1):
  iid Poisson(1) weights per (item, resample).  Same first two moments,
  shard-independent, and makes inter-iteration delta maintenance exact.

Both route moment statistics through kernels/weighted_stats when asked.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import accuracy
from repro.core.reduce_api import Statistic, _as_2d


@dataclasses.dataclass
class BootstrapResult:
    estimate: jax.Array        # f on the full sample (unweighted), corrected
    thetas: jax.Array          # (B, ...) bootstrap result distribution
    report: accuracy.AccuracyReport
    B: int
    n: int

    @property
    def cv(self) -> float:
        return self.report.cv


# ----------------------------------------------------------------------------
# weight generation
# ----------------------------------------------------------------------------
def multinomial_counts(key: jax.Array, B: int, n: int,
                       resample_size: Optional[int] = None) -> jax.Array:
    """Exact multinomial bootstrap counts, shape (B, n) int32.

    Drawn as n' categorical draws per resample, histogrammed via scatter-add.
    """
    m = n if resample_size is None else int(resample_size)
    idx = jax.random.randint(key, (B, m), 0, n)            # (B, m) draws

    def hist(row):
        return jnp.zeros((n,), jnp.int32).at[row].add(1)

    return jax.vmap(hist)(idx)


def poisson_weights(key: jax.Array, B: int, n: int,
                    dtype=jnp.float32) -> jax.Array:
    """Poisson(1) bootstrap weights, shape (B, n)."""
    return jax.random.poisson(key, 1.0, (B, n)).astype(dtype)


def weights_for(engine: str, key: jax.Array, B: int, n: int) -> jax.Array:
    if engine == "multinomial":
        return multinomial_counts(key, B, n).astype(jnp.float32)
    if engine == "poisson":
        return poisson_weights(key, B, n)
    raise ValueError(f"unknown bootstrap engine: {engine!r}")


# ----------------------------------------------------------------------------
# the resample loop
# ----------------------------------------------------------------------------
def bootstrap_thetas(values: jax.Array, stat: Statistic,
                     weights: jax.Array, use_kernel: bool = False
                     ) -> jax.Array:
    """Apply ``stat`` under every weight row.  Returns (B, ...) results."""
    x2 = _as_2d(values)
    dim = x2.shape[1]

    if use_kernel and stat.moment_powers is not None:
        # fused Pallas path: one (B,n)@(n,d) pass for all moments at once.
        from repro.kernels.weighted_stats import ops as ws_ops
        w_tot, s1, s2 = ws_ops.weighted_moments(weights, x2)
        states = jax.vmap(stat.from_moments)(w_tot, s1, s2)
        return jax.vmap(stat.finalize)(states)

    def one(w_row):
        return stat.finalize(stat.update(stat.init_state(dim), values, w_row))

    return jax.vmap(one)(weights)


@partial(jax.jit, static_argnames=("stat", "B", "engine", "use_kernel"))
def _bootstrap_jit(values, key, stat, B, engine, use_kernel):
    n = values.shape[0]
    w = weights_for(engine, key, B, n)
    thetas = bootstrap_thetas(values, stat, w, use_kernel=use_kernel)
    estimate = stat(values)
    return thetas, estimate


def bootstrap(values: jax.Array, stat: Statistic, B: int, key: jax.Array,
              engine: str = "poisson", p: float = 1.0,
              use_kernel: bool = False, alpha: float = 0.05
              ) -> BootstrapResult:
    """One full bootstrap pass: B resamples, result distribution, accuracy.

    ``p`` is the fraction of the population the sample represents — passed to
    ``stat.correct`` (paper §2.1) on both the estimate and the thetas.
    """
    if not isinstance(stat, Statistic):
        raise TypeError("stat must be a reduce_api.Statistic")
    thetas, estimate = _bootstrap_jit(values, key, stat, int(B), engine,
                                      bool(use_kernel))
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(estimate, p)
    return BootstrapResult(
        estimate=estimate,
        thetas=thetas,
        report=accuracy.AccuracyReport.from_thetas(thetas, alpha=alpha),
        B=int(B),
        n=int(values.shape[0]),
    )


# ----------------------------------------------------------------------------
# streaming / chunked variant (large samples that don't fit a (B,n) matrix)
# ----------------------------------------------------------------------------
def bootstrap_chunked(values: jax.Array, stat: Statistic, B: int,
                      key: jax.Array, chunk: int = 65536,
                      engine: str = "poisson", p: float = 1.0
                      ) -> BootstrapResult:
    """Scan over chunks of the sample, merging per-resample states.

    Only valid for mergeable statistics (all built-ins).  Poisson weights are
    drawn per chunk with a folded key, so the full (B, n) matrix never
    materializes — peak memory is (B, chunk).
    """
    if engine != "poisson":
        raise ValueError("chunked bootstrap requires the poisson engine "
                         "(multinomial couples all chunks; see DESIGN.md §7)")
    x = _as_2d(values)
    n, dim = x.shape
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    valid = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad))
    nchunks = xp.shape[0] // chunk
    xc = xp.reshape(nchunks, chunk, dim)
    vc = valid.reshape(nchunks, chunk)

    init = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))

    def body(states, inp):
        i, xi, vi = inp
        w = poisson_weights(jax.random.fold_in(key, i), B, chunk) * vi[None, :]
        new = jax.vmap(lambda s, wr: stat.update(s, xi, wr))(states, w)
        return new, None

    states, _ = jax.lax.scan(body, init,
                             (jnp.arange(nchunks), xc, vc))
    thetas = jax.vmap(stat.finalize)(states)
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(stat(values), p)
    return BootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.AccuracyReport.from_thetas(thetas),
        B=int(B), n=int(n),
    )
